//! E7 — ablations over the design choices DESIGN.md calls out:
//!   (1) safe elimination ON vs OFF (end-to-end cost of skipping Thm 2.1);
//!   (2) barrier ε (β = ε/n) sensitivity: accuracy vs sweeps;
//!   (3) inner QP sweep budget: solution quality vs time;
//!   (4) deflation scheme: projection vs Hotelling on recovered topics.

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::data::SymMat;
use lsspca::elim::SafeElimination;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::deflate::Scheme;
use lsspca::solver::extract::leading_sparse_pc;
use lsspca::solver::qp::QpOptions;
use lsspca::stream::{variance_pass, StreamOptions, SynthSource};
use lsspca::util::bench::{metric, section};
use lsspca::util::rng::Rng;
use lsspca::util::timer::Timer;

fn ablate_elimination() {
    section("A1 — safe elimination on/off (nytimes-like 10k×8k)");
    let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(10_000, 8_000), 5);
    let opts = StreamOptions { workers: 2, chunk_docs: 2048, queue_depth: 4 };
    let (fv, _) = variance_pass(&mut SynthSource::new(&corpus), opts).unwrap();
    let (elim, _) = lsspca::coordinator::choose_elimination(&fv, 5, 200);
    let lambda = elim.lambda;
    // ON: solve on the reduced covariance
    let t = Timer::start();
    let (cov, _) = lsspca::cov::covariance_pass(&mut SynthSource::new(&corpus), &elim, opts).unwrap();
    let sol = bca::solve(&cov, lambda, &BcaOptions::default());
    let on_secs = t.secs();
    metric("elim_on.nhat", elim.reduced());
    metric("elim_on.seconds", format!("{on_secs:.2}"));
    metric("elim_on.phi", format!("{:.4}", sol.phi));
    // OFF: keep everything with nonzero variance, capped at a size that
    // is still feasible on this box — the point is the scaling gap.
    let off_keep = 1200usize;
    let elim_off = SafeElimination::from_variances(&fv, 0.0, Some(off_keep));
    let t = Timer::start();
    let (cov_off, _) =
        lsspca::cov::covariance_pass(&mut SynthSource::new(&corpus), &elim_off, opts).unwrap();
    let sol_off = bca::solve(&cov_off, lambda, &BcaOptions { max_sweeps: 2, ..Default::default() });
    let off_secs = t.secs();
    metric("elim_off.n", format!("{off_keep} (capped; full n=8000 would be ~×{:.0} more)", (8000.0 / off_keep as f64).powi(3)));
    metric("elim_off.seconds_2sweeps", format!("{off_secs:.2}"));
    metric("elim_off.phi_2sweeps", format!("{:.4}", sol_off.phi));
    metric(
        "elim_speedup_observed",
        format!("{:.0}x (at equal sweep count it scales as (n/n̂)³)", off_secs / on_secs.max(1e-9)),
    );
}

fn ablate_epsilon() {
    section("A2 — barrier ε sensitivity (spiked n=60)");
    let mut rng = Rng::seed_from(11);
    let (sigma, _) = spiked_covariance_with_u(60, 120, 6, 3.0, &mut rng);
    let d: Vec<f64> = (0..60).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 20);
    // high-accuracy reference
    let ref_phi = bca::solve(
        &sigma,
        lambda,
        &BcaOptions { max_sweeps: 80, epsilon: 1e-6, tol: 1e-12, ..Default::default() },
    )
    .phi;
    for &eps in &[1e-1, 1e-2, 1e-3, 1e-4] {
        let sol = bca::solve(
            &sigma,
            lambda,
            &BcaOptions { max_sweeps: 40, epsilon: eps, ..Default::default() },
        );
        metric(
            &format!("epsilon.{eps:.0e}"),
            format!(
                "phi_err={:.2e} sweeps={} secs={:.3}",
                (ref_phi - sol.phi).abs(),
                sol.sweeps,
                sol.seconds
            ),
        );
    }
}

fn ablate_qp_sweeps() {
    section("A3 — inner QP sweep budget (spiked n=100)");
    let mut rng = Rng::seed_from(12);
    let (sigma, _) = spiked_covariance_with_u(100, 200, 10, 2.0, &mut rng);
    let d: Vec<f64> = (0..100).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 30);
    let ref_phi = bca::solve(
        &sigma,
        lambda,
        &BcaOptions { max_sweeps: 60, epsilon: 1e-4, tol: 1e-12, ..Default::default() },
    )
    .phi;
    for &k in &[1usize, 2, 4, 8, 32] {
        let opts = BcaOptions {
            max_sweeps: 25,
            qp: QpOptions { max_sweeps: k, tol: 0.0 },
            ..Default::default()
        };
        let sol = bca::solve(&sigma, lambda, &opts);
        metric(
            &format!("qp_sweeps.{k}"),
            format!("phi_err={:.2e} secs={:.3}", (ref_phi - sol.phi).abs(), sol.seconds),
        );
    }
}

fn ablate_deflation() {
    section("A4 — deflation scheme (spiked, 3 planted orthogonal-ish spikes)");
    let mut rng = Rng::seed_from(13);
    // covariance with 3 separated spikes: sum of block spikes + noise
    let n = 60;
    let mut sigma = SymMat::zeros(n);
    for b in 0..3 {
        for i in 0..5 {
            for j in 0..5 {
                let (a, c) = (b * 20 + i, b * 20 + j);
                let v = sigma.get(a, c) + (3.0 - b as f64 * 0.5) * 0.2;
                sigma.set(a, c, v);
            }
        }
    }
    let noise = lsspca::corpus::gaussian_factor_cov(n, 300, &mut rng);
    for i in 0..n {
        for j in 0..n {
            let v = sigma.get(i, j) + 0.3 * noise.get(i, j);
            sigma.set(i, j, v);
        }
    }
    for scheme in [Scheme::Projection, Scheme::Hotelling] {
        let mut work = sigma.clone();
        let mut found = Vec::new();
        for _ in 0..3 {
            let d: Vec<f64> = (0..n).map(|i| work.get(i, i)).collect();
            let lambda = lsspca::elim::lambda_for_survivors(&d, 12).max(1e-6);
            let sol = bca::solve(&work, lambda, &BcaOptions::default());
            let pc = leading_sparse_pc(&sol.z, 1e-3);
            found.push(pc.support.first().map(|&i| i / 20).unwrap_or(99));
            scheme.apply(&mut work, &pc.vector);
        }
        let distinct: std::collections::BTreeSet<_> = found.iter().collect();
        metric(
            &format!("deflation.{scheme:?}.blocks_found"),
            format!("{found:?} ({} distinct)", distinct.len()),
        );
    }
}

fn ablate_methods() {
    // A5 — method quality at matched cardinality: DSPCA (BCA) vs every
    // related-work baseline the paper's intro names — forward greedy
    // [5,6], simple thresholding [4], generalized power [10], and SPCA
    // via elastic net [8]. The literature's claim (and the reason the
    // paper builds on the SDP relaxation): local/ad-hoc methods
    // underperform.
    section("A5 — explained variance at matched cardinality (spiked n=40, card 5)");
    let mut rng = Rng::seed_from(14);
    let mut dspca_best = 0usize;
    let trials = 5;
    for trial in 0..trials {
        let (sigma, u) = spiked_covariance_with_u(40, 60, 5, 2.5, &mut rng);
        let planted = lsspca::linalg::vec::support(&u, 1e-9);
        let thr = lsspca::solver::threshold::thresholded_pc(&sigma, 5);
        let gre = lsspca::solver::greedy::forward(&sigma, 5).pc_at(&sigma, 5);
        // gpower/spca: tune their penalty to land near cardinality 5
        let max_d = (0..40).map(|i| sigma.get(i, i)).fold(0.0f64, f64::max);
        let gp = (0..12)
            .map(|k| {
                let gamma = max_d * (k as f64 + 1.0) / 13.0;
                lsspca::solver::gpower::solve(
                    &sigma,
                    gamma,
                    &lsspca::solver::gpower::GPowerOptions::default(),
                    &mut rng,
                )
            })
            .filter(|pc| pc.cardinality() >= 1)
            .min_by_key(|pc| pc.cardinality().abs_diff(5))
            .unwrap();
        let sz = (0..8)
            .map(|k| {
                let l1 = max_d * (k as f64 + 1.0) / 6.0;
                lsspca::solver::spca_zou::solve(
                    &sigma,
                    l1,
                    &lsspca::solver::spca_zou::SpcaOptions::default(),
                )
            })
            .filter(|pc| pc.cardinality() >= 1)
            .min_by_key(|pc| pc.cardinality().abs_diff(5))
            .unwrap();
        // λ-search DSPCA to cardinality 5
        let res = lsspca::solver::lambda::search(
            &sigma,
            &lsspca::solver::lambda::LambdaSearchOptions {
                target_card: 5,
                slack: 0,
                max_evals: 14,
                ..Default::default()
            },
        );
        // primary metric: planted-support recovery (the robust comparison
        // near the detection threshold — raw explained variance rewards
        // noise-fitting there); explained variance reported alongside.
        let hits = |pc: &lsspca::solver::extract::SparsePc| {
            pc.support.iter().filter(|i| planted.contains(i)).count()
        };
        let (hd, hg, ht, hp, hz) = (hits(&res.pc), hits(&gre), hits(&thr), hits(&gp), hits(&sz));
        let vd = res.pc.explained_variance(&sigma);
        metric(
            &format!("methods.trial{trial}"),
            format!(
                "recovery/5: dspca={hd} greedy={hg} thresh={ht} gpower={hp} spca={hz}  (dspca ev={vd:.3}/k{})",
                res.pc.cardinality()
            ),
        );
        if hd >= hg.max(ht).max(hp).max(hz) {
            dspca_best += 1;
        }
    }
    metric(
        "methods.dspca_recovery_at_or_above_all",
        format!("{dspca_best}/{trials} trials"),
    );
}

fn main() {
    ablate_elimination();
    ablate_epsilon();
    ablate_qp_sweeps();
    ablate_deflation();
    ablate_methods();
}
