//! E3 — paper Table 1: top-5 sparse PCs on the NYTimes-like corpus, with
//! planted-topic recovery scoring (the synthetic substitute has ground
//! truth, so "the PCs correspond to the topics" becomes checkable).

use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::CorpusSpec;
use lsspca::util::bench::{metric, section};

pub fn run_preset(preset: &str, docs: usize, vocab: usize) {
    section(&format!("Table: top-5 sparse PCs on {preset} ({docs}×{vocab})"));
    let cfg = PipelineConfig {
        synth_preset: preset.into(),
        synth_docs: docs,
        synth_vocab: vocab,
        num_pcs: 5,
        target_card: 5,
        card_slack: 2,
        max_reduced: 256,
        workers: 2,
        ..Default::default()
    };
    let report = Pipeline::new(cfg).run().expect("pipeline");
    println!("{}", report.topic_table);
    metric(&format!("{preset}.reduced_size"), report.reduced_size);
    metric(
        &format!("{preset}.reduction_factor"),
        format!("{:.0}", report.reduction_factor),
    );
    // topic recovery score: each PC is assigned its best-matching planted
    // topic; score = matched words / PC cardinality, and topic coverage =
    // number of distinct topics matched across the 5 PCs.
    let spec = CorpusSpec::preset(preset).unwrap();
    let mut matched_topics = std::collections::BTreeSet::new();
    let mut purity_sum = 0.0;
    for (k, comp) in report.components.iter().enumerate() {
        let (best_t, best_overlap) = spec
            .topics
            .iter()
            .enumerate()
            .map(|(t, topic)| {
                (
                    t,
                    comp.words
                        .iter()
                        .filter(|w| topic.words.contains(&w.as_str()))
                        .count(),
                )
            })
            .max_by_key(|&(_, o)| o)
            .unwrap();
        let purity = best_overlap as f64 / comp.words.len().max(1) as f64;
        purity_sum += purity;
        if 2 * best_overlap >= comp.words.len() {
            matched_topics.insert(best_t);
        }
        metric(
            &format!("{preset}.pc{}.purity", k + 1),
            format!("{purity:.2} (topic '{}')", spec.topics[best_t].name),
        );
        metric(
            &format!("{preset}.pc{}.seconds", k + 1),
            format!("{:.2}", comp.seconds),
        );
    }
    metric(
        &format!("{preset}.mean_purity"),
        format!("{:.2}", purity_sum / report.components.len() as f64),
    );
    metric(&format!("{preset}.distinct_topics_recovered"), matched_topics.len());
    metric(
        &format!("{preset}.total_seconds"),
        format!("{:.2}", report.total_seconds),
    );
}

fn main() {
    run_preset("nytimes", 20_000, 30_000);
}
