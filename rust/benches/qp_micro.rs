//! Micro-benchmark of the paper's hot spot: the box-constrained QP
//! coordinate descent (Eq 11–13). Used by the §Perf pass to tune the inner
//! loop (dot-product unrolling, incremental w-maintenance, early exit).

use lsspca::data::SymMat;
use lsspca::solver::qp::{solve, solve_masked, QpOptions};
use lsspca::util::bench::{bench, metric, section, BenchConfig};
use lsspca::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from(99);
    section("QP coordinate descent micro");
    for &n in &[64usize, 128, 256, 512] {
        let y = SymMat::random_psd(n, n / 2 + 4, 0.05, &mut rng);
        let s = rng.gauss_vec(n);
        let lambda = 0.3;
        let opts = QpOptions { max_sweeps: 8, tol: 0.0 };
        let r = bench(&format!("qp fixed-8-sweeps n={n}"), BenchConfig::default(), || {
            solve(&y, &s, lambda, opts).r_squared
        });
        // work rate: 8 sweeps × n coords × n flops ×2 (dot + axpy)
        let flops = (8 * n * n * 4) as f64;
        metric(
            &format!("qp.n{n}.gflops"),
            format!("{:.2}", flops / r.summary.p50 / 1e9),
        );
        // converged (early-exit) variant, as the BCA outer loop runs it
        let conv = QpOptions::default();
        bench(&format!("qp converged n={n}"), BenchConfig::default(), || {
            solve(&y, &s, lambda, conv).sweeps
        });
        // masked (skip-one) variant: the exact call shape of Algorithm 1
        let mut u = Vec::new();
        let mut w = Vec::new();
        let mut radius = vec![lambda; n];
        radius[n / 2] = 0.0;
        let mut center = s.clone();
        center[n / 2] = 0.0;
        bench(&format!("qp masked n={n}"), BenchConfig::default(), || {
            solve_masked(&y, &center, &radius, Some(n / 2), opts, &mut u, &mut w).r_squared
        });
    }
}
