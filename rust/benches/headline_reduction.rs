//! E5 — §4 headline claims:
//!   (a) at λ values targeting cardinality ≈ 5, safe elimination shrinks
//!       the problem ~150–200× (102,660 → ≤500 for NYTimes);
//!   (b) one sparse PC takes ~20 s end-to-end after pre-processing
//!       (2011 laptop; we report this testbed's number).
//!
//! Also prints the λ → n̂ reduction curve at several λ percentiles.

use lsspca::config::PipelineConfig;
use lsspca::coordinator::{choose_elimination, Pipeline};
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::elim::lambda_survivor_curve;
use lsspca::stream::{variance_pass, StreamOptions, SynthSource};
use lsspca::util::bench::{metric, section};

fn main() {
    // Scale note: the paper's NYTimes is 300k×102,660. The synthetic
    // substitute runs 50k×30,000 on this 1-core container; reduction
    // factors are reported relative to each vocabulary.
    let (docs, vocab) = (50_000, 30_000);
    section(&format!("E5 headline — nytimes-like {docs}×{vocab}"));
    let spec = CorpusSpec::nytimes().scaled(docs, vocab);
    let corpus = SynthCorpus::new(spec, 20111212);
    let opts = StreamOptions { workers: 2, chunk_docs: 2048, queue_depth: 4 };
    let (fv, stats) = variance_pass(&mut SynthSource::new(&corpus), opts).unwrap();
    metric("variance_pass_seconds", format!("{:.2}", stats.seconds));

    // (a) reduction at the cardinality-5 elimination threshold
    let (elim, capped) = choose_elimination(&fv, 5, 512);
    metric("reduced_size", elim.reduced());
    metric("reduction_factor", format!("{:.0}", elim.reduction_factor()));
    metric("reduction_capped", capped);
    println!("lambda → n̂ curve:");
    let sv = fv.sorted_variances();
    let lambdas: Vec<f64> = [2usize, 10, 50, 100, 200, 500, 1000, 5000]
        .iter()
        .filter(|&&k| k < sv.len())
        .map(|&k| sv[k])
        .collect();
    for (lam, kept) in lambda_survivor_curve(&fv.variance, &lambdas) {
        println!(
            "  λ={lam:10.4}  n̂={kept:>6}  reduction ×{:.0}",
            vocab as f64 / kept.max(1) as f64
        );
    }

    // (b) per-PC end-to-end time (the paper's ~20 s claim)
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: docs,
        synth_vocab: vocab,
        num_pcs: 3,
        target_card: 5,
        card_slack: 2,
        max_reduced: 512,
        workers: 2,
        ..Default::default()
    };
    let report = Pipeline::new(cfg).run().expect("pipeline");
    for (k, c) in report.components.iter().enumerate() {
        metric(
            &format!("pc{}.solve_seconds", k + 1),
            format!("{:.2} (card={})", c.seconds, c.pc.cardinality()),
        );
    }
    let mean: f64 =
        report.components.iter().map(|c| c.seconds).sum::<f64>() / report.components.len() as f64;
    metric("mean_per_pc_seconds", format!("{mean:.2} (paper: ~20 s, 2011 laptop)"));
    metric("pipeline_total_seconds", format!("{:.2}", report.total_seconds));
}
