//! E2 — paper Fig 2: sorted word variances of the two corpora, plus the
//! streamed moment-pass throughput at several worker counts.

use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::stream::{variance_pass, StreamOptions, SynthSource};
use lsspca::util::bench::{metric, section};

fn profile(preset: &str, docs: usize, vocab: usize) {
    section(&format!("Fig2 {preset} ({docs} docs × {vocab} words)"));
    let spec = CorpusSpec::preset(preset).unwrap().scaled(docs, vocab);
    let corpus = SynthCorpus::new(spec, 20111212);
    // throughput at 1/2/4 workers (backpressure pipeline)
    for workers in [1usize, 2, 4] {
        let opts = StreamOptions { workers, chunk_docs: 2048, queue_depth: 4 };
        let (fv, stats) = variance_pass(&mut SynthSource::new(&corpus), opts).unwrap();
        metric(
            &format!("{preset}.pass_seconds.workers{workers}"),
            format!("{:.3}", stats.seconds),
        );
        metric(
            &format!("{preset}.nnz_per_sec.workers{workers}"),
            format!("{:.0}", stats.nnz as f64 / stats.seconds),
        );
        if workers == 1 {
            let sv = fv.sorted_variances();
            // decimated Fig-2 series
            println!("series {preset}.sorted_variances: rank,variance");
            let step = (sv.len() / 40).max(1);
            for (i, v) in sv.iter().enumerate().step_by(step) {
                if *v > 0.0 {
                    println!("  {},{v:.6e}", i + 1);
                }
            }
            let mid = sv[sv.len() / 2].max(1e-300);
            metric(&format!("{preset}.top_variance"), format!("{:.4}", sv[0]));
            metric(
                &format!("{preset}.decay_decades_to_median"),
                format!("{:.2}", (sv[0] / mid).log10()),
            );
        }
    }
}

fn main() {
    profile("nytimes", 20_000, 30_000);
    profile("pubmed", 20_000, 40_000);
}
