//! E9 (extension) — support-recovery phase transition on the spiked model.
//!
//! The paper motivates DSPCA's statistical side via Amini & Wainwright [2]
//! (ref [2], "statistical regularization when samples < features"): sparse
//! PCA recovers a k-sparse spike once the sample count crosses a threshold
//! scaling like k·log n. This bench sweeps the sample count m and reports
//! the empirical recovery rate of DSPCA vs the thresholding baseline —
//! DSPCA's transition happens earlier, which is the quantitative form of
//! "the SDP relaxation beats ad-hoc methods".

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::solver::bca::BcaOptions;
use lsspca::solver::lambda::{search, LambdaSearchOptions};
use lsspca::solver::threshold::thresholded_pc;
use lsspca::util::bench::{metric, section};
use lsspca::util::rng::Rng;

fn recovery_rate(n: usize, card: usize, m: usize, snr: f64, trials: usize) -> (f64, f64) {
    let mut rng = Rng::seed_from(0xE9 ^ (m as u64) << 8);
    let (mut hits_dspca, mut hits_thresh) = (0usize, 0usize);
    for _ in 0..trials {
        let (sigma, u) = spiked_covariance_with_u(n, m, card, snr, &mut rng);
        let planted = lsspca::linalg::vec::support(&u, 1e-9);
        // DSPCA via λ-search to the planted cardinality
        let res = search(
            &sigma,
            &LambdaSearchOptions {
                target_card: card,
                slack: 0,
                max_evals: 10,
                bca: BcaOptions { max_sweeps: 10, track_history: false, ..Default::default() },
                ..Default::default()
            },
        );
        let exact_dspca = {
            let mut s = res.pc.support.clone();
            s.sort_unstable();
            s == planted
        };
        let thr = thresholded_pc(&sigma, card);
        let exact_thr = {
            let mut s = thr.support.clone();
            s.sort_unstable();
            s == planted
        };
        hits_dspca += exact_dspca as usize;
        hits_thresh += exact_thr as usize;
    }
    (
        hits_dspca as f64 / trials as f64,
        hits_thresh as f64 / trials as f64,
    )
}

fn main() {
    let (n, card, snr, trials) = (60usize, 5usize, 1.5f64, 8usize);
    section(&format!(
        "E9 — exact support recovery vs samples m (spiked n={n}, card={card}, snr={snr})"
    ));
    println!("series recovery: m,dspca_rate,threshold_rate");
    let mut crossed_dspca = None;
    let mut crossed_thr = None;
    for &m in &[5usize, 10, 20, 40, 80, 160, 320] {
        let (rd, rt) = recovery_rate(n, card, m, snr, trials);
        println!("  {m},{rd:.2},{rt:.2}");
        if rd >= 0.75 && crossed_dspca.is_none() {
            crossed_dspca = Some(m);
        }
        if rt >= 0.75 && crossed_thr.is_none() {
            crossed_thr = Some(m);
        }
    }
    metric(
        "m_at_75pct_recovery.dspca",
        crossed_dspca.map_or("not reached".into(), |m| m.to_string()),
    );
    metric(
        "m_at_75pct_recovery.threshold",
        crossed_thr.map_or("not reached".into(), |m| m.to_string()),
    );
}
