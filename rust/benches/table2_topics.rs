//! E4 — paper Table 2: top-5 sparse PCs on the PubMed-like corpus.
//! Shares the recovery-scoring harness with table1_topics.

#[path = "table1_topics.rs"]
mod table1;

fn main() {
    table1::run_preset("pubmed", 20_000, 40_000);
}
