//! E8 — engine comparison: native Rust vs AOT/XLA artifacts (the L2 JAX
//! graph calling the L1 Pallas kernel, executed through PJRT).
//!
//! Checks numerical agreement sweep-by-sweep, then races full solves.
//! Requires `make artifacts`; skips gracefully when they are missing.

#[cfg(feature = "xla")]
use std::path::PathBuf;

#[cfg(feature = "xla")]
use lsspca::corpus::models::spiked_covariance_with_u;
#[cfg(feature = "xla")]
use lsspca::data::SymMat;
#[cfg(feature = "xla")]
use lsspca::engine::{bca_solve, Engine, NativeEngine, XlaEngine};
#[cfg(feature = "xla")]
use lsspca::solver::bca::BcaOptions;
#[cfg(feature = "xla")]
use lsspca::util::bench::{bench, metric, section, BenchConfig};
#[cfg(feature = "xla")]
use lsspca::util::rng::Rng;

#[cfg(feature = "xla")]
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join(".stamp").exists().then_some(dir)
}

#[cfg(not(feature = "xla"))]
fn main() {
    println!("SKIP engines bench: built without the `xla` feature");
}

#[cfg(feature = "xla")]
fn main() {
    let Some(dir) = artifacts_dir() else {
        println!("SKIP engines bench: run `make artifacts` first");
        return;
    };
    let mut xla = match XlaEngine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP engines bench: {e}");
            return;
        }
    };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(77);

    section("E8 — sweep-level agreement (native vs xla, matched budgets)");
    for &n in &[24usize, 60, 120] {
        let (sigma, _) = spiked_covariance_with_u(n, 2 * n, (n / 8).max(2), 2.0, &mut rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, n / 2);
        let opts = BcaOptions::default();
        let mopts = XlaEngine::matching_native_opts(&opts);
        let beta = opts.epsilon / n as f64;
        let mut xn = SymMat::identity(n);
        let mut xx = SymMat::identity(n);
        let mut worst = 0.0f64;
        for _ in 0..3 {
            native.bca_sweep(&mut xn, &sigma, lambda, beta, &mopts).unwrap();
            xla.bca_sweep(&mut xx, &sigma, lambda, beta, &mopts).unwrap();
            for i in 0..n {
                for j in 0..n {
                    worst = worst.max((xn.get(i, j) - xx.get(i, j)).abs());
                }
            }
        }
        metric(&format!("agreement.n{n}.max_abs_diff_3sweeps"), format!("{worst:.2e}"));
        assert!(
            worst < 1e-4,
            "native/xla diverged at n={n}: {worst}"
        );
    }

    section("E8 — full-solve race");
    for &n in &[60usize, 120, 250] {
        let (sigma, _) = spiked_covariance_with_u(n, 2 * n, (n / 8).max(2), 2.0, &mut rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, n / 3);
        let opts = BcaOptions { max_sweeps: 5, track_history: false, ..Default::default() };
        let rn = bench(&format!("native solve n={n} (5 sweeps)"), BenchConfig::slow(), || {
            bca_solve(&mut native, &sigma, lambda, &opts).unwrap().phi
        });
        let rx = bench(&format!("xla    solve n={n} (5 sweeps)"), BenchConfig::slow(), || {
            bca_solve(&mut xla, &sigma, lambda, &opts).unwrap().phi
        });
        metric(
            &format!("race.n{n}.native_over_xla"),
            format!("{:.2}x", rx.summary.p50 / rn.summary.p50),
        );
        let phi_n = bca_solve(&mut native, &sigma, lambda, &opts).unwrap().phi;
        let phi_x = bca_solve(&mut xla, &sigma, lambda, &opts).unwrap().phi;
        metric(
            &format!("race.n{n}.phi_agreement"),
            format!("|Δφ|={:.2e}", (phi_n - phi_x).abs()),
        );
    }

    section("E8 — power-iteration artifact agreement");
    for &n in &[30usize, 100] {
        let (sigma, _) = spiked_covariance_with_u(n, 2 * n, 3, 4.0, &mut rng);
        let v0 = rng.gauss_vec(n);
        let (vn, valn) = native.power_iter(&sigma, &v0).unwrap();
        let (vx, valx) = xla.power_iter(&sigma, &v0).unwrap();
        let align: f64 = vn.iter().zip(&vx).map(|(a, b)| a * b).sum::<f64>().abs();
        metric(
            &format!("power.n{n}"),
            format!("|Δλ|={:.2e} alignment={:.6}", (valn - valx).abs(), align),
        );
        assert!((valn - valx).abs() < 1e-6 * (1.0 + valn.abs()));
    }

    section("E8 — gram artifact (Pallas blocked matmul) agreement + rate");
    let (m, k) = (1000usize, 300usize);
    let data: Vec<f64> = (0..m * k).map(|_| rng.gauss()).collect();
    let g_native = native.gram(m, k, &data).unwrap();
    let g_xla = xla.gram(m, k, &data).unwrap();
    let mut worst = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            worst = worst.max((g_native.get(i, j) - g_xla.get(i, j)).abs());
        }
    }
    metric("gram.max_abs_diff", format!("{worst:.2e}"));
    assert!(worst < 1e-8);
    bench("gram native 1000x300", BenchConfig::default(), || {
        native.gram(m, k, &data).unwrap().trace()
    });
    bench("gram xla    1000x300", BenchConfig::default(), || {
        xla.gram(m, k, &data).unwrap().trace()
    });
}
