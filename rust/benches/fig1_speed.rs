//! E1a/E1b — paper Fig 1: convergence speed, BCA vs first-order DSPCA.
//!
//! Regenerates both panels: objective-vs-time series on (a) Σ = FᵀF with
//! Gaussian F and (b) the spiked model, plus the time-to-99%-of-best
//! speedup factor. The paper's claim is the *shape*: BCA reaches the
//! optimum orders of magnitude sooner.

use lsspca::corpus::models::{gaussian_factor_cov, spiked_covariance_with_u};
use lsspca::data::SymMat;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::first_order::{self, FirstOrderOptions};
use lsspca::util::bench::{metric, section};
use lsspca::util::rng::Rng;

fn panel(label: &str, sigma: &SymMat, lambda: f64) {
    section(&format!("Fig1 {label} (n={}, λ={lambda:.3})", sigma.n()));
    let b = bca::solve(
        sigma,
        lambda,
        &BcaOptions { max_sweeps: 15, epsilon: 1e-3, tol: 1e-10, ..Default::default() },
    );
    let f = first_order::solve(
        sigma,
        lambda,
        &FirstOrderOptions { max_iters: 4000, epsilon: 5e-2, gap_tol: 1e-4, ..Default::default() },
    );
    metric(&format!("{label}.bca.phi"), format!("{:.6}", b.phi));
    metric(&format!("{label}.bca.seconds"), format!("{:.4}", b.seconds));
    metric(&format!("{label}.first_order.phi"), format!("{:.6}", f.phi));
    metric(&format!("{label}.first_order.seconds"), format!("{:.4}", f.seconds));
    // the Fig-1 series, as CSV rows in the bench log
    println!("series {label}.bca: t,objective");
    for h in &b.history {
        println!("  {:.5},{:.6}", h.seconds, h.objective);
    }
    println!("series {label}.first_order: t,objective (every 10th)");
    for (it, obj, secs) in f.history.iter().step_by(10) {
        println!("  {secs:.5},{obj:.6}  # iter {it}");
    }
    let target = 0.99 * b.phi.max(f.phi);
    let t_b = b
        .history
        .iter()
        .find(|h| h.objective >= target)
        .map(|h| h.seconds);
    let t_f = f
        .history
        .iter()
        .find(|&&(_, o, _)| o >= target)
        .map(|&(_, _, s)| s);
    match (t_b, t_f) {
        (Some(tb), Some(tf)) => {
            metric(&format!("{label}.speedup_at_99pct"), format!("{:.1}", tf / tb.max(1e-9)));
        }
        (Some(tb), None) => {
            metric(
                &format!("{label}.speedup_at_99pct"),
                format!(">{:.1} (first-order never reached target)", f.seconds / tb.max(1e-9)),
            );
        }
        _ => metric(&format!("{label}.speedup_at_99pct"), "n/a"),
    }
}

fn main() {
    let mut rng = Rng::seed_from(20111212);
    for &n in &[40usize, 80] {
        let m = n / 2;
        let sigma = gaussian_factor_cov(n, m, &mut rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, 3 * n / 4);
        panel(&format!("gaussian_n{n}"), &sigma, lambda);

        let (sigma, _) = spiked_covariance_with_u(n, m, (n / 10).max(2), 1.5, &mut rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, 3 * n / 4);
        panel(&format!("spiked_n{n}"), &sigma, lambda);
    }
}
