//! E6 — §3 complexity: one BCA sweep is O(n²) per column, O(n³) total.
//! Times a sweep across n and fits the exponent of t(n) = a·n^b; the
//! paper's claim holds if b ≈ 3 (and the first-order method's per-iteration
//! eigendecomposition shows its heavier scaling).

use lsspca::corpus::models::gaussian_factor_cov;
use lsspca::linalg::eig::JacobiEig;
use lsspca::solver::bca::{sweep, BcaOptions, SweepBuffers};
use lsspca::util::bench::{bench, metric, section, BenchConfig};
use lsspca::util::rng::Rng;
use lsspca::util::stats::linfit;

fn main() {
    section("E6 — BCA sweep time vs n (fit exponent)");
    let mut rng = Rng::seed_from(7);
    let sizes = [50usize, 100, 200, 400];
    let mut pts = Vec::new();
    for &n in &sizes {
        let sigma = gaussian_factor_cov(n, n / 2, &mut rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, n / 2);
        let opts = BcaOptions::default();
        let beta = opts.epsilon / n as f64;
        let mut x = lsspca::data::SymMat::identity(n);
        let mut buf = SweepBuffers::new(n);
        // measure a mid-flight sweep (first sweep does extra support churn)
        sweep(&mut x, &sigma, lambda, beta, &opts, &mut buf);
        let r = bench(
            &format!("bca_sweep n={n}"),
            BenchConfig { max_seconds: 4.0, ..Default::default() },
            || {
                let mut xc = x.clone();
                sweep(&mut xc, &sigma, lambda, beta, &opts, &mut buf)
            },
        );
        pts.push(((n as f64).ln(), r.summary.p50.ln()));
    }
    let (_, b) = linfit(
        &pts.iter().map(|p| p.0).collect::<Vec<_>>(),
        &pts.iter().map(|p| p.1).collect::<Vec<_>>(),
    );
    metric("bca_sweep_exponent", format!("{b:.2} (paper: 3)"));

    section("E6 — first-order per-iteration (eigendecomposition) vs n");
    let mut pts = Vec::new();
    for &n in &[50usize, 100, 200] {
        let sigma = gaussian_factor_cov(n, n / 2, &mut rng);
        let r = bench(
            &format!("jacobi_eig n={n}"),
            BenchConfig { max_seconds: 4.0, ..Default::default() },
            || JacobiEig::new(&sigma).lambda_max(),
        );
        pts.push(((n as f64).ln(), r.summary.p50.ln()));
    }
    let (_, b) = linfit(
        &pts.iter().map(|p| p.0).collect::<Vec<_>>(),
        &pts.iter().map(|p| p.1).collect::<Vec<_>>(),
    );
    metric("first_order_periter_exponent", format!("{b:.2} (≥3; ×O(1/ε) iterations)"));
}
