//! Worker-process side of the distributed corpus pass.
//!
//! A worker is the same `lsspca` binary re-executed with the hidden
//! `worker --manifest <path> --shard <index>` subcommand. It loads the
//! [`crate::jobstate::DistManifest`], recomputes the shard plan (a pure
//! function of the manifest, so coordinator and worker always agree on
//! boundaries), reopens the corpus stream from the manifest's
//! [`crate::jobstate::CorpusSource`], and folds its shard's chunks into
//! per-chunk accumulator blocks appended to the shard's `.part` file.
//! The atomic rename in [`crate::dist::shardio::ShardWriter::finish`] is
//! the shard's commit point.
//!
//! Determinism: the worker streams its chunks **sequentially** (no
//! in-process thread pool) into one fresh accumulator per chunk —
//! exactly the per-chunk arithmetic of
//! [`crate::stream::resumable_variance_pass`], so the coordinator's
//! strict chunk-order merge replays the single-process f64 sequence bit
//! for bit.
//!
//! Crash safety: a SIGKILLed worker leaves a `.part` file whose longest
//! valid block prefix is resumed on the next launch (torn tail
//! truncated, completed chunks never re-folded). Alongside it the worker
//! maintains a per-shard `.lsjs` job-state snapshot — for variance
//! shards a genuine [`crate::jobstate::JobState`] of the shard's partial
//! accumulator, for reduce shards a progress-only marker — which is both
//! operator-visible progress and the write the fault suite's
//! `wkill:jobstate@…` scripts kill workers through. Malformed records go
//! to a per-shard dead-letter file the coordinator later merges with
//! offset dedup.

use std::path::{Path, PathBuf};

use crate::corpus::{CorpusSpec, SynthCorpus};
use crate::cov::{reduced_lookup_from_kept, ReducedDocsAccum};
use crate::deadletter::{DeadLetterQueue, RecordPolicy};
use crate::dist::plan::{plan_shards, ShardRange};
use crate::dist::shardio::{self, BlockPayload, ShardBlock, ShardHeader, ShardWriter};
use crate::error::LsspcaError;
use crate::jobstate::{self, CorpusSource, DistManifest, JobState, KIND_REDUCE, KIND_VARIANCE};
use crate::moments::FeatureMoments;
use crate::stream::{ChunkSource, FileSource, SynthSource};

/// Per-shard dead-letter file: the main queue path with `_shard<i>`
/// spliced in before the extension, so shard spills sit next to the
/// merged queue and match the CI artifact globs.
pub fn shard_dlq_path(main: &Path, shard: usize) -> PathBuf {
    match main.extension() {
        Some(ext) => {
            let stem = main.with_extension("");
            let mut name = stem.file_name().unwrap_or_default().to_os_string();
            name.push(format!("_shard{shard}."));
            name.push(ext);
            stem.with_file_name(name)
        }
        None => main.with_file_name({
            let mut name = main.file_name().unwrap_or_default().to_os_string();
            name.push(format!("_shard{shard}"));
            name
        }),
    }
}

/// Per-shard job-state path: a shard-scoped corpus key keeps it distinct
/// from the single-process `jobstate_*.lsjs` of the same corpus.
pub fn shard_jobstate_path(cache_dir: &Path, m: &DistManifest, shard: usize) -> PathBuf {
    let key = crate::checkpoint::corpus_key(&format!(
        "{:016x}:dist:{}:{}",
        m.key, m.kind, shard
    ));
    jobstate::path_for(cache_dir, key)
}

/// The shard-file identity header a manifest implies for one shard.
pub fn shard_header(m: &DistManifest, range: &ShardRange) -> ShardHeader {
    let n = if m.kind == KIND_REDUCE { m.kept.len() as u64 } else { m.n };
    ShardHeader {
        key: m.key,
        kind: m.kind,
        shard_index: range.index as u64,
        chunk_docs: m.chunk_docs,
        chunk_start: range.chunk_start,
        n,
    }
}

/// Resolve the manifest's shard table entry to a chunk range.
fn shard_range(m: &DistManifest, shard: usize) -> Result<ShardRange, LsspcaError> {
    let plan = plan_shards(m.num_docs, m.chunk_docs, m.shard_docs);
    if plan.len() != m.shards.len() {
        return Err(LsspcaError::corpus(format!(
            "dist manifest shard table ({}) disagrees with the recomputed plan ({})",
            m.shards.len(),
            plan.len()
        )));
    }
    plan.get(shard).copied().ok_or_else(|| {
        LsspcaError::corpus(format!("shard index {shard} out of range (plan has {})", plan.len()))
    })
}

/// The corpus stream a worker folds: either a rebuilt synthetic
/// generator or the docword file, with the skip-ahead already applied.
enum WorkerSource<'a> {
    Synth(SynthSource<'a>),
    File(FileSource),
}

impl WorkerSource<'_> {
    fn next_chunk(
        &mut self,
        max_docs: usize,
    ) -> Result<Option<crate::data::docword::DocChunk>, LsspcaError> {
        match self {
            WorkerSource::Synth(s) => s.next_chunk(max_docs),
            WorkerSource::File(s) => s.next_chunk(max_docs),
        }
    }
}

/// Run one shard to completion (idempotent: returns immediately when the
/// shard's final result file is already committed and valid).
pub fn run_worker(manifest_path: &Path, shard: usize) -> Result<(), LsspcaError> {
    let m = jobstate::load_dist(manifest_path)?.ok_or_else(|| {
        LsspcaError::corpus(format!("dist manifest not found: {}", manifest_path.display()))
    })?;
    let cache_dir = manifest_path
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));
    let range = shard_range(&m, shard)?;
    let hdr = shard_header(&m, &range);
    let final_path = shardio::result_path(&cache_dir, m.key, m.kind, shard);
    if shardio::read_complete(&final_path, &hdr)?.is_some() {
        return Ok(()); // an earlier attempt committed; nothing to redo
    }

    // Pre-scan the `.part` prefix so the variance shard-master can be
    // rebuilt to exactly the state the killed attempt had reached
    // (create_or_resume re-scans and truncates the torn tail itself).
    let part = shardio::part_path(&cache_dir, m.key, m.kind, shard);
    let prior = shardio::scan(&part, &hdr)?;
    let (mut writer, done) = ShardWriter::create_or_resume(&cache_dir, &hdr)?;
    debug_assert_eq!(done, prior.blocks.len() as u64);
    let chunk_docs = m.chunk_docs as usize;
    let skip_chunks = range.chunk_start + done;

    // Rebuild the corpus stream and position it at the first chunk this
    // attempt still owes. The synthetic generator is position-seeded, so
    // it jumps straight there; a file re-reads and discards the prefix
    // (gzip cannot seek), quarantining any malformed prefix records into
    // this shard's dead-letter file — the coordinator's offset-dedup
    // merge collapses the cross-worker duplicates that creates.
    let corpus_holder; // owns the SynthCorpus the source borrows
    let mut source = match &m.source {
        CorpusSource::Synth { preset, docs, vocab, seed } => {
            let spec = CorpusSpec::preset(preset)
                .ok_or_else(|| {
                    LsspcaError::corpus(format!("dist manifest names unknown preset {preset:?}"))
                })?
                .scaled(*docs as usize, *vocab as usize);
            corpus_holder = SynthCorpus::new(spec, *seed);
            WorkerSource::Synth(SynthSource::starting_at(
                &corpus_holder,
                skip_chunks * m.chunk_docs,
            ))
        }
        CorpusSource::File { path } => {
            let path = Path::new(path);
            let policy = if m.max_bad_records > 0 && !m.dead_letter.is_empty() {
                let dlq_path = shard_dlq_path(Path::new(&m.dead_letter), shard);
                Some(RecordPolicy::new(m.max_bad_records, DeadLetterQueue::open(&dlq_path)?))
            } else {
                None
            };
            let mut src = FileSource::open_with_policy(path, policy)?;
            if src.header().vocab_size as u64 != m.n {
                return Err(LsspcaError::corpus(format!(
                    "docword vocabulary {} disagrees with the dist manifest ({})",
                    src.header().vocab_size,
                    m.n
                )));
            }
            for _ in 0..skip_chunks {
                if src.next_chunk(chunk_docs)?.is_none() {
                    return Err(LsspcaError::corpus(
                        "corpus ended before this shard's range — stale dist manifest",
                    ));
                }
            }
            WorkerSource::File(src)
        }
    };

    // Kept-feature lookup for the reduce kind (full → reduced index).
    let lookup = if m.kind == KIND_REDUCE {
        reduced_lookup_from_kept(&m.kept, m.n as usize)
    } else {
        Vec::new()
    };

    // Shard-local master (variance kind): merged in chunk order so the
    // job-state snapshot is a genuine resumable accumulator — including
    // the chunks a killed earlier attempt already committed.
    let mut shard_master =
        FeatureMoments::new(if m.kind == KIND_VARIANCE { m.n as usize } else { 0 });
    if m.kind == KIND_VARIANCE {
        for block in &prior.blocks {
            shard_master.merge(&super::block_moments(block, m.n as usize));
        }
    }
    let js_path = shard_jobstate_path(&cache_dir, &m, shard);

    for chunk_index in skip_chunks..range.chunk_end {
        let chunk = source.next_chunk(chunk_docs)?.ok_or_else(|| {
            LsspcaError::corpus("corpus ended inside this shard's range — stale dist manifest")
        })?;
        let block = match m.kind {
            KIND_VARIANCE => {
                let mut acc = FeatureMoments::new(m.n as usize);
                acc.push_chunk(&chunk);
                let feats: Vec<(u32, crate::util::stats::RunningStats)> = acc
                    .stats()
                    .iter()
                    .enumerate()
                    .filter(|(_, st)| st.n > 0)
                    .map(|(f, st)| (f as u32, *st))
                    .collect();
                let block = ShardBlock {
                    chunk_index,
                    docs: acc.docs,
                    nnz: acc.nnz,
                    payload: BlockPayload::Variance { feats },
                };
                shard_master.merge(&acc);
                block
            }
            KIND_REDUCE => {
                let mut acc = ReducedDocsAccum::new();
                for doc in &chunk.docs {
                    acc.push_doc(doc.id as u64, &doc.words, &lookup);
                }
                let (doc_ids, doc_ptr, idx, val) = acc.into_parts();
                ShardBlock {
                    chunk_index,
                    docs: chunk.docs.len() as u64,
                    nnz: chunk.total_nnz() as u64,
                    payload: BlockPayload::Reduce { doc_ids, doc_ptr, idx, val },
                }
            }
            k => return Err(LsspcaError::corpus(format!("unknown dist pass kind {k}"))),
        };
        writer.append(&block)?;
        // Progress snapshot after every durable block. The `.part` prefix
        // is the authoritative resume source; this file is the operator-
        // visible breadcrumb and the `wkill:jobstate@…` kill point.
        jobstate::save(
            &js_path,
            &JobState {
                key: m.key,
                kind: m.kind,
                chunk_docs: m.chunk_docs,
                completed_chunks: chunk_index + 1,
                moments: shard_master.clone(),
            },
        )?;
    }

    writer.finish()?;
    jobstate::remove(&js_path)
        .map_err(|e| LsspcaError::io_at(&js_path, format!("remove shard job state: {e}")))?;
    Ok(())
}
