//! Shard partitioner for the distributed corpus pass.
//!
//! Shards are expressed in **observed-document ordinals** (the k-th
//! document the streaming reader materializes, not the file's declared
//! doc id) and are always aligned to `chunk_docs` multiples. That
//! alignment is the determinism keystone: the chunks a shard's worker
//! folds are *exactly* the chunks the single-process resumable pass
//! would have folded at the same global chunk indices, so the
//! coordinator can replay the single-process merge order bit for bit.
//!
//! Invariants (pinned by the property tests below):
//! - every chunk index in `[0, ceil(num_docs / chunk_docs))` belongs to
//!   exactly one shard,
//! - shard boundaries fall on chunk boundaries, so a document is never
//!   split across shards,
//! - the plan is a pure function of `(num_docs, chunk_docs, shard_docs)`
//!   — worker count and completion order never change it.

/// One shard: a contiguous run of global chunk indices and the
/// observed-document ordinals they cover.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    /// Position in the shard table (merge order).
    pub index: usize,
    /// First global chunk index (inclusive).
    pub chunk_start: u64,
    /// Past-the-end global chunk index.
    pub chunk_end: u64,
    /// First observed-document ordinal (inclusive).
    pub doc_start: u64,
    /// Past-the-end observed-document ordinal (clamped to `num_docs`).
    pub doc_end: u64,
}

impl ShardRange {
    /// Chunks this shard covers.
    pub fn num_chunks(&self) -> u64 {
        self.chunk_end - self.chunk_start
    }
}

/// Effective shard size in documents: the configured `shard_docs`
/// (0 = auto, eight chunks) rounded **up** to a `chunk_docs` multiple.
pub fn effective_shard_docs(chunk_docs: u64, shard_docs: u64) -> u64 {
    let auto = 8 * chunk_docs;
    let want = if shard_docs == 0 { auto } else { shard_docs };
    want.div_ceil(chunk_docs).max(1) * chunk_docs
}

/// Partition a corpus of `num_docs` observed documents into chunk-aligned
/// shards. Always returns at least one shard (possibly empty, when
/// `num_docs == 0`), so the coordinator's shard table is never empty.
pub fn plan_shards(num_docs: u64, chunk_docs: u64, shard_docs: u64) -> Vec<ShardRange> {
    assert!(chunk_docs >= 1, "chunk_docs must be >= 1");
    let eff = effective_shard_docs(chunk_docs, shard_docs);
    let chunks_per_shard = eff / chunk_docs;
    let num_chunks = num_docs.div_ceil(chunk_docs);
    let num_shards = num_chunks.div_ceil(chunks_per_shard).max(1);
    (0..num_shards)
        .map(|s| {
            let chunk_start = s * chunks_per_shard;
            let chunk_end = ((s + 1) * chunks_per_shard).min(num_chunks);
            ShardRange {
                index: s as usize,
                chunk_start,
                chunk_end,
                doc_start: (chunk_start * chunk_docs).min(num_docs),
                doc_end: (chunk_end * chunk_docs).min(num_docs),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;

    #[test]
    fn exact_cover_small_cases() {
        // 10 docs, chunks of 4 → chunks [0,3); shard_docs 5 rounds up to 8
        // (2 chunks) → shards {[0,2), [2,3)}.
        let p = plan_shards(10, 4, 5);
        assert_eq!(p.len(), 2);
        assert_eq!((p[0].chunk_start, p[0].chunk_end), (0, 2));
        assert_eq!((p[0].doc_start, p[0].doc_end), (0, 8));
        assert_eq!((p[1].chunk_start, p[1].chunk_end), (2, 3));
        assert_eq!((p[1].doc_start, p[1].doc_end), (8, 10));
    }

    #[test]
    fn zero_docs_yields_one_empty_shard() {
        let p = plan_shards(0, 64, 0);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].num_chunks(), 0);
        assert_eq!((p[0].doc_start, p[0].doc_end), (0, 0));
    }

    #[test]
    fn auto_shard_docs_is_eight_chunks() {
        assert_eq!(effective_shard_docs(64, 0), 512);
        assert_eq!(effective_shard_docs(64, 1), 64);
        assert_eq!(effective_shard_docs(64, 65), 128);
        assert_eq!(effective_shard_docs(64, 128), 128);
    }

    #[test]
    fn prop_every_doc_covered_exactly_once() {
        property("shard plan covers every doc exactly once", 50, |rng| {
            let num_docs = rng.below(2000) as u64;
            let chunk_docs = (1 + rng.below(128)) as u64;
            let shard_docs = rng.below(512) as u64;
            let plan = plan_shards(num_docs, chunk_docs, shard_docs);
            // doc ranges tile [0, num_docs) in order with no gap/overlap
            let mut next = 0u64;
            for s in &plan {
                if s.doc_start != next {
                    return Err(format!(
                        "gap/overlap at shard {}: {} != {next}",
                        s.index, s.doc_start
                    ));
                }
                if s.doc_end < s.doc_start {
                    return Err(format!("inverted shard {}", s.index));
                }
                next = s.doc_end;
            }
            if next != num_docs {
                return Err(format!("plan ends at {next}, want {num_docs}"));
            }
            // chunk ranges tile the global chunk index space the same way
            let mut next_chunk = 0u64;
            for s in &plan {
                if s.chunk_start != next_chunk {
                    return Err(format!("chunk gap at shard {}", s.index));
                }
                next_chunk = s.chunk_end;
            }
            if next_chunk != num_docs.div_ceil(chunk_docs) {
                return Err("chunk cover incomplete".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_boundaries_never_split_a_document() {
        property("shard boundaries land on chunk boundaries", 50, |rng| {
            let num_docs = (1 + rng.below(3000)) as u64;
            let chunk_docs = (1 + rng.below(200)) as u64;
            let shard_docs = rng.below(1000) as u64;
            for s in plan_shards(num_docs, chunk_docs, shard_docs) {
                // every shard start is a chunk multiple; a document lives
                // entirely inside one chunk, so it cannot straddle shards
                if s.doc_start % chunk_docs != 0 {
                    return Err(format!("shard {} starts mid-chunk at {}", s.index, s.doc_start));
                }
                if s.doc_start != s.chunk_start * chunk_docs {
                    return Err(format!("shard {} doc/chunk start disagree", s.index));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_plan_independent_of_worker_count() {
        // The plan has no worker-count input at all; pin that the merge
        // order (shard index order) reconstructs the identity permutation
        // regardless of any completion order a scheduler could produce.
        property("merge order independent of completion order", 30, |rng| {
            let num_docs = (1 + rng.below(2000)) as u64;
            let chunk_docs = (1 + rng.below(100)) as u64;
            let plan = plan_shards(num_docs, chunk_docs, rng.below(700) as u64);
            // simulate an arbitrary completion order
            let mut order: Vec<usize> = (0..plan.len()).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, rng.below(i + 1));
            }
            // merging by shard index (not completion order) restores the
            // global chunk sequence
            let mut merged: Vec<(usize, u64, u64)> = order
                .iter()
                .map(|&i| (plan[i].index, plan[i].chunk_start, plan[i].chunk_end))
                .collect();
            merged.sort_unstable_by_key(|&(idx, _, _)| idx);
            let mut next = 0u64;
            for (_, start, end) in merged {
                if start != next {
                    return Err(format!("merge order broke the chunk sequence at {start}"));
                }
                next = end;
            }
            Ok(())
        });
    }
}
