//! Per-shard result files for the distributed corpus pass.
//!
//! A worker appends one self-checksummed **block per corpus chunk** to a
//! `.part` file and atomically renames it to the final `.lsds` name when
//! its doc range is exhausted — the rename is the shard's commit point.
//! Storing per-chunk blocks (not a per-shard merged accumulator) is what
//! lets the coordinator replay the *single-process* merge order exactly:
//! Welford merges are not associative in floating point, so the merged
//! result is only bitwise-reproducible if the coordinator folds chunk
//! accumulators in ascending global chunk index, precisely as
//! [`crate::stream::resumable_variance_pass`] does.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "LSDS" | u32 version | u64×6 header (key kind shard chunk_docs
//!                                      chunk_start n) | u64 hdr checksum
//! repeated blocks:
//!   u64 payload_len | payload | u64 xor-fold checksum of payload
//! payload = u64 chunk_index, u64 docs, u64 nnz, then per kind:
//!   variance: u64 k, k × (u32 feature, u64 n, f64 mean, f64 m2)
//!             (only features with n > 0 — merging an empty Welford
//!              triple is an exact no-op, so sparsity is free)
//!   reduce:   u64 rows, u64 rnnz, rows × u64 doc_id, rows × u64 row_end,
//!             rnnz × u32 col, rnnz × f64 val
//! ```
//!
//! A truncated or torn tail never corrupts a shard: readers accept the
//! longest valid block prefix ([`scan`]), and a resuming worker truncates
//! to that prefix and continues. Writes go through the fault-injection
//! tags `"distshard"` (all workers) and `"distshard<index>"` (one
//! worker), so `LSSPCA_FAULTS=wkill:distshard@…` scripts a mid-shard
//! worker kill.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::error::LsspcaError;
use crate::util::faultinject::{self, FaultWrite};
use crate::util::stats::RunningStats;
use crate::util::xor_fold_checksum;

/// Magic bytes of a shard result file.
pub const SHARD_MAGIC: &[u8; 4] = b"LSDS";
/// Shard result format version.
pub const SHARD_VERSION: u32 = 1;

/// Identity header every shard file carries; readers reject files whose
/// header disagrees with the manifest they are merging under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    /// Corpus digest (same FNV fold as the variance checkpoint).
    pub key: u64,
    /// Pass kind: [`crate::jobstate::KIND_VARIANCE`] or
    /// [`crate::jobstate::KIND_REDUCE`].
    pub kind: u64,
    /// Shard index in the manifest's shard table.
    pub shard_index: u64,
    /// Documents per chunk the pass ran at.
    pub chunk_docs: u64,
    /// First global chunk index of this shard's range.
    pub chunk_start: u64,
    /// Feature dimension: vocabulary n (variance) or n̂ (reduce).
    pub n: u64,
}

/// Kind-specific contents of one per-chunk block.
#[derive(Clone, Debug)]
pub enum BlockPayload {
    /// Sparse Welford triples of one chunk's [`crate::moments::FeatureMoments`]
    /// (features with at least one nonzero observation, ascending).
    Variance {
        /// `(feature, stats)` pairs, ascending by feature id.
        feats: Vec<(u32, RunningStats)>,
    },
    /// One chunk's [`crate::cov::ReducedDocsAccum`] parts.
    Reduce {
        /// Kept-doc ids, in stream order.
        doc_ids: Vec<u64>,
        /// Row start offsets (`len == doc_ids.len() + 1`, starts at 0).
        doc_ptr: Vec<usize>,
        /// Reduced column indices per stored entry.
        idx: Vec<u32>,
        /// Stored counts, aligned with `idx`.
        val: Vec<f64>,
    },
}

/// One per-chunk result block.
#[derive(Clone, Debug)]
pub struct ShardBlock {
    /// Global chunk index this block covers.
    pub chunk_index: u64,
    /// Documents streamed in the chunk (including docs with no kept
    /// features — the reduce pass still counts them).
    pub docs: u64,
    /// `(word, count)` pairs streamed in the chunk.
    pub nnz: u64,
    /// The accumulator contents.
    pub payload: BlockPayload,
}

/// Final (committed) path of a shard's result file.
pub fn result_path(dir: &Path, key: u64, kind: u64, shard: usize) -> PathBuf {
    dir.join(format!("distshard_{key:016x}_k{kind}_s{shard}.lsds"))
}

/// In-progress path a worker appends to before the commit rename.
pub fn part_path(dir: &Path, key: u64, kind: u64, shard: usize) -> PathBuf {
    dir.join(format!("distshard_{key:016x}_k{kind}_s{shard}.lsds.part"))
}

fn push_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn push_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn push_f64(v: &mut Vec<u8>, x: f64) {
    v.extend_from_slice(&x.to_le_bytes());
}

/// Little-endian cursor over a byte slice; `None` on underrun.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, p: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.p.checked_add(n)?;
        if end > self.b.len() {
            return None;
        }
        let s = &self.b[self.p..end];
        self.p = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn f64(&mut self) -> Option<f64> {
        self.take(8).map(|s| f64::from_le_bytes(s.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

fn header_bytes(h: &ShardHeader) -> Vec<u8> {
    let mut v = Vec::with_capacity(64);
    v.extend_from_slice(SHARD_MAGIC);
    push_u32(&mut v, SHARD_VERSION);
    let payload_start = v.len();
    for x in [h.key, h.kind, h.shard_index, h.chunk_docs, h.chunk_start, h.n] {
        push_u64(&mut v, x);
    }
    let ck = xor_fold_checksum(&v[payload_start..]);
    push_u64(&mut v, ck);
    v
}

/// Byte length of the file header.
const HEADER_LEN: usize = 4 + 4 + 6 * 8 + 8;

fn parse_header(bytes: &[u8]) -> Option<ShardHeader> {
    if bytes.len() < HEADER_LEN || &bytes[..4] != SHARD_MAGIC {
        return None;
    }
    let mut c = Cur::new(&bytes[4..HEADER_LEN]);
    if c.u32()? != SHARD_VERSION {
        return None;
    }
    let payload = &bytes[8..HEADER_LEN - 8];
    let h = ShardHeader {
        key: c.u64()?,
        kind: c.u64()?,
        shard_index: c.u64()?,
        chunk_docs: c.u64()?,
        chunk_start: c.u64()?,
        n: c.u64()?,
    };
    if c.u64()? != xor_fold_checksum(payload) {
        return None;
    }
    Some(h)
}

fn encode_block(b: &ShardBlock) -> Vec<u8> {
    let mut payload = Vec::new();
    push_u64(&mut payload, b.chunk_index);
    push_u64(&mut payload, b.docs);
    push_u64(&mut payload, b.nnz);
    match &b.payload {
        BlockPayload::Variance { feats } => {
            push_u64(&mut payload, feats.len() as u64);
            for (f, st) in feats {
                push_u32(&mut payload, *f);
                push_u64(&mut payload, st.n);
                push_f64(&mut payload, st.mean);
                push_f64(&mut payload, st.m2);
            }
        }
        BlockPayload::Reduce { doc_ids, doc_ptr, idx, val } => {
            debug_assert_eq!(doc_ptr.len(), doc_ids.len() + 1);
            debug_assert_eq!(idx.len(), val.len());
            push_u64(&mut payload, doc_ids.len() as u64);
            push_u64(&mut payload, idx.len() as u64);
            for &d in doc_ids {
                push_u64(&mut payload, d);
            }
            for &p in &doc_ptr[1..] {
                push_u64(&mut payload, p as u64);
            }
            for &i in idx {
                push_u32(&mut payload, i);
            }
            for &x in val {
                push_f64(&mut payload, x);
            }
        }
    }
    let mut out = Vec::with_capacity(payload.len() + 16);
    push_u64(&mut out, payload.len() as u64);
    let ck = xor_fold_checksum(&payload);
    out.extend_from_slice(&payload);
    push_u64(&mut out, ck);
    out
}

fn decode_payload(payload: &[u8], hdr: &ShardHeader) -> Option<ShardBlock> {
    let mut c = Cur::new(payload);
    let chunk_index = c.u64()?;
    let docs = c.u64()?;
    let nnz = c.u64()?;
    let body = match hdr.kind {
        crate::jobstate::KIND_VARIANCE => {
            let k = c.u64()? as usize;
            let mut feats = Vec::with_capacity(k.min(payload.len() / 28));
            let mut prev: Option<u32> = None;
            for _ in 0..k {
                let f = c.u32()?;
                if f as u64 >= hdr.n || prev.is_some_and(|p| f <= p) {
                    return None;
                }
                prev = Some(f);
                let st = RunningStats { n: c.u64()?, mean: c.f64()?, m2: c.f64()? };
                if st.n == 0 {
                    return None;
                }
                feats.push((f, st));
            }
            BlockPayload::Variance { feats }
        }
        crate::jobstate::KIND_REDUCE => {
            let rows = c.u64()? as usize;
            let rnnz = c.u64()? as usize;
            let mut doc_ids = Vec::with_capacity(rows.min(payload.len() / 8));
            for _ in 0..rows {
                doc_ids.push(c.u64()?);
            }
            let mut doc_ptr = Vec::with_capacity(rows + 1);
            doc_ptr.push(0usize);
            for _ in 0..rows {
                let p = c.u64()? as usize;
                if p < *doc_ptr.last().unwrap() || p > rnnz {
                    return None;
                }
                doc_ptr.push(p);
            }
            if doc_ptr.last() != Some(&rnnz) {
                return None;
            }
            let mut idx = Vec::with_capacity(rnnz.min(payload.len() / 4));
            for _ in 0..rnnz {
                let i = c.u32()?;
                if i as u64 >= hdr.n {
                    return None;
                }
                idx.push(i);
            }
            let mut val = Vec::with_capacity(rnnz);
            for _ in 0..rnnz {
                val.push(c.f64()?);
            }
            BlockPayload::Reduce { doc_ids, doc_ptr, idx, val }
        }
        _ => return None,
    };
    if !c.done() || docs == 0 {
        return None;
    }
    Some(ShardBlock { chunk_index, docs, nnz, payload: body })
}

/// Result of scanning a (possibly partial) shard file: the longest valid
/// block prefix plus how far into the file it reaches.
#[derive(Debug, Default)]
pub struct ScanOutcome {
    /// Whether the header parsed and matched the expected identity.
    pub header_ok: bool,
    /// Decoded blocks of the valid prefix, in file order.
    pub blocks: Vec<ShardBlock>,
    /// Byte length of header + valid blocks (truncation point on resume).
    pub valid_len: u64,
    /// Total file length on disk (0 when the file is missing).
    pub file_len: u64,
}

impl ScanOutcome {
    /// A committed shard: header valid and every byte belongs to a valid
    /// block whose chunk indices are contiguous from `chunk_start`.
    pub fn is_complete(&self, chunk_start: u64) -> bool {
        self.header_ok
            && self.file_len > 0
            && self.valid_len == self.file_len
            && self
                .blocks
                .iter()
                .enumerate()
                .all(|(i, b)| b.chunk_index == chunk_start + i as u64)
    }
}

/// Scan `path` against the expected header, tolerating a missing file
/// and any truncated/corrupt tail. Reads are wrapped under the
/// `"distshard"` fault tag.
pub fn scan(path: &Path, expect: &ShardHeader) -> Result<ScanOutcome, LsspcaError> {
    let file = match File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(ScanOutcome::default()),
        Err(e) => return Err(LsspcaError::io_at(path, format!("open shard result: {e}"))),
    };
    let mut bytes = Vec::new();
    faultinject::wrap_read("distshard", file)
        .read_to_end(&mut bytes)
        .map_err(|e| LsspcaError::io_at(path, format!("read shard result: {e}")))?;
    let mut out = ScanOutcome { file_len: bytes.len() as u64, ..Default::default() };
    let Some(hdr) = parse_header(&bytes) else {
        return Ok(out);
    };
    if hdr != *expect {
        return Ok(out);
    }
    out.header_ok = true;
    out.valid_len = HEADER_LEN as u64;
    let mut pos = HEADER_LEN;
    let mut next_chunk = expect.chunk_start;
    while pos + 8 <= bytes.len() {
        let len = u64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap()) as usize;
        let Some(end) =
            pos.checked_add(8).and_then(|p| p.checked_add(len)).and_then(|p| p.checked_add(8))
        else {
            break;
        };
        if end > bytes.len() {
            break;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        let ck = u64::from_le_bytes(bytes[end - 8..end].try_into().unwrap());
        if ck != xor_fold_checksum(payload) {
            break;
        }
        let Some(block) = decode_payload(payload, expect) else {
            break;
        };
        if block.chunk_index != next_chunk {
            break;
        }
        next_chunk += 1;
        out.blocks.push(block);
        out.valid_len = end as u64;
        pos = end;
    }
    Ok(out)
}

/// Read a committed shard result; `Ok(None)` when the file is missing,
/// incomplete, or fails validation — the caller then re-runs the shard.
pub fn read_complete(
    path: &Path,
    expect: &ShardHeader,
) -> Result<Option<Vec<ShardBlock>>, LsspcaError> {
    let out = scan(path, expect)?;
    if out.is_complete(expect.chunk_start) {
        Ok(Some(out.blocks))
    } else {
        Ok(None)
    }
}

/// Incremental writer over a shard's `.part` file. Each appended block
/// is flushed before the next chunk is read, so a killed worker loses at
/// most the chunk it was writing; [`ShardWriter::finish`] fsyncs and
/// commits via atomic rename.
pub struct ShardWriter {
    w: FaultWrite<FaultWrite<File>>,
    part: PathBuf,
    final_path: PathBuf,
    kind: u64,
    next_chunk: u64,
}

impl ShardWriter {
    /// Open the shard's `.part` file for appending, reusing the longest
    /// valid block prefix of any earlier attempt. Returns the writer and
    /// the number of blocks (chunks) already committed to the prefix.
    pub fn create_or_resume(
        dir: &Path,
        hdr: &ShardHeader,
    ) -> Result<(ShardWriter, u64), LsspcaError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::io_at(dir, format!("create cache dir: {e}")))?;
        let part = part_path(dir, hdr.key, hdr.kind, hdr.shard_index as usize);
        let final_path = result_path(dir, hdr.key, hdr.kind, hdr.shard_index as usize);
        let prior = scan(&part, hdr)?;
        let done = if prior.header_ok {
            // keep the valid prefix, drop the torn tail
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&part)
                .map_err(|e| LsspcaError::io_at(&part, format!("reopen shard part: {e}")))?;
            f.set_len(prior.valid_len)
                .map_err(|e| LsspcaError::io_at(&part, format!("truncate shard part: {e}")))?;
            prior.blocks.len() as u64
        } else {
            0
        };
        let fresh = !prior.header_ok;
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&part)
            .map_err(|e| LsspcaError::io_at(&part, format!("open shard part: {e}")))?;
        if fresh {
            file.set_len(0)
                .map_err(|e| LsspcaError::io_at(&part, format!("reset shard part: {e}")))?;
        }
        let specific = format!("distshard{}", hdr.shard_index);
        let mut w = faultinject::wrap_write(&specific, faultinject::wrap_write("distshard", file));
        if fresh {
            w.write_all(&header_bytes(hdr))
                .and_then(|()| w.flush())
                .map_err(|e| LsspcaError::io_at(&part, format!("write shard header: {e}")))?;
        }
        Ok((
            ShardWriter {
                w,
                part,
                final_path,
                kind: hdr.kind,
                next_chunk: hdr.chunk_start + done,
            },
            done,
        ))
    }

    /// The global chunk index the next appended block must carry.
    pub fn next_chunk(&self) -> u64 {
        self.next_chunk
    }

    /// Append one per-chunk block and flush it.
    pub fn append(&mut self, block: &ShardBlock) -> Result<(), LsspcaError> {
        assert_eq!(block.chunk_index, self.next_chunk, "blocks must be appended in chunk order");
        match (&block.payload, self.kind) {
            (BlockPayload::Variance { .. }, crate::jobstate::KIND_VARIANCE)
            | (BlockPayload::Reduce { .. }, crate::jobstate::KIND_REDUCE) => {}
            _ => panic!("block payload kind does not match the shard header"),
        }
        let bytes = encode_block(block);
        self.w
            .write_all(&bytes)
            .and_then(|()| self.w.flush())
            .map_err(|e| LsspcaError::io_at(&self.part, format!("append shard block: {e}")))?;
        self.next_chunk += 1;
        Ok(())
    }

    /// Commit: fsync the `.part` file and rename it to the final name.
    pub fn finish(self) -> Result<PathBuf, LsspcaError> {
        let file = self.w.into_inner().into_inner();
        file.sync_all()
            .map_err(|e| LsspcaError::io_at(&self.part, format!("sync shard result: {e}")))?;
        drop(file);
        std::fs::rename(&self.part, &self.final_path)
            .map_err(|e| LsspcaError::io_at(&self.final_path, format!("commit shard result: {e}")))?;
        Ok(self.final_path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobstate::{KIND_REDUCE, KIND_VARIANCE};

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lsspca_shardio_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn var_header() -> ShardHeader {
        ShardHeader {
            key: 0xabcd,
            kind: KIND_VARIANCE,
            shard_index: 2,
            chunk_docs: 64,
            chunk_start: 6,
            n: 100,
        }
    }

    fn var_block(chunk: u64) -> ShardBlock {
        let mut st = RunningStats::new();
        st.push(2.0);
        st.push(3.0);
        let mut st17 = RunningStats::new();
        st17.push(1.0);
        ShardBlock {
            chunk_index: chunk,
            docs: 64,
            nnz: 2,
            payload: BlockPayload::Variance { feats: vec![(5, st), (17, st17)] },
        }
    }

    #[test]
    fn roundtrip_variance_blocks() {
        let dir = tmpdir("roundtrip_var");
        let hdr = var_header();
        let (mut w, done) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        assert_eq!(done, 0);
        w.append(&var_block(6)).unwrap();
        w.append(&var_block(7)).unwrap();
        let final_path = w.finish().unwrap();
        let blocks = read_complete(&final_path, &hdr).unwrap().expect("complete");
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].chunk_index, 6);
        match &blocks[1].payload {
            BlockPayload::Variance { feats } => {
                assert_eq!(feats.len(), 2);
                assert_eq!(feats[0].0, 5);
                assert_eq!(feats[0].1.n, 2);
                assert_eq!(feats[0].1.mean.to_bits(), 2.5f64.to_bits());
            }
            _ => panic!("wrong payload kind"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_reduce_blocks() {
        let dir = tmpdir("roundtrip_red");
        let hdr = ShardHeader { kind: KIND_REDUCE, n: 8, ..var_header() };
        let block = ShardBlock {
            chunk_index: 6,
            docs: 3,
            nnz: 5,
            payload: BlockPayload::Reduce {
                doc_ids: vec![400, 402],
                doc_ptr: vec![0, 2, 3],
                idx: vec![1, 7, 0],
                val: vec![2.0, 1.0, 4.0],
            },
        };
        let (mut w, _) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        w.append(&block).unwrap();
        let p = w.finish().unwrap();
        let blocks = read_complete(&p, &hdr).unwrap().expect("complete");
        match &blocks[0].payload {
            BlockPayload::Reduce { doc_ids, doc_ptr, idx, val } => {
                assert_eq!(doc_ids[..], [400u64, 402][..]);
                assert_eq!(doc_ptr[..], [0usize, 2, 3][..]);
                assert_eq!(idx[..], [1u32, 7, 0][..]);
                assert_eq!(
                    val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    [2.0f64, 1.0, 4.0].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
            _ => panic!("wrong payload kind"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_dropped_and_resumed() {
        let dir = tmpdir("torn_tail");
        let hdr = var_header();
        let (mut w, _) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        w.append(&var_block(6)).unwrap();
        drop(w); // simulate a kill: .part left behind, no rename
        let part = part_path(&dir, hdr.key, hdr.kind, hdr.shard_index as usize);
        // tear the file mid-block: append half of a second block
        let next = encode_block(&var_block(7));
        let mut f = std::fs::OpenOptions::new().append(true).open(&part).unwrap();
        f.write_all(&next[..next.len() / 2]).unwrap();
        drop(f);
        let torn_len = std::fs::metadata(&part).unwrap().len();

        let (mut w, done) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        assert_eq!(done, 1, "one valid block survives the tear");
        assert!(std::fs::metadata(&part).unwrap().len() < torn_len, "torn tail truncated");
        assert_eq!(w.next_chunk(), 7);
        w.append(&var_block(7)).unwrap();
        let p = w.finish().unwrap();
        assert_eq!(read_complete(&p, &hdr).unwrap().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_or_corrupt_header_is_rejected() {
        let dir = tmpdir("foreign");
        let hdr = var_header();
        let (mut w, _) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        w.append(&var_block(6)).unwrap();
        let p = w.finish().unwrap();
        // wrong key
        let other = ShardHeader { key: 0x9999, ..hdr };
        assert!(read_complete(&p, &other).unwrap().is_none());
        // flipped byte inside the first block's payload
        let mut bytes = std::fs::read(&p).unwrap();
        let at = HEADER_LEN + 12;
        bytes[at] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_complete(&p, &hdr).unwrap().is_none());
        // a missing file is simply "not complete", not an error
        assert!(read_complete(Path::new("/nonexistent/x.lsds"), &hdr).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_order_chunks_invalidate_the_tail() {
        let dir = tmpdir("order");
        let hdr = var_header();
        let (mut w, _) = ShardWriter::create_or_resume(&dir, &hdr).unwrap();
        w.append(&var_block(6)).unwrap();
        let part = part_path(&dir, hdr.key, hdr.kind, hdr.shard_index as usize);
        drop(w);
        // forge a block with a skipped chunk index
        let mut f = std::fs::OpenOptions::new().append(true).open(&part).unwrap();
        f.write_all(&encode_block(&var_block(9))).unwrap();
        drop(f);
        let out = scan(&part, &hdr).unwrap();
        assert!(out.header_ok);
        assert_eq!(out.blocks.len(), 1, "the out-of-order block is rejected");
        assert!(out.valid_len < out.file_len);
        std::fs::remove_dir_all(&dir).ok();
    }
}
