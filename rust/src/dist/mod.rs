//! Distributed sharded corpus pass: a coordinator plus N worker
//! *processes* over the streaming passes, bitwise identical to the
//! single-process pipeline.
//!
//! ```text
//!            ┌────────────── coordinator (this module) ──────────────┐
//!            │ distjob_*.lsjs manifest: identity + shard status table │
//!            └──┬──────────────────┬──────────────────┬──────────────┘
//!     spawn `lsspca worker`  spawn `lsspca worker`  spawn …
//!            │ shard 0             │ shard 1             │ shard S-1
//!            ▼                     ▼                     ▼
//!   distshard_*_s0.lsds   distshard_*_s1.lsds   distshard_*_sS-1.lsds
//!   (per-chunk blocks)    (per-chunk blocks)    (per-chunk blocks)
//!            └──────────────────┬──┴──────────────────┬─┘
//!                               ▼
//!              merge in strict shard → chunk order
//!              (= ascending global chunk index)
//! ```
//!
//! **Determinism invariant.** Workers fold each chunk into a fresh
//! accumulator sequentially and persist *per-chunk* blocks; the
//! coordinator merges them in ascending global chunk index — exactly the
//! merge schedule of [`crate::stream::resumable_variance_pass`]. Welford
//! merges are not associative in floating point, but a fixed merge order
//! over identical per-chunk inputs is reproducible, so the merged
//! variance pass is **bitwise identical** to a single-process run for
//! any worker count and any shard size. The reduce pass is canonical by
//! construction ([`crate::cov::ReducedDocsAccum::finalize`] sorts rows
//! and columns), and the distributed dense backend replays that
//! canonical CSR through [`crate::cov::covariance_from_canonical_csr`]
//! — bitwise equal to a `stream.workers = 1` single-process pass.
//!
//! **Fault model.** Every shard commits via atomic rename; the manifest
//! records per-shard status crash-atomically. A SIGKILLed worker resumes
//! from its `.part` block prefix; a SIGKILLed coordinator reloads the
//! manifest, adopts shards whose result files verify, and re-runs only
//! the rest. A worker that *fails* (bad exit, corrupt result) leaves its
//! shard in a retryable `Failed` state — the job errors at the end of
//! the run instead of aborting mid-flight, and the next run retries just
//! the failed shards. Malformed corpus records land in per-shard
//! dead-letter files merged into the main queue with offset dedup.

pub mod plan;
pub mod shardio;
pub mod worker;

use std::collections::VecDeque;
use std::path::{Path, PathBuf};

use crate::cov::ReducedDocsAccum;
use crate::data::sparse::CsrMatrix;
use crate::error::LsspcaError;
use crate::jobstate::{
    self, CorpusSource, DistManifest, ShardEntry, ShardStatus, KIND_REDUCE, KIND_VARIANCE,
};
use crate::moments::{FeatureMoments, FeatureVariances};
use crate::session::{Progress, ProgressUpdate, Stage};
use crate::stream::StreamStats;
use plan::{plan_shards, ShardRange};
use shardio::{BlockPayload, ShardBlock};

/// Environment override for the worker executable (tests run inside the
/// test harness binary, which has no `worker` subcommand).
pub const WORKER_BIN_ENV: &str = "LSSPCA_WORKER_BIN";

/// Everything a distributed pass needs from the session, decoupled from
/// the session's own types so the coordinator stays independently
/// testable.
#[derive(Clone, Debug)]
pub struct DistPassParams {
    /// Cache directory holding the manifest and shard files (the config
    /// validator requires one when `dist_workers > 0`).
    pub cache_dir: PathBuf,
    /// Concurrent worker processes to keep in flight.
    pub workers: usize,
    /// Requested shard size in documents (0 = auto; rounded up to a
    /// chunk multiple either way).
    pub shard_docs: u64,
    /// Documents per streamed chunk.
    pub chunk_docs: u64,
    /// Corpus digest ([`crate::checkpoint::corpus_key`]).
    pub key: u64,
    /// How workers reopen the corpus.
    pub source: CorpusSource,
    /// Total observed documents.
    pub num_docs: u64,
    /// Vocabulary size.
    pub n: u64,
    /// Dead-letter budget (0 = strict readers).
    pub max_bad_records: u64,
    /// Main dead-letter queue path, when quarantine is enabled.
    pub dead_letter: Option<PathBuf>,
    /// In-process threads for the final `finalize_par` (output is
    /// thread-count independent).
    pub threads: usize,
}

/// Resolve the worker executable: [`WORKER_BIN_ENV`] override, else the
/// current binary re-exec'd.
pub fn worker_binary() -> Result<PathBuf, LsspcaError> {
    if let Ok(p) = std::env::var(WORKER_BIN_ENV) {
        if !p.is_empty() {
            return Ok(PathBuf::from(p));
        }
    }
    std::env::current_exe()
        .map_err(|e| LsspcaError::config(format!("cannot locate the worker binary: {e}")))
}

/// Rebuild one variance block's chunk accumulator (sparse stored feats →
/// full-width [`FeatureMoments`]). Exact: features absent from the block
/// had zero nonzero observations in the chunk, which is precisely the
/// default [`crate::util::stats::RunningStats`] the in-process pass
/// would have left untouched.
pub(crate) fn block_moments(block: &ShardBlock, n: usize) -> FeatureMoments {
    let BlockPayload::Variance { feats } = &block.payload else {
        unreachable!("variance merge over a reduce block");
    };
    let mut stats = vec![crate::util::stats::RunningStats::new(); n];
    for &(f, st) in feats {
        stats[f as usize] = st;
    }
    FeatureMoments::from_parts(stats, block.docs, block.nnz)
}

/// The distributed variance pass (drop-in for the single-process
/// resumable pass in `Session::run_stream`). Fires
/// `observer.stage_advanced(Stage::Stream, …)` once per shard a worker
/// actually executed — adopted (already-complete) shards are silent, so
/// `CountingProgress::reads(Stage::Stream)` counts re-executed shards.
pub fn dist_variance_pass(
    params: &DistPassParams,
    observer: &dyn Progress,
) -> Result<(FeatureVariances, StreamStats), LsspcaError> {
    let n = params.n as usize;
    let mut master = FeatureMoments::new(n);
    let stats = run_job(params, KIND_VARIANCE, Vec::new(), observer, Stage::Stream, |block| {
        master.merge(&block_moments(&block, n));
    })?;
    Ok((master.finalize_par(params.threads), stats))
}

/// The distributed reduced-CSR pass (drop-in for
/// [`crate::cov::reduced_csr_pass`]): per-chunk accumulator parts are
/// concatenated in shard/chunk order and finalized into the canonical
/// doc-sorted, column-sorted CSR — bitwise identical to any
/// single-process run.
pub fn dist_reduced_csr_pass(
    params: &DistPassParams,
    kept: &[u32],
    observer: &dyn Progress,
) -> Result<(CsrMatrix, StreamStats), LsspcaError> {
    let mut acc = ReducedDocsAccum::new();
    let stats = run_job(params, KIND_REDUCE, kept.to_vec(), observer, Stage::Reduce, |block| {
        let BlockPayload::Reduce { doc_ids, doc_ptr, idx, val } = block.payload else {
            unreachable!("reduce merge over a variance block");
        };
        acc.merge(ReducedDocsAccum::from_parts(doc_ids, doc_ptr, idx, val));
    })?;
    Ok((acc.finalize(kept.len()), stats))
}

/// Coordinator core: resume-or-create the manifest, drive workers over
/// the incomplete shards, merge dead-letter spills, then fold every
/// shard's blocks through `fold` in strict shard → chunk order.
fn run_job(
    params: &DistPassParams,
    kind: u64,
    kept: Vec<u32>,
    observer: &dyn Progress,
    stage: Stage,
    mut fold: impl FnMut(ShardBlock),
) -> Result<StreamStats, LsspcaError> {
    let t0 = std::time::Instant::now();
    let shard_plan = plan_shards(params.num_docs, params.chunk_docs, params.shard_docs);
    let fresh = DistManifest {
        key: params.key,
        kind,
        chunk_docs: params.chunk_docs,
        shard_docs: plan::effective_shard_docs(params.chunk_docs, params.shard_docs),
        num_docs: params.num_docs,
        n: params.n,
        source: params.source.clone(),
        max_bad_records: params.max_bad_records,
        dead_letter: params
            .dead_letter
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default(),
        kept,
        shards: vec![ShardEntry { status: ShardStatus::Pending, attempts: 0 }; shard_plan.len()],
    };
    let manifest_path = jobstate::dist_path_for(&params.cache_dir, params.key, kind);
    let mut manifest = match jobstate::load_dist(&manifest_path) {
        Ok(Some(old)) if old.same_job(&fresh) => {
            let done = old.shards.iter().filter(|s| s.status == ShardStatus::Done).count();
            eprintln!(
                "dist: resuming {} from its manifest ({done}/{} shards already complete)",
                pass_name(kind),
                old.shards.len()
            );
            old
        }
        Ok(Some(_)) => {
            eprintln!("warning: dist manifest belongs to a different job; starting over");
            jobstate::save_dist(&manifest_path, &fresh, "distmanifest-init")?;
            fresh
        }
        Ok(None) => {
            jobstate::save_dist(&manifest_path, &fresh, "distmanifest-init")?;
            fresh
        }
        Err(e) => {
            eprintln!("warning: dist manifest rejected ({e}); starting over");
            jobstate::save_dist(&manifest_path, &fresh, "distmanifest-init")?;
            fresh
        }
    };

    // Adopt shards whose committed result file verifies — covers a
    // coordinator killed after a worker's rename but before the manifest
    // update. Adopted shards are not re-read and fire no progress.
    let mut adopted = false;
    for range in &shard_plan {
        if manifest.shards[range.index].status != ShardStatus::Done {
            let hdr = worker::shard_header(&manifest, range);
            let path = shardio::result_path(&params.cache_dir, params.key, kind, range.index);
            if shardio::read_complete(&path, &hdr)?.is_some() {
                manifest.shards[range.index].status = ShardStatus::Done;
                adopted = true;
            }
        }
    }
    if adopted {
        jobstate::save_dist(&manifest_path, &manifest, "distmanifest")?;
    }

    drive_workers(params, &mut manifest, &manifest_path, &shard_plan, observer, stage)?;

    // Merge per-shard dead-letter spills (offset dedup) and enforce the
    // *global* budget — two workers can each stay within budget while
    // their distinct bad lines together exceed it.
    if params.max_bad_records > 0 {
        if let Some(main) = &params.dead_letter {
            let shard_paths: Vec<PathBuf> =
                (0..shard_plan.len()).map(|i| worker::shard_dlq_path(main, i)).collect();
            let total = crate::deadletter::merge_shard_queues(main, &shard_paths)?;
            if total > params.max_bad_records {
                return Err(LsspcaError::corpus(format!(
                    "too many bad records: {total} quarantined, max_bad_records = {} (see {})",
                    params.max_bad_records,
                    main.display()
                )));
            }
            if total > 0 {
                eprintln!(
                    "warning: {total} malformed record(s) quarantined across shards (see {})",
                    main.display()
                );
            }
        }
    }

    // Strict-order merge: ascending shard index, ascending chunk index
    // within each shard = ascending global chunk index.
    let mut stats = StreamStats::default();
    for range in &shard_plan {
        let hdr = worker::shard_header(&manifest, range);
        let path = shardio::result_path(&params.cache_dir, params.key, kind, range.index);
        let blocks = shardio::read_complete(&path, &hdr)?.ok_or_else(|| {
            LsspcaError::cache(format!("shard {} result vanished before the merge", range.index))
        })?;
        for block in blocks {
            stats.docs += block.docs;
            stats.nnz += block.nnz;
            stats.chunks += 1;
            fold(block);
        }
    }

    // Success: the job's scaffolding has served its purpose.
    jobstate::remove(&manifest_path)
        .map_err(|e| LsspcaError::io_at(&manifest_path, format!("remove dist manifest: {e}")))?;
    for range in &shard_plan {
        for p in [
            shardio::result_path(&params.cache_dir, params.key, kind, range.index),
            shardio::part_path(&params.cache_dir, params.key, kind, range.index),
            worker::shard_jobstate_path(&params.cache_dir, &manifest, range.index),
        ] {
            match std::fs::remove_file(&p) {
                Err(e) if e.kind() != std::io::ErrorKind::NotFound => {
                    eprintln!("warning: cannot remove {}: {e}", p.display());
                }
                _ => {}
            }
        }
    }
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok(stats)
}

fn pass_name(kind: u64) -> &'static str {
    match kind {
        KIND_VARIANCE => "variance pass",
        KIND_REDUCE => "reduce pass",
        _ => "corpus pass",
    }
}

/// Spawn worker processes (at most `params.workers` in flight) for every
/// shard not yet `Done`, recording each outcome in the manifest as it
/// lands. Returns an error if any shard ends the run `Failed` — the
/// manifest keeps the failed shards retryable for the next run.
fn drive_workers(
    params: &DistPassParams,
    manifest: &mut DistManifest,
    manifest_path: &Path,
    shard_plan: &[ShardRange],
    observer: &dyn Progress,
    stage: Stage,
) -> Result<(), LsspcaError> {
    let mut queue: VecDeque<usize> = shard_plan
        .iter()
        .filter(|r| manifest.shards[r.index].status != ShardStatus::Done)
        .map(|r| r.index)
        .collect();
    if queue.is_empty() {
        return Ok(());
    }
    let bin = worker_binary()?;
    let procs = params.workers.max(1);
    let mut active: Vec<(usize, std::process::Child)> = Vec::new();
    let mut failed = 0usize;
    while !queue.is_empty() || !active.is_empty() {
        while active.len() < procs {
            let Some(shard) = queue.pop_front() else {
                break;
            };
            match std::process::Command::new(&bin)
                .arg("worker")
                .arg("--manifest")
                .arg(manifest_path)
                .arg("--shard")
                .arg(shard.to_string())
                .spawn()
            {
                Ok(child) => active.push((shard, child)),
                Err(e) => {
                    eprintln!("warning: cannot spawn worker for shard {shard}: {e}");
                    manifest.shards[shard].status = ShardStatus::Failed;
                    manifest.shards[shard].attempts += 1;
                    failed += 1;
                    jobstate::save_dist(manifest_path, manifest, "distmanifest")?;
                }
            }
        }
        let mut reaped_any = false;
        let mut k = 0;
        while k < active.len() {
            let exited = active[k].1.try_wait().map_err(|e| {
                LsspcaError::corpus(format!("waiting on worker for shard {}: {e}", active[k].0))
            })?;
            match exited {
                None => k += 1,
                Some(status) => {
                    let (shard, _) = active.swap_remove(k);
                    reaped_any = true;
                    let range = shard_plan[shard];
                    let hdr = worker::shard_header(manifest, &range);
                    let path = shardio::result_path(&params.cache_dir, params.key, hdr.kind, shard);
                    let complete = shardio::read_complete(&path, &hdr)?.is_some();
                    let entry = &mut manifest.shards[shard];
                    entry.attempts += 1;
                    if status.success() && complete {
                        entry.status = ShardStatus::Done;
                        observer.stage_advanced(
                            stage,
                            ProgressUpdate { docs: range.doc_end - range.doc_start, nnz: 0 },
                        );
                    } else {
                        entry.status = ShardStatus::Failed;
                        failed += 1;
                        eprintln!(
                            "warning: shard {shard} worker {} (result {}); marked retryable",
                            describe_exit(&status),
                            if complete { "complete" } else { "incomplete" },
                        );
                    }
                    jobstate::save_dist(manifest_path, manifest, "distmanifest")?;
                }
            }
        }
        if !reaped_any && !active.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
    if failed > 0 {
        return Err(LsspcaError::corpus(format!(
            "{failed} shard(s) failed; the dist manifest keeps them retryable — rerun to retry"
        )));
    }
    Ok(())
}

fn describe_exit(status: &std::process::ExitStatus) -> String {
    match status.code() {
        Some(c) => format!("exited with status {c}"),
        None => "was killed by a signal".to_string(),
    }
}
