//! Minimal leveled stderr logger (the `log` facade has no vendored backend;
//! this is the offline substitute, see DESIGN.md §3).
//!
//! Level is controlled by `LSSPCA_LOG` (`error|warn|info|debug|trace`,
//! default `info`) or programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Progress messages (the default threshold).
    Info = 2,
    /// Diagnostic detail.
    Debug = 3,
    /// Very chatty inner-loop tracing.
    Trace = 4,
}

impl Level {
    /// Parse a level name (`error|warn|info|debug|trace`, any case).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn init_level() -> u8 {
    let lvl = std::env::var("LSSPCA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_level() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Set the global level programmatically.
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Whether a message at `lvl` would be emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit a log line (used by the macros; rarely called directly).
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {}] {args}", lvl.tag());
    }
}

/// Log at `Error` level (printf-style args, stderr).
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Error, format_args!($($arg)*)) };
}
/// Log at `Warn` level (named `warn_!` — `warn` collides with the
/// built-in lint attribute namespace in some contexts).
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Warn, format_args!($($arg)*)) };
}
/// Log at `Info` level (the default threshold).
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Info, format_args!($($arg)*)) };
}
/// Log at `Debug` level (enable with `LSSPCA_LOG=debug`).
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Debug, format_args!($($arg)*)) };
}
/// Log at `Trace` level (enable with `LSSPCA_LOG=trace`).
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn set_and_query() {
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }
}
