//! Runtime-dispatched compute kernels for the BCA/covariance/scoring hot
//! paths.
//!
//! Every arithmetic-intensity-bound loop in the crate — QP coordinate
//! sweeps, Gram/covariance matvecs, scorer projections — bottoms out in a
//! handful of vector primitives (`dot`, `axpy`, `scale`, gathered axpy).
//! This module owns those primitives and selects, once per process, the
//! fastest available backend:
//!
//! | tier     | ISA            | guard                                  |
//! |----------|----------------|----------------------------------------|
//! | `scalar` | portable Rust  | always available (the reference)       |
//! | `avx2`   | x86-64 AVX2    | `is_x86_feature_detected!("avx2")`     |
//! | `neon`   | AArch64 NEON   | `is_aarch64_feature_detected!("neon")` |
//!
//! Selection order: the `LSSPCA_KERNELS` environment variable (read
//! lazily on first kernel call), then any explicit [`force`] from the
//! `[compute] kernels` config key / `--kernels` CLI flag, then hardware
//! auto-detection. The active tier is a process-global so every layer —
//! solver, covariance backends, scorer — flips together; [`active`]
//! reports it for benchmarks and logs.
//!
//! # Determinism invariant
//!
//! **Every SIMD path is bitwise-identical to the scalar path.** The
//! scalar kernels fix the floating-point evaluation order (e.g. [`dot`]
//! accumulates into four lanes combined as `(s0 + s1) + (s2 + s3)` with a
//! sequential remainder), and the SIMD backends reproduce *exactly that
//! tree*: a 4-wide vertical accumulate whose horizontal reduction is the
//! same `(s0 + s1) + (s2 + s3)`, with separate rounding of every product
//! and sum (vector multiply + add, never fused multiply-add). Element-wise
//! kernels (`axpy`, `scale`) are trivially bitwise because each lane is an
//! independent rounding. This is what lets the pipeline promise
//! bit-identical principal components across `scalar`/`avx2`/`neon`/`auto`
//! — pinned by property tests over every remainder-lane count.
//!
//! The only reassociating/fusing variants live behind the explicit
//! `fast_math = true` opt-in ([`set_fast_math`]): FMA-contracted dot
//! products, validated against the exact path at ≤ 1e-12 by tests and
//! **off by default**.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

use crate::error::LsspcaError;

pub mod scalar;

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

/// Requested dispatch mode — what config/CLI/env ask for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Pick the best tier the hardware supports (the default).
    Auto,
    /// Portable scalar reference kernels.
    Scalar,
    /// x86-64 AVX2 (requires hardware support; error otherwise).
    Avx2,
    /// AArch64 NEON (requires hardware support; error otherwise).
    Neon,
}

impl KernelMode {
    /// Parse a mode name as accepted by `[compute] kernels`, `--kernels`
    /// and `LSSPCA_KERNELS`: `auto | scalar | avx2 | neon`.
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s {
            "auto" => Some(KernelMode::Auto),
            "scalar" => Some(KernelMode::Scalar),
            "avx2" => Some(KernelMode::Avx2),
            "neon" => Some(KernelMode::Neon),
            _ => None,
        }
    }

    /// Canonical lowercase name.
    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Scalar => "scalar",
            KernelMode::Avx2 => "avx2",
            KernelMode::Neon => "neon",
        }
    }
}

/// Resolved dispatch tier — what the process actually runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Tier {
    /// Portable scalar kernels.
    Scalar = 1,
    /// x86-64 AVX2 kernels.
    Avx2 = 2,
    /// AArch64 NEON kernels.
    Neon = 3,
}

impl Tier {
    /// Lowercase tier name, for logs and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }
}

/// 0 = not yet initialised; otherwise a `Tier` discriminant.
static ACTIVE_TIER: AtomicU8 = AtomicU8::new(0);

/// Reassociating/FMA variants opt-in (`[compute] fast_math`). Off by
/// default: the exact, bitwise-reproducible paths run.
static FAST_MATH: AtomicBool = AtomicBool::new(false);

fn tier_from_u8(v: u8) -> Option<Tier> {
    match v {
        1 => Some(Tier::Scalar),
        2 => Some(Tier::Avx2),
        3 => Some(Tier::Neon),
        _ => None,
    }
}

/// Best tier the current hardware supports.
fn detect() -> Tier {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Tier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Tier::Neon;
        }
    }
    Tier::Scalar
}

/// Resolve a requested mode against the hardware; `Err` if the mode
/// names a tier this machine cannot run.
fn resolve(mode: KernelMode) -> Result<Tier, LsspcaError> {
    match mode {
        KernelMode::Auto => Ok(detect()),
        KernelMode::Scalar => Ok(Tier::Scalar),
        KernelMode::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    return Ok(Tier::Avx2);
                }
            }
            Err(LsspcaError::config(
                "kernels = \"avx2\" requested but AVX2 is not available on this CPU".to_string(),
            ))
        }
        KernelMode::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    return Ok(Tier::Neon);
                }
            }
            Err(LsspcaError::config(
                "kernels = \"neon\" requested but NEON is not available on this CPU".to_string(),
            ))
        }
    }
}

/// Lazy first-touch initialisation: honour `LSSPCA_KERNELS` if set (an
/// unusable value warns and falls back to auto-detection so an exported
/// variable never turns a working binary into a crashing one), otherwise
/// auto-detect.
#[cold]
fn init_tier() -> Tier {
    let mode = match std::env::var("LSSPCA_KERNELS") {
        Ok(v) if !v.is_empty() => match KernelMode::parse(&v) {
            Some(m) => m,
            None => {
                crate::warn_!("LSSPCA_KERNELS={v:?} not one of auto|scalar|avx2|neon; using auto");
                KernelMode::Auto
            }
        },
        _ => KernelMode::Auto,
    };
    let tier = resolve(mode).unwrap_or_else(|e| {
        crate::warn_!("LSSPCA_KERNELS: {e}; using auto-detected tier");
        detect()
    });
    ACTIVE_TIER.store(tier as u8, Ordering::Relaxed);
    tier
}

/// The active dispatch tier (initialising it on first call).
#[inline]
pub fn active() -> Tier {
    match tier_from_u8(ACTIVE_TIER.load(Ordering::Relaxed)) {
        Some(t) => t,
        None => init_tier(),
    }
}

/// Force the dispatch tier (config `[compute] kernels` / `--kernels` /
/// A-B tests). Errors if the requested tier is unavailable on this
/// hardware; on success returns the resolved tier.
///
/// Switching tiers at runtime is safe for results: every tier is
/// bitwise-identical (see the module docs), so concurrent work observes
/// identical arithmetic regardless of when the switch lands.
pub fn force(mode: KernelMode) -> Result<Tier, LsspcaError> {
    let tier = resolve(mode)?;
    ACTIVE_TIER.store(tier as u8, Ordering::Relaxed);
    Ok(tier)
}

/// Enable/disable the reassociating FMA variants. Off by default; when
/// on, SIMD dots contract multiply-add pairs (≤ 1e-12 relative deviation
/// from the exact path, pinned by tests) — bitwise reproducibility across
/// tiers is forfeited.
pub fn set_fast_math(on: bool) {
    FAST_MATH.store(on, Ordering::Relaxed);
}

/// Whether the reassociating variants are enabled.
#[inline]
pub fn fast_math() -> bool {
    FAST_MATH.load(Ordering::Relaxed)
}

/// Apply the `[compute]` settings (config or CLI): parse + force the
/// kernel mode, set the fast-math opt-in. Returns the resolved tier.
///
/// An explicit tier name beats everything. `"auto"` (the config default)
/// defers to `LSSPCA_KERNELS` when set, then hardware detection — so an
/// exported env override keeps working for runs whose config never
/// mentions `[compute]`.
pub fn apply_settings(kernels: &str, fast: bool) -> Result<Tier, LsspcaError> {
    let mode = KernelMode::parse(kernels).ok_or_else(|| {
        LsspcaError::config(format!(
            "compute.kernels = {kernels:?} not one of auto|scalar|avx2|neon"
        ))
    })?;
    set_fast_math(fast);
    match mode {
        KernelMode::Auto => {
            // Re-run the env-aware lazy init rather than plain detection.
            ACTIVE_TIER.store(0, Ordering::Relaxed);
            Ok(active())
        }
        m => force(m),
    }
}

/// Cache-block target for column-range sweeps: the working window of a
/// sweep (the `x` slice plus column pointers) is kept within a
/// conservative half-L2 budget so the streamed output is the only
/// traffic that misses. 256 KiB covers the common 512 KiB–1 MiB L2 sizes
/// without starving hyper-threaded siblings.
pub const L2_TARGET_BYTES: usize = 256 * 1024;

/// Number of columns per cache block for a sweep touching
/// `bytes_per_col` bytes of working set per column (floor 64 so tiny
/// estimates never degenerate into per-column loop overhead).
pub fn l2_block_cols(bytes_per_col: usize) -> usize {
    (L2_TARGET_BYTES / bytes_per_col.max(1)).max(64)
}

/// Dot product `Σ aᵢ·bᵢ` over `a.len()` entries (`b` may be longer).
///
/// Fixed evaluation order on every tier: four lanes over 4-element
/// chunks combined as `(s0 + s1) + (s2 + s3)`, then a sequential
/// remainder — see the module docs for why this is bitwise-stable
/// across `scalar`/`avx2`/`neon`.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert!(b.len() >= a.len(), "dot: b.len() {} < a.len() {}", b.len(), a.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe {
            if fast_math() {
                x86::dot_fma(a, b)
            } else {
                x86::dot(a, b)
            }
        },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe {
            if fast_math() {
                neon::dot_fma(a, b)
            } else {
                neon::dot(a, b)
            }
        },
        _ => scalar::dot(a, b),
    }
}

/// In-place `y ← y + α·x` over `min(x.len(), y.len())` entries.
/// Element-wise, hence bitwise-identical on every tier.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { x86::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::axpy(alpha, x, y) },
        _ => scalar::axpy(alpha, x, y),
    }
}

/// In-place `x ← α·x`. Element-wise, bitwise-identical on every tier.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { x86::scale(alpha, x) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::scale(alpha, x) },
        _ => scalar::scale(alpha, x),
    }
}

/// Gathered axpy `y[k] ← y[k] + α·x[k]` for `k` in `idx` — the QP
/// active-set inner update. Each index is an independent rounding, so
/// any future vector-gather implementation stays bitwise-identical; for
/// now every tier runs the scalar loop (AVX2 has no f64 scatter store,
/// so a gather/scalar-scatter mix measures no better than the scalar
/// loop on typical active-set sizes).
#[inline]
pub fn gather_axpy(alpha: f64, x: &[f64], idx: &[usize], y: &mut [f64]) {
    scalar::gather_axpy(alpha, x, idx, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Serialises the tests that mutate the process-global tier or the
    /// fast-math flag: switching tiers is bitwise-invisible to concurrent
    /// work, but enabling fast-math mid-flight is not.
    static GLOBAL_STATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn global_lock() -> std::sync::MutexGuard<'static, ()> {
        GLOBAL_STATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Sizes covering every remainder-lane count of the 4-wide kernels,
    /// plus a couple of larger lengths.
    fn probe_sizes() -> Vec<usize> {
        let mut v: Vec<usize> = (0..=33).collect();
        v.push(127);
        v.push(1000);
        v
    }

    fn vecs(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>) {
        let a: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        (a, b)
    }

    #[test]
    fn mode_parsing_round_trips() {
        for m in [KernelMode::Auto, KernelMode::Scalar, KernelMode::Avx2, KernelMode::Neon] {
            assert_eq!(KernelMode::parse(m.name()), Some(m));
        }
        assert_eq!(KernelMode::parse("sse2"), None);
        assert_eq!(KernelMode::parse(""), None);
    }

    #[test]
    fn unavailable_tier_is_an_error() {
        // At most one of the SIMD tiers can exist on any one machine, so
        // at least one of these must error (both on plain scalar hosts).
        let avx2 = resolve(KernelMode::Avx2);
        let neon = resolve(KernelMode::Neon);
        assert!(avx2.is_err() || neon.is_err());
        // Auto and Scalar always resolve.
        assert!(resolve(KernelMode::Auto).is_ok());
        assert_eq!(resolve(KernelMode::Scalar).unwrap(), Tier::Scalar);
    }

    #[test]
    fn dispatch_is_bitwise_stable_across_forced_tiers() {
        // The dispatch-level invariant: whatever tier `auto` lands on,
        // the public kernels return the same bits as forced scalar.
        let _g = global_lock();
        let mut rng = Rng::seed_from(0xD07);
        for n in probe_sizes() {
            let (a, b) = vecs(&mut rng, n);
            let mut y1: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let mut y2 = y1.clone();
            force(KernelMode::Scalar).unwrap();
            let d1 = dot(&a, &b);
            axpy(0.37, &a, &mut y1);
            scale(-1.25, &mut y1);
            force(KernelMode::Auto).unwrap();
            let d2 = dot(&a, &b);
            axpy(0.37, &a, &mut y2);
            scale(-1.25, &mut y2);
            assert_eq!(d1.to_bits(), d2.to_bits(), "dot diverged at n = {n}");
            for (v1, v2) in y1.iter().zip(&y2) {
                assert_eq!(v1.to_bits(), v2.to_bits(), "axpy/scale diverged at n = {n}");
            }
        }
        force(KernelMode::Auto).unwrap();
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn prop_avx2_bitwise_identical_to_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return; // nothing to pin on this host
        }
        let mut rng = Rng::seed_from(0xA5C2);
        for n in probe_sizes() {
            for rep in 0..4 {
                let (a, b) = vecs(&mut rng, n);
                let exact = scalar::dot(&a, &b);
                let simd = unsafe { x86::dot(&a, &b) };
                assert_eq!(
                    exact.to_bits(),
                    simd.to_bits(),
                    "avx2 dot != scalar at n = {n}, rep {rep}"
                );
                let mut ys = b.clone();
                let mut yv = b.clone();
                scalar::axpy(1.5 - rep as f64, &a, &mut ys);
                unsafe { x86::axpy(1.5 - rep as f64, &a, &mut yv) };
                scalar::scale(0.75, &mut ys);
                unsafe { x86::scale(0.75, &mut yv) };
                for (s, v) in ys.iter().zip(&yv) {
                    assert_eq!(s.to_bits(), v.to_bits(), "avx2 axpy/scale != scalar at n = {n}");
                }
            }
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn prop_neon_bitwise_identical_to_scalar() {
        if !std::arch::is_aarch64_feature_detected!("neon") {
            return;
        }
        let mut rng = Rng::seed_from(0x4E04);
        for n in probe_sizes() {
            for rep in 0..4 {
                let (a, b) = vecs(&mut rng, n);
                let exact = scalar::dot(&a, &b);
                let simd = unsafe { neon::dot(&a, &b) };
                assert_eq!(
                    exact.to_bits(),
                    simd.to_bits(),
                    "neon dot != scalar at n = {n}, rep {rep}"
                );
                let mut ys = b.clone();
                let mut yv = b.clone();
                scalar::axpy(1.5 - rep as f64, &a, &mut ys);
                unsafe { neon::axpy(1.5 - rep as f64, &a, &mut yv) };
                scalar::scale(0.75, &mut ys);
                unsafe { neon::scale(0.75, &mut yv) };
                for (s, v) in ys.iter().zip(&yv) {
                    assert_eq!(s.to_bits(), v.to_bits(), "neon axpy/scale != scalar at n = {n}");
                }
            }
        }
    }

    #[test]
    fn fast_math_dot_within_1e12_of_exact() {
        // The fused variants may reassociate but must stay within 1e-12
        // (relative to the sum of |aᵢ·bᵢ|, which bounds the condition of
        // the sum) of the exact path on every probe size.
        let _g = global_lock();
        let mut rng = Rng::seed_from(0xFA57);
        for n in probe_sizes() {
            let (a, b) = vecs(&mut rng, n);
            let exact = scalar::dot(&a, &b);
            let denom = 1.0 + a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>();
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                let fused = unsafe { x86::dot_fma(&a, &b) };
                assert!(
                    (fused - exact).abs() / denom <= 1e-12,
                    "fma dot off by {} at n = {n}",
                    (fused - exact).abs()
                );
            }
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                let fused = unsafe { neon::dot_fma(&a, &b) };
                assert!(
                    (fused - exact).abs() / denom <= 1e-12,
                    "fma dot off by {} at n = {n}",
                    (fused - exact).abs()
                );
            }
            // The scalar tier ignores fast_math entirely: identical bits.
            set_fast_math(true);
            force(KernelMode::Scalar).unwrap();
            assert_eq!(dot(&a, &b).to_bits(), exact.to_bits());
            set_fast_math(false);
            force(KernelMode::Auto).unwrap();
        }
    }

    #[test]
    fn gather_axpy_matches_dense_axpy_on_full_index_set() {
        let mut rng = Rng::seed_from(0x6A7);
        for n in [1usize, 7, 32, 33, 127] {
            let (x, y0) = vecs(&mut rng, n);
            let idx: Vec<usize> = (0..n).collect();
            let mut y1 = y0.clone();
            let mut y2 = y0.clone();
            gather_axpy(-0.625, &x, &idx, &mut y1);
            axpy(-0.625, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn l2_block_cols_has_floor_and_scales() {
        assert_eq!(l2_block_cols(0), L2_TARGET_BYTES.max(64));
        assert!(l2_block_cols(usize::MAX) >= 64);
        assert_eq!(l2_block_cols(1024), (L2_TARGET_BYTES / 1024).max(64));
    }
}
