//! AArch64 NEON backend.
//!
//! NEON vectors are 2×f64, so each kernel runs two vector accumulators
//! per 4-element chunk — together they are exactly the scalar
//! reference's four lanes `s0..s3`, reduced with the same
//! `(s0 + s1) + (s2 + s3)` tree (see [`super::scalar`]); the exact paths
//! use separate multiply + add so results are bitwise identical. Only
//! [`dot_fma`] — the `fast_math = true` variant — fuses multiply-add.

#![allow(unsafe_code)]

use std::arch::aarch64::{
    float64x2_t, vaddq_f64, vdupq_n_f64, vfmaq_f64, vgetq_lane_f64, vld1q_f64, vmulq_f64,
    vmulq_n_f64, vst1q_f64,
};

/// `(s0 + s1) + (s2 + s3)` over the two 2-lane accumulators.
#[inline(always)]
unsafe fn reduce4(acc01: float64x2_t, acc23: float64x2_t) -> f64 {
    let s01 = vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01);
    let s23 = vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23);
    s01 + s23
}

/// Exact NEON dot product — bitwise identical to [`super::scalar::dot`].
///
/// # Safety
/// The caller must ensure NEON is available
/// (`is_aarch64_feature_detected!("neon")`) and `b.len() >= a.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(b.len() >= a.len());
    let n = a.len();
    let chunks = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for k in 0..chunks {
        let i = 4 * k;
        acc01 = vaddq_f64(acc01, vmulq_f64(vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i))));
        acc23 = vaddq_f64(acc23, vmulq_f64(vld1q_f64(pa.add(i + 2)), vld1q_f64(pb.add(i + 2))));
    }
    let mut s = reduce4(acc01, acc23);
    for i in 4 * chunks..n {
        s += *pa.add(i) * *pb.add(i);
    }
    s
}

/// FMA-contracted dot product — the `fast_math = true` variant (≤ 1e-12
/// relative deviation from the exact path, pinned by tests).
///
/// # Safety
/// The caller must ensure NEON is available and `b.len() >= a.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn dot_fma(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(b.len() >= a.len());
    let n = a.len();
    let chunks = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for k in 0..chunks {
        let i = 4 * k;
        acc01 = vfmaq_f64(acc01, vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i)));
        acc23 = vfmaq_f64(acc23, vld1q_f64(pa.add(i + 2)), vld1q_f64(pb.add(i + 2)));
    }
    let mut s = reduce4(acc01, acc23);
    for i in 4 * chunks..n {
        s = (*pa.add(i)).mul_add(*pb.add(i), s);
    }
    s
}

/// Exact NEON `y ← y + α·x` — element-wise, bitwise identical to
/// [`super::scalar::axpy`].
///
/// # Safety
/// The caller must ensure NEON is available.
#[target_feature(enable = "neon")]
pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let chunks = n / 2;
    let va = vdupq_n_f64(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 2 * k;
        let vy = vld1q_f64(py.add(i));
        let vx = vld1q_f64(px.add(i));
        vst1q_f64(py.add(i), vaddq_f64(vy, vmulq_f64(va, vx)));
    }
    for i in 2 * chunks..n {
        *py.add(i) += alpha * *px.add(i);
    }
}

/// Exact NEON `x ← α·x` — element-wise, bitwise identical to
/// [`super::scalar::scale`].
///
/// # Safety
/// The caller must ensure NEON is available.
#[target_feature(enable = "neon")]
pub unsafe fn scale(alpha: f64, x: &mut [f64]) {
    let n = x.len();
    let chunks = n / 2;
    let px = x.as_mut_ptr();
    for k in 0..chunks {
        let i = 2 * k;
        vst1q_f64(px.add(i), vmulq_n_f64(vld1q_f64(px.add(i)), alpha));
    }
    for i in 2 * chunks..n {
        *px.add(i) *= alpha;
    }
}
