//! x86-64 AVX2 backend.
//!
//! Each kernel reproduces the scalar reference order from
//! [`super::scalar`] exactly — the 4-wide vertical accumulate *is* the
//! scalar code's four lanes `s0..s3`, and the horizontal reduction is
//! the same `(s0 + s1) + (s2 + s3)` tree, so results are bitwise
//! identical (the exact paths use separate `vmulpd`/`vaddpd`, never a
//! fused multiply-add). Only [`dot_fma`] — the `fast_math = true`
//! variant — contracts multiply-add pairs and may deviate by one
//! rounding per term.

#![allow(unsafe_code)]

use std::arch::x86_64::{
    _mm256_add_pd, _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
    _mm256_setzero_pd, _mm256_storeu_pd,
};

/// Exact AVX2 dot product — bitwise identical to [`super::scalar::dot`].
///
/// # Safety
/// The caller must ensure AVX2 is available
/// (`is_x86_feature_detected!("avx2")`) and `b.len() >= a.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(b.len() >= a.len());
    let n = a.len();
    let chunks = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    // Vertical accumulation: lane j of `acc` is exactly the scalar
    // reference's accumulator s_j (same multiplies, same adds, same
    // rounding at every step).
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        let va = _mm256_loadu_pd(pa.add(i));
        let vb = _mm256_loadu_pd(pb.add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in 4 * chunks..n {
        s += *pa.add(i) * *pb.add(i);
    }
    s
}

/// FMA-contracted dot product — the `fast_math = true` variant. Deviates
/// from the exact path by at most one rounding per term (≤ 1e-12
/// relative in practice, pinned by tests).
///
/// # Safety
/// The caller must ensure AVX2 and FMA are available and
/// `b.len() >= a.len()`.
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub unsafe fn dot_fma(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(b.len() >= a.len());
    let n = a.len();
    let chunks = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        let va = _mm256_loadu_pd(pa.add(i));
        let vb = _mm256_loadu_pd(pb.add(i));
        acc = _mm256_fmadd_pd(va, vb, acc);
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in 4 * chunks..n {
        s = (*pa.add(i)).mul_add(*pb.add(i), s);
    }
    s
}

/// Exact AVX2 `y ← y + α·x` — element-wise, bitwise identical to
/// [`super::scalar::axpy`].
///
/// # Safety
/// The caller must ensure AVX2 is available.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let va = _mm256_set1_pd(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let vy = _mm256_loadu_pd(py.add(i));
        let vx = _mm256_loadu_pd(px.add(i));
        _mm256_storeu_pd(py.add(i), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
    }
    for i in 4 * chunks..n {
        *py.add(i) += alpha * *px.add(i);
    }
}

/// Exact AVX2 `x ← α·x` — element-wise, bitwise identical to
/// [`super::scalar::scale`].
///
/// # Safety
/// The caller must ensure AVX2 is available.
#[target_feature(enable = "avx2")]
pub unsafe fn scale(alpha: f64, x: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(alpha);
    let px = x.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let vx = _mm256_loadu_pd(px.add(i));
        _mm256_storeu_pd(px.add(i), _mm256_mul_pd(vx, va));
    }
    for i in 4 * chunks..n {
        *px.add(i) *= alpha;
    }
}
