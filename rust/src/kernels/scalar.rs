//! Portable scalar kernels — the reference the SIMD tiers must match
//! bitwise.
//!
//! These bodies *define* the crate's floating-point evaluation orders.
//! They are the former `linalg::vec` loops, moved here verbatim so the
//! dispatch layer has a single authoritative scalar implementation; the
//! AVX2/NEON backends reproduce each order exactly (see the module docs
//! on [`crate::kernels`]). Written as simple indexable loops that LLVM
//! auto-vectorizes well even without the explicit SIMD tiers.

/// Dot product with a fixed 4-lane reduction tree:
/// `(s0 + s1) + (s2 + s3)` over 4-element chunks, sequential remainder.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y ← y + α·x` over `min(x.len(), y.len())` entries.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x ← α·x`.
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// `y[k] ← y[k] + α·x[k]` for each `k` in `idx`, in index order.
pub fn gather_axpy(alpha: f64, x: &[f64], idx: &[usize], y: &mut [f64]) {
    for &k in idx {
        y[k] += alpha * x[k];
    }
}
