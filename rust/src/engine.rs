//! Compute engines: the same solver operations behind one trait, with a
//! pure-native implementation and an AOT/XLA-artifact implementation.
//!
//! - [`NativeEngine`] — optimized Rust (the §Perf hot path).
//! - `XlaEngine` (feature `xla`) — executes the L2 JAX graphs (which call
//!   the L1 Pallas kernels) AOT-compiled to `artifacts/*.hlo.txt`,
//!   through the PJRT runtime. Artifacts are shape-static, so problems
//!   are zero-padded up to the nearest compiled size (see DESIGN.md
//!   "Fixed shapes and masking" — padded features have `Σ_ii = 0 < λ` and
//!   never enter the support; their diagonal settles at `x ≈ β/(λ+t)`, a
//!   vanishing perturbation).
//!
//! Engines consume Σ through `&dyn CovOp`: the native engine works on
//! any operator (dense, implicit Gram, masked, deflated); the XLA engine
//! must ship an explicit matrix to the accelerator and declares that via
//! [`Engine::requires_dense`] — [`bca_solve`] then materializes a
//! non-dense operator once per solve.
//!
//! The two engines are cross-checked for numerical agreement in
//! `rust/tests/engine_agreement.rs` and raced in `benches/engines.rs`.

#[cfg(feature = "xla")]
use std::path::Path;

use crate::covop::CovOp;
use crate::data::SymMat;
use crate::error::LsspcaError;
#[cfg(feature = "xla")]
use crate::runtime::{Runtime, TensorF64};
use crate::solver::bca::{self, BcaOptions, BcaSolution, SolverWorkspace};

/// Abstract compute engine for the solver's heavy operations.
pub trait Engine {
    fn name(&self) -> &str;

    /// Called once at the start of every [`bca_solve`]: a solve boundary.
    /// Engines with cross-sweep state (the native warm-start cache) drop
    /// anything tied to the previous problem here, so a reused engine
    /// solves each (Σ, λ) exactly like a fresh one.
    fn begin_solve(&mut self) {}

    /// Whether this engine needs an explicit dense Σ (`CovOp::as_dense`).
    /// [`bca_solve`] materializes non-dense operators once per solve for
    /// such engines instead of failing mid-sweep.
    fn requires_dense(&self) -> bool {
        false
    }

    /// One full Algorithm-1 sweep over all columns of `x` in place;
    /// returns the largest entry change.
    fn bca_sweep(
        &mut self,
        x: &mut SymMat,
        sigma: &dyn CovOp,
        lambda: f64,
        beta: f64,
        opts: &BcaOptions,
    ) -> Result<f64, LsspcaError>;

    /// `iters` rounds of power iteration from `v0`; returns (vector, value).
    fn power_iter(
        &mut self,
        sigma: &dyn CovOp,
        v0: &[f64],
    ) -> Result<(Vec<f64>, f64), LsspcaError>;

    /// Gram matrix `AᵀA/m` of a dense row-major `m × n` block.
    fn gram(&mut self, m_rows: usize, n: usize, data: &[f64]) -> Result<SymMat, LsspcaError> {
        let _ = self.name();
        Ok(SymMat::gram(m_rows, n, data))
    }

    /// Per-column `(sum, sum of squares)` of a dense row-major block —
    /// the dense-shard moment-pass primitive.
    fn col_moments(
        &mut self,
        m_rows: usize,
        n: usize,
        data: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>), LsspcaError> {
        let _ = self.name();
        assert_eq!(data.len(), m_rows * n);
        let mut s = vec![0.0; n];
        let mut ss = vec![0.0; n];
        for r in 0..m_rows {
            let row = &data[r * n..(r + 1) * n];
            for j in 0..n {
                let v = row[j];
                s[j] += v;
                ss[j] += v * v;
            }
        }
        Ok((s, ss))
    }
}

/// Run the full BCA solve on any engine (shared outer loop). For engines
/// that [`Engine::requires_dense`], a non-dense operator is materialized
/// once here (not per sweep).
pub fn bca_solve(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    lambda: f64,
    opts: &BcaOptions,
) -> Result<BcaSolution, LsspcaError> {
    engine.begin_solve();
    let dense_holder;
    let sigma: &dyn CovOp = if engine.requires_dense() && sigma.as_dense().is_none() {
        dense_holder = sigma.materialize_full();
        &dense_holder
    } else {
        sigma
    };
    bca::solve_with(sigma, lambda, opts, |x, o| {
        let beta = o.epsilon / x.n() as f64;
        engine.bca_sweep(x, sigma, lambda, beta, o)
    })
}

// ---------------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------------

/// Pure-Rust engine (no artifacts needed). Holds the persistent
/// [`SolverWorkspace`] so repeated sweeps/solves warm-start each column's
/// box-QP, and a thread knob for its parallel Gram kernel.
#[derive(Default)]
pub struct NativeEngine {
    workspace: Option<SolverWorkspace>,
    threads: usize,
}

impl NativeEngine {
    /// Engine with a fresh workspace and automatic threading.
    pub fn new() -> NativeEngine {
        NativeEngine::default()
    }

    /// Set the worker-thread count for parallel kernels (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> NativeEngine {
        self.threads = threads;
        self
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn begin_solve(&mut self) {
        if let Some(ws) = &mut self.workspace {
            ws.reset();
        }
    }

    fn bca_sweep(
        &mut self,
        x: &mut SymMat,
        sigma: &dyn CovOp,
        lambda: f64,
        beta: f64,
        opts: &BcaOptions,
    ) -> Result<f64, LsspcaError> {
        let n = x.n();
        let ws = match &mut self.workspace {
            Some(w) if w.n() == n => w,
            _ => {
                self.workspace = Some(SolverWorkspace::new(n));
                self.workspace.as_mut().unwrap()
            }
        };
        Ok(bca::sweep_ws(x, sigma, lambda, beta, opts, ws))
    }

    fn gram(&mut self, m_rows: usize, n: usize, data: &[f64]) -> Result<SymMat, LsspcaError> {
        Ok(crate::cov::gram_parallel(m_rows, n, data, self.threads))
    }

    fn power_iter(
        &mut self,
        sigma: &dyn CovOp,
        v0: &[f64],
    ) -> Result<(Vec<f64>, f64), LsspcaError> {
        let n = sigma.n();
        assert_eq!(v0.len(), n);
        let mut v = v0.to_vec();
        crate::linalg::vec::normalize(&mut v);
        let mut av = vec![0.0; n];
        for _ in 0..XLA_POWER_ITERS {
            sigma.matvec(&v, &mut av);
            crate::linalg::vec::normalize(&mut av);
            std::mem::swap(&mut v, &mut av);
        }
        sigma.matvec(&v, &mut av);
        let value = crate::linalg::vec::dot(&v, &av);
        Ok((v, value))
    }
}

// ---------------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------------

/// Shape-static artifact sizes emitted by `python/compile/aot.py`.
/// Keep in sync with `SIZES` there.
pub const XLA_SIZES: [usize; 5] = [32, 64, 128, 256, 512];
/// QP coordinate-descent sweeps baked into the Pallas kernel.
pub const XLA_QP_SWEEPS: usize = 8;
/// Power-iteration rounds baked into the power artifact.
pub const XLA_POWER_ITERS: usize = 100;
/// Gram artifact block shape (rows × cols).
pub const XLA_GRAM_BLOCK: (usize, usize) = (256, 512);
/// Col-moments artifact block shape (rows × cols).
pub const XLA_MOMENTS_BLOCK: (usize, usize) = (1024, 512);

/// Engine executing the AOT artifacts through PJRT. Requires the `xla`
/// feature (off by default so the build is dependency-free offline).
#[cfg(feature = "xla")]
pub struct XlaEngine {
    rt: Runtime,
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Load all artifacts from a directory (run `make artifacts` first).
    pub fn load(dir: &Path) -> Result<XlaEngine, LsspcaError> {
        let mut rt = Runtime::new().map_err(|e| LsspcaError::io(format!("{e:#}")))?;
        rt.load_dir(dir).map_err(|e| LsspcaError::io(format!("{e:#}")))?;
        Ok(XlaEngine { rt })
    }

    /// Smallest compiled size ≥ n.
    pub fn padded_size(n: usize) -> Result<usize, LsspcaError> {
        XLA_SIZES.iter().copied().find(|&s| s >= n).ok_or_else(|| {
            LsspcaError::numeric(format!(
                "problem size {n} exceeds largest artifact {}",
                XLA_SIZES[4]
            ))
        })
    }

    /// Match the kernel's fixed inner-iteration budget on the native side
    /// (used by the agreement tests to compare like for like).
    pub fn matching_native_opts(opts: &BcaOptions) -> BcaOptions {
        let mut o = *opts;
        o.qp.max_sweeps = XLA_QP_SWEEPS;
        o.qp.tol = 0.0;
        o
    }
}

#[cfg(feature = "xla")]
impl Engine for XlaEngine {
    fn name(&self) -> &str {
        "xla"
    }

    fn requires_dense(&self) -> bool {
        true
    }

    fn bca_sweep(
        &mut self,
        x: &mut SymMat,
        sigma: &dyn CovOp,
        lambda: f64,
        beta: f64,
        _opts: &BcaOptions,
    ) -> Result<f64, LsspcaError> {
        let sigma = sigma.as_dense().ok_or_else(|| {
            LsspcaError::numeric("xla engine needs a dense covariance (see bca_solve)")
        })?;
        let n = x.n();
        let np = Self::padded_size(n)?;
        let name = format!("bca_sweep_n{np}");
        let xp = if np == n { x.clone() } else { x.pad_to(np) };
        let sp = if np == n { sigma.clone() } else { sigma.pad_to(np) };
        let out = self
            .rt
            .execute(
                &name,
                &[
                    TensorF64::new(xp.as_slice().to_vec(), &[np, np]),
                    TensorF64::new(sp.as_slice().to_vec(), &[np, np]),
                    TensorF64::scalar(lambda),
                    TensorF64::scalar(beta),
                ],
            )
            .map_err(|e| LsspcaError::numeric(format!("{e:#}")))?;
        let new_x = &out[0];
        if new_x.len() != np * np {
            return Err(LsspcaError::numeric(format!(
                "artifact returned {} values, want {}",
                new_x.len(),
                np * np
            )));
        }
        // Copy the active block back, tracking the largest change.
        let mut max_delta = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let v = new_x[i * np + j];
                let d = (v - x.get(i, j)).abs();
                if d > max_delta {
                    max_delta = d;
                }
            }
        }
        for i in 0..n {
            for j in i..n {
                // symmetrize vs FP drift between the (i,j)/(j,i) lanes
                let v = 0.5 * (new_x[i * np + j] + new_x[j * np + i]);
                x.set(i, j, v);
            }
        }
        Ok(max_delta)
    }

    fn power_iter(
        &mut self,
        sigma: &dyn CovOp,
        v0: &[f64],
    ) -> Result<(Vec<f64>, f64), LsspcaError> {
        let dense_holder;
        let sigma: &SymMat = match sigma.as_dense() {
            Some(d) => d,
            None => {
                dense_holder = sigma.materialize_full();
                &dense_holder
            }
        };
        let n = SymMat::n(sigma);
        let np = Self::padded_size(n)?;
        let name = format!("power_iter_n{np}");
        let sp = if np == n { sigma.clone() } else { sigma.pad_to(np) };
        let mut v0p = v0.to_vec();
        v0p.resize(np, 0.0);
        let out = self
            .rt
            .execute(
                &name,
                &[
                    TensorF64::new(sp.as_slice().to_vec(), &[np, np]),
                    TensorF64::new(v0p, &[np]),
                ],
            )
            .map_err(|e| LsspcaError::numeric(format!("{e:#}")))?;
        let mut v = out[0].clone();
        v.truncate(n);
        let value = out[1][0];
        Ok((v, value))
    }

    fn col_moments(
        &mut self,
        m_rows: usize,
        n: usize,
        data: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>), LsspcaError> {
        assert_eq!(data.len(), m_rows * n);
        let (bm, bn) = XLA_MOMENTS_BLOCK;
        if n > bn {
            return Err(LsspcaError::numeric(format!(
                "col_moments block supports n ≤ {bn}, got {n}"
            )));
        }
        let name = format!("col_moments_b{bm}x{bn}");
        let mut s = vec![0.0f64; n];
        let mut ss = vec![0.0f64; n];
        let mut row = 0;
        while row < m_rows {
            let rows_here = (m_rows - row).min(bm);
            let mut block = vec![0.0f64; bm * bn];
            for r in 0..rows_here {
                let src = &data[(row + r) * n..(row + r + 1) * n];
                block[r * bn..r * bn + n].copy_from_slice(src);
            }
            let out = self
                .rt
                .execute(&name, &[TensorF64::new(block, &[bm, bn])])
                .map_err(|e| LsspcaError::numeric(format!("{e:#}")))?;
            for j in 0..n {
                s[j] += out[0][j];
                ss[j] += out[1][j];
            }
            row += rows_here;
        }
        Ok((s, ss))
    }

    fn gram(&mut self, m_rows: usize, n: usize, data: &[f64]) -> Result<SymMat, LsspcaError> {
        assert_eq!(data.len(), m_rows * n);
        let (bm, bn) = XLA_GRAM_BLOCK;
        if n > bn {
            return Err(LsspcaError::numeric(format!(
                "gram block supports n ≤ {bn}, got {n}"
            )));
        }
        let name = format!("gram_b{bm}x{bn}");
        // Accumulate AᵀA over zero-padded row blocks.
        let mut acc = vec![0.0f64; bn * bn];
        let mut row = 0;
        while row < m_rows {
            let rows_here = (m_rows - row).min(bm);
            let mut block = vec![0.0f64; bm * bn];
            for r in 0..rows_here {
                let src = &data[(row + r) * n..(row + r + 1) * n];
                block[r * bn..r * bn + n].copy_from_slice(src);
            }
            let out = self
                .rt
                .execute(&name, &[TensorF64::new(block, &[bm, bn])])
                .map_err(|e| LsspcaError::numeric(format!("{e:#}")))?;
            for (a, b) in acc.iter_mut().zip(&out[0]) {
                *a += b;
            }
            row += rows_here;
        }
        let inv = 1.0 / m_rows as f64;
        let mut g = SymMat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                g.as_mut_slice()[i * n + j] = acc[i * bn + j] * inv;
            }
        }
        g.symmetrize();
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[cfg(feature = "xla")]
    #[test]
    fn padded_size_selection() {
        assert_eq!(XlaEngine::padded_size(1).unwrap(), 32);
        assert_eq!(XlaEngine::padded_size(32).unwrap(), 32);
        assert_eq!(XlaEngine::padded_size(33).unwrap(), 64);
        assert_eq!(XlaEngine::padded_size(512).unwrap(), 512);
        assert!(XlaEngine::padded_size(513).is_err());
    }

    #[test]
    fn native_engine_solves() {
        let mut rng = Rng::seed_from(151);
        let sigma = SymMat::random_psd(8, 20, 0.1, &mut rng);
        let mut eng = NativeEngine::new();
        let sol = bca_solve(&mut eng, &sigma, 0.05, &BcaOptions::default()).unwrap();
        assert!(sol.phi.is_finite());
        // equals the direct solver
        let direct = bca::solve(&sigma, 0.05, &BcaOptions::default());
        assert!((sol.phi - direct.phi).abs() < 1e-9);
    }

    #[test]
    fn native_power_iter_matches_linalg() {
        let mut rng = Rng::seed_from(152);
        let sigma = SymMat::random_psd(10, 30, 0.1, &mut rng);
        let mut eng = NativeEngine::new();
        let v0 = rng.gauss_vec(10);
        let (_, value) = eng.power_iter(&sigma, &v0).unwrap();
        let eig = crate::linalg::eig::JacobiEig::new(&sigma);
        assert!((value - eig.lambda_max()).abs() < 1e-3 * (1.0 + eig.lambda_max()));
    }

    #[test]
    fn default_gram_matches_symmat() {
        let mut rng = Rng::seed_from(153);
        let (m, n) = (7, 5);
        let data: Vec<f64> = (0..m * n).map(|_| rng.gauss()).collect();
        let mut eng = NativeEngine::new();
        let g = eng.gram(m, n, &data).unwrap();
        let want = SymMat::gram(m, n, &data);
        for i in 0..n {
            for j in 0..n {
                assert!((g.get(i, j) - want.get(i, j)).abs() < 1e-12);
            }
        }
    }
}
