//! Data structures and on-disk formats: dense symmetric matrices, sparse
//! matrices (triplet/CSR/CSC), the UCI bag-of-words `docword` format,
//! vocabulary files, and the out-of-core corpus shard cache.

pub mod docword;
pub mod shardcache;
pub mod sparse;
pub mod sym;
pub mod vocab;

pub use docword::{DocwordHeader, DocwordReader, DocwordWriter};
pub use shardcache::{ShardCacheKey, ShardManifest};
pub use sparse::{CscMatrix, CsrMatrix, TripletMatrix};
pub use sym::SymMat;
pub use vocab::Vocab;
