//! Data structures and on-disk formats: dense symmetric matrices, sparse
//! matrices (triplet/CSR/CSC), the UCI bag-of-words `docword` format and
//! vocabulary files.

pub mod docword;
pub mod sparse;
pub mod sym;
pub mod vocab;

pub use docword::{DocwordHeader, DocwordReader, DocwordWriter};
pub use sparse::{CscMatrix, CsrMatrix, TripletMatrix};
pub use sym::SymMat;
pub use vocab::Vocab;
