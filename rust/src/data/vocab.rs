//! Vocabulary files: one word per line, line number = 1-based word id,
//! matching the UCI `vocab.*.txt` companions of the docword files.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::LsspcaError;

/// An ordered vocabulary with reverse lookup.
#[derive(Clone, Debug, Default)]
pub struct Vocab {
    words: Vec<String>,
}

impl Vocab {
    /// Wrap an ordered word list (index = 0-based id).
    pub fn new(words: Vec<String>) -> Vocab {
        Vocab { words }
    }

    /// Load from a one-word-per-line file.
    pub fn load(path: &Path) -> Result<Vocab, LsspcaError> {
        let f = std::fs::File::open(path)
            .map_err(|e| LsspcaError::io_at(path, format!("open vocab: {e}")))?;
        let mut words = Vec::new();
        for line in BufReader::new(f).lines() {
            let line = line.map_err(|e| LsspcaError::io_at(path, format!("read vocab: {e}")))?;
            words.push(line.trim().to_string());
        }
        Ok(Vocab { words })
    }

    /// Save one word per line.
    pub fn save(&self, path: &Path) -> Result<(), LsspcaError> {
        let mut f = std::fs::File::create(path)
            .map_err(|e| LsspcaError::io_at(path, format!("create vocab: {e}")))?;
        for w in &self.words {
            writeln!(f, "{w}").map_err(|e| LsspcaError::io_at(path, format!("write vocab: {e}")))?;
        }
        Ok(())
    }

    /// Number of known words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// Whether no vocabulary was provided.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Word for a 0-based id; synthesizes `word<id>` when out of range or
    /// when no vocabulary was provided (the UCI sets ship metadata-free
    /// variants too).
    pub fn word(&self, id0: usize) -> String {
        self.words
            .get(id0)
            .cloned()
            .unwrap_or_else(|| format!("word{id0}"))
    }

    /// 0-based id of a word, if present.
    pub fn id(&self, word: &str) -> Option<usize> {
        self.words.iter().position(|w| w == word)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_vocab_{}.txt", std::process::id()));
        let v = Vocab::new(vec!["alpha".into(), "beta".into()]);
        v.save(&p).unwrap();
        let v2 = Vocab::load(&p).unwrap();
        assert_eq!(v2.len(), 2);
        assert_eq!(v2.word(1), "beta");
        assert_eq!(v2.id("alpha"), Some(0));
        assert_eq!(v2.id("gamma"), None);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fallback_names() {
        let v = Vocab::default();
        assert!(v.is_empty());
        assert_eq!(v.word(17), "word17");
    }
}
