//! Sparse matrix types for bag-of-words data: triplet (COO) for assembly,
//! CSR (document-major) for streaming passes, CSC (feature-major) for the
//! reduced-covariance gather pass.

/// Coordinate-format sparse matrix (assembly form).
#[derive(Clone, Debug, Default)]
pub struct TripletMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// `(row, col, value)` coordinates, in push order.
    pub entries: Vec<(u32, u32, f64)>,
}

impl TripletMatrix {
    /// Empty matrix with the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        TripletMatrix { rows, cols, entries: Vec::new() }
    }

    /// Append one `(row, col, value)` entry.
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.entries.push((r as u32, c as u32, v));
    }

    /// Stored entries (duplicates not yet folded).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR, summing duplicate coordinates.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(entries.len());
        let mut last: Option<(u32, u32)> = None;
        for &(r, c, v) in &entries {
            if last == Some((r, c)) {
                // duplicate coordinate: fold into the previous entry
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                indptr[r as usize + 1] += 1; // per-row count, prefix-summed below
                last = Some((r, c));
            }
        }
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

/// Compressed sparse row matrix. Rows = documents, cols = features.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// Row (document) count.
    pub rows: usize,
    /// Column (feature) count.
    pub cols: usize,
    /// Row start offsets into `indices`/`values` (`len == rows + 1`).
    pub indptr: Vec<usize>,
    /// Column indices per stored entry.
    pub indices: Vec<u32>,
    /// Stored values, aligned with `indices`.
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Empty matrix with the given shape.
    pub fn empty(rows: usize, cols: usize) -> Self {
        CsrMatrix { rows, cols, indptr: vec![0; rows + 1], indices: Vec::new(), values: Vec::new() }
    }

    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate a row's `(col, value)` pairs.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&c, &v)| (c as usize, v))
    }

    /// Transpose-convert to CSC (feature-major) via counting sort — O(nnz).
    pub fn to_csc(&self) -> CscMatrix {
        let mut colptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            colptr[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            colptr[i + 1] += colptr[i];
        }
        let mut next = colptr.clone();
        let mut rowidx = vec![0u32; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                let dst = next[c];
                rowidx[dst] = r as u32;
                values[dst] = self.values[k];
                next[c] += 1;
            }
        }
        CscMatrix { rows: self.rows, cols: self.cols, colptr, rowidx, values }
    }

    /// Dense row-major copy (test helper; O(rows·cols)).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for (c, v) in self.row(r) {
                d[r * self.cols + c] += v;
            }
        }
        d
    }

    /// `ax[r] = row_r · x` — the forward half of the Gram action.
    ///
    /// This per-row sequential accumulate over ascending column indices
    /// is the *definitional* forward order: the CSC scatter path
    /// ([`CscMatrix::scatter_matvec_into`]) and the out-of-core shard
    /// sweep (`cov_disk::DiskGramCov::stream_ax`) replay exactly this
    /// per-document summation order, so all three are bitwise-identical.
    /// Every entry of `ax` is assigned (no pre-zeroing needed).
    pub fn matvec_into(&self, x: &[f64], ax: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(ax.len(), self.rows);
        for (r, axr) in ax.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (c, v) in self.row(r) {
                acc += v * x[c];
            }
            *axr = acc;
        }
    }

    /// Backward Gram half `y = Aᵀ ax`: zero `y`, then scatter each row
    /// with nonzero `ax[r]` in ascending row order (so each `y[c]`
    /// accumulates its terms in ascending document order — the order the
    /// out-of-core backend's per-column accumulate replays bitwise).
    pub fn t_matvec_into(&self, ax: &[f64], y: &mut [f64]) {
        assert_eq!(ax.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for (r, &a) in ax.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            for (c, v) in self.row(r) {
                y[c] += v * a;
            }
        }
    }

    /// `y = Aᵀ(Ax)` into a caller buffer — the single Gram-action kernel
    /// shared by [`CsrMatrix::gram_action`] and the implicit-Gram
    /// covariance operator (`covop::GramCov`, which swaps the forward
    /// half for the active-column scatter when `x` is sparse).
    pub fn gram_action_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(y.len(), self.cols);
        let mut ax = vec![0.0; self.rows];
        self.matvec_into(x, &mut ax);
        self.t_matvec_into(&ax, y);
    }

    /// y = Aᵀ(Ax) convenience used by tests (covariance action without
    /// forming the covariance).
    pub fn gram_action(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.gram_action_into(x, &mut y);
        y
    }
}

/// Compressed sparse column matrix (feature-major).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    /// Row (document) count.
    pub rows: usize,
    /// Column (feature) count.
    pub cols: usize,
    /// Column start offsets into `rowidx`/`values` (`len == cols + 1`).
    pub colptr: Vec<usize>,
    /// Row indices per stored entry, ascending within each column.
    pub rowidx: Vec<u32>,
    /// Stored values, aligned with `rowidx`.
    pub values: Vec<f64>,
}

impl CscMatrix {
    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate a column's `(row, value)` pairs.
    pub fn col(&self, c: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.colptr[c], self.colptr[c + 1]);
        self.rowidx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&r, &v)| (r as usize, v))
    }

    /// Column nnz.
    pub fn col_nnz(&self, c: usize) -> usize {
        self.colptr[c + 1] - self.colptr[c]
    }

    /// Dot product of two columns — the covariance entry `(AᵀA)_{ij}` up to
    /// scaling. Uses a merge over sorted row indices: O(nnz_i + nnz_j).
    pub fn col_dot(&self, i: usize, j: usize) -> f64 {
        let (mut a, ahi) = (self.colptr[i], self.colptr[i + 1]);
        let (mut b, bhi) = (self.colptr[j], self.colptr[j + 1]);
        let mut acc = 0.0;
        while a < ahi && b < bhi {
            let (ra, rb) = (self.rowidx[a], self.rowidx[b]);
            match ra.cmp(&rb) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[a] * self.values[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        acc
    }

    /// Forward Gram half `ax[d] += A_dc·x[c]` as an ascending column
    /// scatter that *skips inactive columns* (`x[c] == 0`) — the
    /// sparse-`x` fast path behind `GramCov`/`DiskGramCov` probes
    /// (λ-search explained-variance quad forms touch a handful of
    /// columns; the row-major path would still walk every stored entry).
    ///
    /// Requires `ax` pre-zeroed. **Bitwise identical** to
    /// [`CsrMatrix::matvec_into`] for any `x`: rows are column-sorted
    /// (the canonical reduced layout), so sweeping columns in ascending
    /// order delivers each document's terms in exactly the row
    /// accumulate's order; and a skipped `±0.0` term cannot change a
    /// partial sum, because a sum seeded at `+0.0` can never reach
    /// `-0.0` (IEEE round-to-nearest yields `+0.0` for every exact-zero
    /// result of non-`-0.0` addends). Columns are processed in L2-sized
    /// blocks ([`crate::kernels::l2_block_cols`]) so the `x` window and
    /// column pointers stay cache-resident while `ax` streams.
    pub fn scatter_matvec_into(&self, x: &[f64], ax: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(ax.len(), self.rows);
        debug_assert!(ax.iter().all(|&v| v == 0.0), "ax must start zeroed");
        // 8 bytes of x + ~8 bytes of colptr per column in the window.
        let block = crate::kernels::l2_block_cols(16);
        let mut start = 0;
        while start < self.cols {
            let end = (start + block).min(self.cols);
            for (off, &xc) in x[start..end].iter().enumerate() {
                if xc == 0.0 {
                    continue;
                }
                for (d, v) in self.col(start + off) {
                    ax[d] += v * xc;
                }
            }
            start = end;
        }
    }

    /// Sum and sum-of-squares per column (moment pass building block).
    pub fn col_moments(&self, c: usize) -> (f64, f64) {
        let mut s = 0.0;
        let mut ss = 0.0;
        for k in self.colptr[c]..self.colptr[c + 1] {
            let v = self.values[k];
            s += v;
            ss += v * v;
        }
        (s, ss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, ensure, property};

    fn sample_csr() -> CsrMatrix {
        // [[1,0,2],[0,0,0],[3,4,0]]
        let mut t = TripletMatrix::new(3, 3);
        t.push(0, 0, 1.0);
        t.push(0, 2, 2.0);
        t.push(2, 0, 3.0);
        t.push(2, 1, 4.0);
        t.to_csr()
    }

    #[test]
    fn triplet_to_csr_basic() {
        let m = sample_csr();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(m.row(1).count(), 0);
        assert_eq!(m.row(2).collect::<Vec<_>>(), vec![(0, 3.0), (1, 4.0)]);
    }

    #[test]
    fn duplicates_summed() {
        let mut t = TripletMatrix::new(2, 2);
        t.push(0, 1, 1.0);
        t.push(0, 1, 2.5);
        let m = t.to_csr();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0).next(), Some((1, 3.5)));
    }

    #[test]
    fn csr_csc_roundtrip_dense() {
        let m = sample_csr();
        let c = m.to_csc();
        assert_eq!(c.nnz(), m.nnz());
        assert_eq!(c.col(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 3.0)]);
        assert_eq!(c.col(1).collect::<Vec<_>>(), vec![(2, 4.0)]);
        assert_eq!(c.col(2).collect::<Vec<_>>(), vec![(0, 2.0)]);
    }

    #[test]
    fn col_dot_matches_dense() {
        let m = sample_csr();
        let c = m.to_csc();
        let d = m.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                let want: f64 = (0..3).map(|r| d[r * 3 + i] * d[r * 3 + j]).sum();
                assert!((c.col_dot(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prop_scatter_matvec_bitwise_matches_row_major() {
        property("CSC scatter forward == CSR row accumulate, bitwise", 30, |rng| {
            let rows = rng.range(1, 40);
            let cols = rng.range(1, 20);
            let mut t = TripletMatrix::new(rows, cols);
            for _ in 0..rng.below(rows * cols + 1) {
                t.push(rng.below(rows), rng.below(cols), rng.range_f64(-3.0, 3.0));
            }
            let csr = t.to_csr();
            let csc = csr.to_csc();
            // probe with dense, sparse, and signed-zero-bearing x
            for density in [1.0, 0.2, 0.0] {
                let x: Vec<f64> = (0..cols)
                    .map(|_| {
                        if rng.bool(density) {
                            rng.range_f64(-2.0, 2.0)
                        } else if rng.bool(0.5) {
                            0.0
                        } else {
                            -0.0
                        }
                    })
                    .collect();
                let mut by_rows = vec![0.0; rows];
                csr.matvec_into(&x, &mut by_rows);
                let mut by_cols = vec![0.0; rows];
                csc.scatter_matvec_into(&x, &mut by_cols);
                for (a, b) in by_rows.iter().zip(&by_cols) {
                    ensure(a.to_bits() == b.to_bits(), "forward halves must agree bitwise")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_action_split_halves_compose() {
        let m = sample_csr();
        let x = [0.5, -1.0, 2.0];
        let mut ax = vec![0.0; 3];
        m.matvec_into(&x, &mut ax);
        let mut y = vec![0.0; 3];
        m.t_matvec_into(&ax, &mut y);
        let whole = m.gram_action(&x);
        for (a, b) in y.iter().zip(&whole) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn prop_roundtrip_and_moments() {
        property("sparse roundtrips", 30, |rng| {
            let rows = rng.range(1, 12);
            let cols = rng.range(1, 12);
            let mut t = TripletMatrix::new(rows, cols);
            let nnz = rng.below(rows * cols + 1);
            for _ in 0..nnz {
                t.push(rng.below(rows), rng.below(cols), rng.range_f64(-3.0, 3.0));
            }
            let csr = t.to_csr();
            let d = csr.to_dense();
            let csc = csr.to_csc();
            ensure(csc.nnz() == csr.nnz(), "nnz preserved")?;
            for c in 0..cols {
                let (s, ss) = csc.col_moments(c);
                let want_s: f64 = (0..rows).map(|r| d[r * cols + c]).sum();
                let want_ss: f64 = (0..rows).map(|r| d[r * cols + c].powi(2)).sum();
                close(s, want_s, 1e-10)?;
                close(ss, want_ss, 1e-10)?;
            }
            // gram_action equals dense AᵀA x
            let x: Vec<f64> = (0..cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = csr.gram_action(&x);
            for i in 0..cols {
                let mut want = 0.0;
                for j in 0..cols {
                    let mut aa = 0.0;
                    for r in 0..rows {
                        aa += d[r * cols + i] * d[r * cols + j];
                    }
                    want += aa * x[j];
                }
                close(y[i], want, 1e-9)?;
            }
            Ok(())
        });
    }
}
