//! The UCI "Bag of Words" `docword` on-disk format, exactly as used by the
//! paper's NYTimes and PubMed data sets:
//!
//! ```text
//! D            <- number of documents
//! W            <- vocabulary size
//! NNZ          <- number of (doc, word) pairs
//! docID wordID count     <- 1-based ids, one triple per line
//! ...
//! ```
//!
//! Files may be gzip-compressed (`.gz` suffix), matching the UCI
//! distribution. The reader streams documents in bounded-size chunks so a
//! 7.8 GB PubMed-scale file never needs to fit in memory — this is the
//! property the paper's pre-processing pass depends on.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::deadletter::{BadRecordReason, RecordPolicy};
use crate::error::LsspcaError;
use crate::util::faultinject;
use crate::util::gzip::{GzDecoder, GzEncoder};

/// Header of a docword file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DocwordHeader {
    /// Declared document count D.
    pub num_docs: usize,
    /// Declared vocabulary size W.
    pub vocab_size: usize,
    /// Declared nonzero count NNZ.
    pub nnz: usize,
}

/// One document: sorted `(word_id_0based, count)` pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    /// 0-based document id (file order).
    pub id: usize,
    /// Sorted `(word_id_0based, count)` pairs.
    pub words: Vec<(u32, f64)>,
}

/// A chunk of consecutive documents, the unit handed to moment workers.
#[derive(Clone, Debug, Default)]
pub struct DocChunk {
    /// Consecutive documents, in file order.
    pub docs: Vec<Doc>,
}

impl DocChunk {
    /// Stored `(word, count)` pairs across the chunk.
    pub fn total_nnz(&self) -> usize {
        self.docs.iter().map(|d| d.words.len()).sum()
    }
}

fn open_maybe_gz(path: &Path) -> std::io::Result<Box<dyn BufRead + Send>> {
    let f = faultinject::wrap_read("docword", File::open(path)?);
    if path.extension().is_some_and(|e| e == "gz") {
        // Inner BufReader feeds the decoder's byte-at-a-time bit reader
        // from memory (one syscall per compressed byte otherwise); the
        // outer one buffers decompressed lines.
        let compressed = BufReader::with_capacity(1 << 16, f);
        Ok(Box::new(BufReader::with_capacity(1 << 20, GzDecoder::new(compressed))))
    } else {
        Ok(Box::new(BufReader::with_capacity(1 << 20, f)))
    }
}

/// Streaming reader over a docword file.
pub struct DocwordReader {
    header: DocwordHeader,
    lines: std::io::Lines<Box<dyn BufRead + Send>>,
    /// Lookahead triple that belongs to the next document.
    pending: Option<(usize, u32, f64)>,
    docs_seen: usize,
    nnz_seen: usize,
    /// 1-based data-line counter (the dead-letter `offset`).
    data_line: u64,
    /// Last docID seen (1-based), for the monotonicity check.
    last_doc: Option<usize>,
    /// `Some` = quarantine malformed records instead of aborting.
    policy: Option<RecordPolicy>,
}

impl DocwordReader {
    /// Open a (possibly gzipped) docword file and parse the header.
    /// A filesystem failure is [`LsspcaError::Io`]; a present-but-
    /// malformed header is [`LsspcaError::Corpus`].
    pub fn open(path: &Path) -> Result<DocwordReader, LsspcaError> {
        DocwordReader::open_with_policy(path, None)
    }

    /// [`open`](DocwordReader::open), optionally with a dead-letter
    /// [`RecordPolicy`]: with a policy, malformed *data* records are
    /// quarantined and skipped (up to the policy's budget) instead of
    /// aborting the stream. The header is always strict — a damaged
    /// header means there is no trustworthy stream to salvage.
    pub fn open_with_policy(
        path: &Path,
        policy: Option<RecordPolicy>,
    ) -> Result<DocwordReader, LsspcaError> {
        let reader = open_maybe_gz(path)
            .map_err(|e| LsspcaError::io_at(path, format!("open docword: {e}")))?;
        let mut lines = reader.lines();
        let mut next_header = |what: &str| -> Result<usize, LsspcaError> {
            let line = lines
                .next()
                .ok_or_else(|| LsspcaError::corpus(format!("truncated header: missing {what}")))?
                .map_err(|e| LsspcaError::corpus(format!("read error in header: {e}")))?;
            line.trim()
                .parse::<usize>()
                .map_err(|_| LsspcaError::corpus(format!("bad {what} line: '{line}'")))
        };
        let num_docs = next_header("D")?;
        let vocab_size = next_header("W")?;
        let nnz = next_header("NNZ")?;
        Ok(DocwordReader {
            header: DocwordHeader { num_docs, vocab_size, nnz },
            lines,
            pending: None,
            docs_seen: 0,
            nnz_seen: 0,
            data_line: 0,
            last_doc: None,
            policy,
        })
    }

    /// The file's declared `(D, W, NNZ)` header.
    pub fn header(&self) -> DocwordHeader {
        self.header
    }

    /// Distinct records quarantined by this reader's policy across all
    /// passes (0 when running strict).
    pub fn bad_records(&self) -> u64 {
        self.policy.as_ref().map_or(0, RecordPolicy::quarantined)
    }

    /// Strict mode: abort with a corpus error. Quarantine mode: spill the
    /// record to the dead-letter queue and let the caller skip it (the
    /// budget check inside [`RecordPolicy::admit`] may still abort).
    fn reject(
        &mut self,
        reason: BadRecordReason,
        detail: String,
        line: &str,
    ) -> Result<(), LsspcaError> {
        match self.policy.as_mut() {
            None => Err(LsspcaError::corpus(detail)),
            Some(p) => p.admit(self.data_line, reason, &detail, line),
        }
    }

    fn next_triple(&mut self) -> Result<Option<(usize, u32, f64)>, LsspcaError> {
        if let Some(t) = self.pending.take() {
            return Ok(Some(t));
        }
        loop {
            let line = match self.lines.next() {
                None => return Ok(None),
                Some(Ok(l)) => l,
                Some(Err(e)) => {
                    let detail = format!("read error: {e}");
                    // A gzip member whose CRC32 trailer fails is damage,
                    // not formatting: quarantine the event, then stop —
                    // the decompressed stream past it is untrustworthy.
                    if self.policy.is_some() && e.to_string().contains("CRC32 mismatch") {
                        self.data_line += 1;
                        self.reject(BadRecordReason::GzipCrc, detail, "")?;
                        return Ok(None);
                    }
                    return Err(LsspcaError::corpus(detail));
                }
            };
            self.data_line += 1;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let mut it = trimmed.split_ascii_whitespace();
            let Some(doc) = it.next().and_then(|s| s.parse::<usize>().ok()) else {
                self.reject(
                    BadRecordReason::BadDocId,
                    format!("bad docID in line '{trimmed}'"),
                    trimmed,
                )?;
                continue;
            };
            let Some(word) = it.next().and_then(|s| s.parse::<usize>().ok()) else {
                self.reject(
                    BadRecordReason::BadWordId,
                    format!("bad wordID in line '{trimmed}'"),
                    trimmed,
                )?;
                continue;
            };
            let Some(count) = it.next().and_then(|s| s.parse::<f64>().ok()) else {
                self.reject(
                    BadRecordReason::BadCount,
                    format!("bad count in line '{trimmed}'"),
                    trimmed,
                )?;
                continue;
            };
            if doc == 0 || word == 0 {
                self.reject(
                    BadRecordReason::ZeroId,
                    format!("ids are 1-based; got line '{trimmed}'"),
                    trimmed,
                )?;
                continue;
            }
            if word > self.header.vocab_size {
                self.reject(
                    BadRecordReason::WordOutOfRange,
                    format!(
                        "wordID {word} exceeds W={} in line '{trimmed}'",
                        self.header.vocab_size
                    ),
                    trimmed,
                )?;
                continue;
            }
            // UCI files are sorted by docID; a docID going backwards means
            // shuffled or spliced data (equal is fine — same doc continues).
            if let Some(last) = self.last_doc {
                if doc < last {
                    self.reject(
                        BadRecordReason::NonMonotonicDoc,
                        format!("non-monotonic docID {doc} after {last} in line '{trimmed}'"),
                        trimmed,
                    )?;
                    continue;
                }
            }
            self.last_doc = Some(doc);
            self.nnz_seen += 1;
            return Ok(Some((doc - 1, (word - 1) as u32, count)));
        }
    }

    /// Read the next chunk of up to `max_docs` documents. Returns `None` at
    /// end of stream. Triples for one document must be contiguous (UCI files
    /// are sorted by docID).
    pub fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        assert!(max_docs > 0);
        let mut chunk = DocChunk::default();
        let mut cur: Option<Doc> = None;
        loop {
            let triple = self.next_triple()?;
            match triple {
                None => {
                    if let Some(d) = cur.take() {
                        self.docs_seen += 1;
                        chunk.docs.push(d);
                    }
                    break;
                }
                Some((doc_id, w, c)) => {
                    // (match, not Option::is_none_or — that is post-MSRV)
                    let start_new = match &cur {
                        Some(d) => d.id != doc_id,
                        None => true,
                    };
                    if start_new {
                        if let Some(d) = cur.take() {
                            self.docs_seen += 1;
                            chunk.docs.push(d);
                            if chunk.docs.len() >= max_docs {
                                // This triple belongs to the next chunk.
                                self.pending = Some((doc_id, w, c));
                                return Ok(Some(chunk));
                            }
                        }
                        cur = Some(Doc { id: doc_id, words: vec![(w, c)] });
                    } else {
                        cur.as_mut().unwrap().words.push((w, c));
                    }
                }
            }
        }
        if chunk.docs.is_empty() {
            Ok(None)
        } else {
            Ok(Some(chunk))
        }
    }

    /// Documents and nnz consumed so far.
    pub fn progress(&self) -> (usize, usize) {
        (self.docs_seen, self.nnz_seen)
    }
}

/// Writer producing the same format (used by the synthetic corpus
/// generator; `.gz` suffix enables compression).
///
/// Concrete output variants (not `Box<dyn Write>`) so [`finish`]
/// (DocwordWriter::finish) can finalize the gzip trailer *explicitly* and
/// surface its I/O errors — relying on the encoder's Drop would swallow a
/// failed trailer write and leave a silently corrupt file.
enum DocOut {
    Plain(BufWriter<File>),
    Gz(BufWriter<GzEncoder<File>>),
}

impl Write for DocOut {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            DocOut::Plain(w) => w.write(buf),
            DocOut::Gz(w) => w.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            DocOut::Plain(w) => w.flush(),
            DocOut::Gz(w) => w.flush(),
        }
    }
}

/// Streaming writer for the UCI docword format (`.gz` when the path
/// ends in `.gz`).
pub struct DocwordWriter {
    out: DocOut,
    nnz_written: usize,
    declared: DocwordHeader,
}

impl DocwordWriter {
    /// Create the file and write the three-line header.
    pub fn create(path: &Path, header: DocwordHeader) -> Result<DocwordWriter, LsspcaError> {
        let f = File::create(path)
            .map_err(|e| LsspcaError::io_at(path, format!("create docword: {e}")))?;
        let mut out = if path.extension().is_some_and(|e| e == "gz") {
            DocOut::Gz(BufWriter::with_capacity(1 << 20, GzEncoder::new(f)))
        } else {
            DocOut::Plain(BufWriter::with_capacity(1 << 20, f))
        };
        write!(out, "{}\n{}\n{}\n", header.num_docs, header.vocab_size, header.nnz)
            .map_err(|e| LsspcaError::io(format!("write header: {e}")))?;
        Ok(DocwordWriter { out, nnz_written: 0, declared: header })
    }

    /// Write one document's `(word_id_0based, count)` pairs.
    pub fn write_doc(
        &mut self,
        doc_id_0based: usize,
        words: &[(u32, f64)],
    ) -> Result<(), LsspcaError> {
        for &(w, c) in words {
            // counts in UCI files are integers; keep integer formatting when exact
            if c.fract() == 0.0 {
                writeln!(self.out, "{} {} {}", doc_id_0based + 1, w + 1, c as i64)
            } else {
                writeln!(self.out, "{} {} {}", doc_id_0based + 1, w + 1, c)
            }
            .map_err(|e| LsspcaError::io(format!("write doc: {e}")))?;
            self.nnz_written += 1;
        }
        Ok(())
    }

    /// Verify the declared nnz, then flush and finalize (the gzip trailer
    /// is written here, with errors surfaced, not in a silent Drop).
    pub fn finish(self) -> Result<(), LsspcaError> {
        if self.nnz_written != self.declared.nnz {
            return Err(LsspcaError::io(format!(
                "nnz mismatch: declared {} wrote {}",
                self.declared.nnz, self.nnz_written
            )));
        }
        match self.out {
            DocOut::Plain(mut w) => {
                w.flush().map_err(|e| LsspcaError::io(format!("flush: {e}")))?
            }
            DocOut::Gz(w) => {
                let enc = w
                    .into_inner()
                    .map_err(|e| LsspcaError::io(format!("flush gzip buffer: {e}")))?;
                enc.finish()
                    .map_err(|e| LsspcaError::io(format!("finalize gzip stream: {e}")))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_test_{}_{name}", std::process::id()));
        p
    }

    fn write_sample(path: &Path) {
        let hdr = DocwordHeader { num_docs: 3, vocab_size: 5, nnz: 5 };
        let mut w = DocwordWriter::create(path, hdr).unwrap();
        w.write_doc(0, &[(0, 2.0), (3, 1.0)]).unwrap();
        w.write_doc(1, &[(1, 4.0)]).unwrap();
        w.write_doc(2, &[(0, 1.0), (4, 7.0)]).unwrap();
        w.finish().unwrap();
    }

    #[test]
    fn roundtrip_plain() {
        let p = tmpfile("roundtrip.txt");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        assert_eq!(r.header(), DocwordHeader { num_docs: 3, vocab_size: 5, nnz: 5 });
        let chunk = r.next_chunk(10).unwrap().unwrap();
        assert_eq!(chunk.docs.len(), 3);
        assert_eq!(chunk.docs[0].words, vec![(0, 2.0), (3, 1.0)]);
        assert_eq!(chunk.docs[2].words, vec![(0, 1.0), (4, 7.0)]);
        assert!(r.next_chunk(10).unwrap().is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_gzip() {
        let p = tmpfile("roundtrip.txt.gz");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        let chunk = r.next_chunk(10).unwrap().unwrap();
        assert_eq!(chunk.total_nnz(), 5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_boundaries_respected() {
        let p = tmpfile("chunks.txt");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        let c1 = r.next_chunk(2).unwrap().unwrap();
        assert_eq!(c1.docs.len(), 2);
        let c2 = r.next_chunk(2).unwrap().unwrap();
        assert_eq!(c2.docs.len(), 1);
        assert_eq!(c2.docs[0].id, 2);
        assert!(r.next_chunk(2).unwrap().is_none());
        assert_eq!(r.progress().0, 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_based_ids() {
        let p = tmpfile("zerobased.txt");
        std::fs::write(&p, "1\n5\n1\n0 3 1\n").unwrap();
        let mut r = DocwordReader::open(&p).unwrap();
        assert!(r.next_chunk(1).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_out_of_range_word() {
        let p = tmpfile("oor.txt");
        std::fs::write(&p, "1\n5\n1\n1 6 1\n").unwrap();
        let mut r = DocwordReader::open(&p).unwrap();
        assert!(r.next_chunk(1).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn strict_rejects_non_monotonic_doc_ids() {
        let p = tmpfile("nonmono.txt");
        std::fs::write(&p, "3\n5\n3\n1 1 1\n3 1 1\n2 1 1\n").unwrap();
        let mut r = DocwordReader::open(&p).unwrap();
        let err = loop {
            match r.next_chunk(10) {
                Err(e) => break e,
                Ok(Some(_)) => continue,
                Ok(None) => panic!("expected a non-monotonic error"),
            }
        };
        assert!(err.to_string().contains("non-monotonic"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn policy_quarantines_and_stream_continues() {
        use crate::deadletter::{read_records, BadRecordReason, DeadLetterQueue, RecordPolicy};
        let p = tmpfile("quarantine.txt");
        let dlq = tmpfile("quarantine.jsonl");
        std::fs::remove_file(&dlq).ok();
        // data lines: good, bad count, zero id, out-of-range, good,
        // non-monotonic, good (doc 3 continues after the rejected doc 1)
        std::fs::write(
            &p,
            "3\n5\n4\n1 1 2\n1 2 oops\n0 3 1\n2 6 1\n2 2 5\n1 1 9\n3 4 1\n",
        )
        .unwrap();
        let policy = RecordPolicy::new(10, DeadLetterQueue::open(&dlq).unwrap());
        let mut r = DocwordReader::open_with_policy(&p, Some(policy)).unwrap();
        let mut docs = Vec::new();
        while let Some(chunk) = r.next_chunk(2).unwrap() {
            docs.extend(chunk.docs);
        }
        assert_eq!(docs.len(), 3);
        assert_eq!(docs[0].words, vec![(0, 2.0)]);
        assert_eq!(docs[1].words, vec![(1, 5.0)]);
        assert_eq!(docs[2].words, vec![(3, 1.0)]);
        assert_eq!(r.bad_records(), 4);
        let recs = read_records(&dlq).unwrap();
        let reasons: Vec<_> = recs.iter().map(|r| r.reason.unwrap()).collect();
        assert_eq!(
            reasons,
            vec![
                BadRecordReason::BadCount,
                BadRecordReason::ZeroId,
                BadRecordReason::WordOutOfRange,
                BadRecordReason::NonMonotonicDoc,
            ]
        );
        // offsets are 1-based data-line numbers (header excluded)
        assert_eq!(recs.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![2, 3, 4, 6]);
        assert!(recs.iter().all(|r| r.crc_ok));
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&dlq).ok();
    }

    #[test]
    fn policy_budget_aborts_stream() {
        use crate::deadletter::{DeadLetterQueue, RecordPolicy};
        let p = tmpfile("budget.txt");
        let dlq = tmpfile("budget.jsonl");
        std::fs::remove_file(&dlq).ok();
        std::fs::write(&p, "2\n5\n2\n1 1 a\n1 2 b\n2 1 1\n").unwrap();
        let policy = RecordPolicy::new(1, DeadLetterQueue::open(&dlq).unwrap());
        let mut r = DocwordReader::open_with_policy(&p, Some(policy)).unwrap();
        let err = r.next_chunk(10).unwrap_err();
        assert!(err.to_string().contains("too many bad records"), "{err}");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&dlq).ok();
    }

    #[test]
    fn truncated_header_errors() {
        let p = tmpfile("trunc.txt");
        std::fs::write(&p, "10\n").unwrap();
        assert!(DocwordReader::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_verifies_nnz() {
        let p = tmpfile("nnzmismatch.txt");
        let hdr = DocwordHeader { num_docs: 1, vocab_size: 2, nnz: 3 };
        let mut w = DocwordWriter::create(&p, hdr).unwrap();
        w.write_doc(0, &[(0, 1.0)]).unwrap();
        assert!(w.finish().is_err());
        std::fs::remove_file(&p).ok();
    }
}
