//! The UCI "Bag of Words" `docword` on-disk format, exactly as used by the
//! paper's NYTimes and PubMed data sets:
//!
//! ```text
//! D            <- number of documents
//! W            <- vocabulary size
//! NNZ          <- number of (doc, word) pairs
//! docID wordID count     <- 1-based ids, one triple per line
//! ...
//! ```
//!
//! Files may be gzip-compressed (`.gz` suffix), matching the UCI
//! distribution. The reader streams documents in bounded-size chunks so a
//! 7.8 GB PubMed-scale file never needs to fit in memory — this is the
//! property the paper's pre-processing pass depends on.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::LsspcaError;
use crate::util::gzip::{GzDecoder, GzEncoder};

/// Header of a docword file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DocwordHeader {
    /// Declared document count D.
    pub num_docs: usize,
    /// Declared vocabulary size W.
    pub vocab_size: usize,
    /// Declared nonzero count NNZ.
    pub nnz: usize,
}

/// One document: sorted `(word_id_0based, count)` pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    /// 0-based document id (file order).
    pub id: usize,
    /// Sorted `(word_id_0based, count)` pairs.
    pub words: Vec<(u32, f64)>,
}

/// A chunk of consecutive documents, the unit handed to moment workers.
#[derive(Clone, Debug, Default)]
pub struct DocChunk {
    /// Consecutive documents, in file order.
    pub docs: Vec<Doc>,
}

impl DocChunk {
    /// Stored `(word, count)` pairs across the chunk.
    pub fn total_nnz(&self) -> usize {
        self.docs.iter().map(|d| d.words.len()).sum()
    }
}

fn open_maybe_gz(path: &Path) -> std::io::Result<Box<dyn BufRead + Send>> {
    let f = File::open(path)?;
    if path.extension().is_some_and(|e| e == "gz") {
        // Inner BufReader feeds the decoder's byte-at-a-time bit reader
        // from memory (one syscall per compressed byte otherwise); the
        // outer one buffers decompressed lines.
        let compressed = BufReader::with_capacity(1 << 16, f);
        Ok(Box::new(BufReader::with_capacity(1 << 20, GzDecoder::new(compressed))))
    } else {
        Ok(Box::new(BufReader::with_capacity(1 << 20, f)))
    }
}

/// Streaming reader over a docword file.
pub struct DocwordReader {
    header: DocwordHeader,
    lines: std::io::Lines<Box<dyn BufRead + Send>>,
    /// Lookahead triple that belongs to the next document.
    pending: Option<(usize, u32, f64)>,
    docs_seen: usize,
    nnz_seen: usize,
}

impl DocwordReader {
    /// Open a (possibly gzipped) docword file and parse the header.
    /// A filesystem failure is [`LsspcaError::Io`]; a present-but-
    /// malformed header is [`LsspcaError::Corpus`].
    pub fn open(path: &Path) -> Result<DocwordReader, LsspcaError> {
        let reader = open_maybe_gz(path)
            .map_err(|e| LsspcaError::io_at(path, format!("open docword: {e}")))?;
        let mut lines = reader.lines();
        let mut next_header = |what: &str| -> Result<usize, LsspcaError> {
            let line = lines
                .next()
                .ok_or_else(|| LsspcaError::corpus(format!("truncated header: missing {what}")))?
                .map_err(|e| LsspcaError::corpus(format!("read error in header: {e}")))?;
            line.trim()
                .parse::<usize>()
                .map_err(|_| LsspcaError::corpus(format!("bad {what} line: '{line}'")))
        };
        let num_docs = next_header("D")?;
        let vocab_size = next_header("W")?;
        let nnz = next_header("NNZ")?;
        Ok(DocwordReader {
            header: DocwordHeader { num_docs, vocab_size, nnz },
            lines,
            pending: None,
            docs_seen: 0,
            nnz_seen: 0,
        })
    }

    /// The file's declared `(D, W, NNZ)` header.
    pub fn header(&self) -> DocwordHeader {
        self.header
    }

    fn next_triple(&mut self) -> Result<Option<(usize, u32, f64)>, LsspcaError> {
        if let Some(t) = self.pending.take() {
            return Ok(Some(t));
        }
        for line in self.lines.by_ref() {
            let line = line.map_err(|e| LsspcaError::corpus(format!("read error: {e}")))?;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let mut it = trimmed.split_ascii_whitespace();
            let doc: usize = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| LsspcaError::corpus(format!("bad docID in line '{trimmed}'")))?;
            let word: usize = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| LsspcaError::corpus(format!("bad wordID in line '{trimmed}'")))?;
            let count: f64 = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| LsspcaError::corpus(format!("bad count in line '{trimmed}'")))?;
            if doc == 0 || word == 0 {
                return Err(LsspcaError::corpus(format!("ids are 1-based; got line '{trimmed}'")));
            }
            if word > self.header.vocab_size {
                return Err(LsspcaError::corpus(format!(
                    "wordID {word} exceeds W={} in line '{trimmed}'",
                    self.header.vocab_size
                )));
            }
            self.nnz_seen += 1;
            return Ok(Some((doc - 1, (word - 1) as u32, count)));
        }
        Ok(None)
    }

    /// Read the next chunk of up to `max_docs` documents. Returns `None` at
    /// end of stream. Triples for one document must be contiguous (UCI files
    /// are sorted by docID).
    pub fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        assert!(max_docs > 0);
        let mut chunk = DocChunk::default();
        let mut cur: Option<Doc> = None;
        loop {
            let triple = self.next_triple()?;
            match triple {
                None => {
                    if let Some(d) = cur.take() {
                        self.docs_seen += 1;
                        chunk.docs.push(d);
                    }
                    break;
                }
                Some((doc_id, w, c)) => {
                    // (match, not Option::is_none_or — that is post-MSRV)
                    let start_new = match &cur {
                        Some(d) => d.id != doc_id,
                        None => true,
                    };
                    if start_new {
                        if let Some(d) = cur.take() {
                            self.docs_seen += 1;
                            chunk.docs.push(d);
                            if chunk.docs.len() >= max_docs {
                                // This triple belongs to the next chunk.
                                self.pending = Some((doc_id, w, c));
                                return Ok(Some(chunk));
                            }
                        }
                        cur = Some(Doc { id: doc_id, words: vec![(w, c)] });
                    } else {
                        cur.as_mut().unwrap().words.push((w, c));
                    }
                }
            }
        }
        if chunk.docs.is_empty() {
            Ok(None)
        } else {
            Ok(Some(chunk))
        }
    }

    /// Documents and nnz consumed so far.
    pub fn progress(&self) -> (usize, usize) {
        (self.docs_seen, self.nnz_seen)
    }
}

/// Writer producing the same format (used by the synthetic corpus
/// generator; `.gz` suffix enables compression).
///
/// Concrete output variants (not `Box<dyn Write>`) so [`finish`]
/// (DocwordWriter::finish) can finalize the gzip trailer *explicitly* and
/// surface its I/O errors — relying on the encoder's Drop would swallow a
/// failed trailer write and leave a silently corrupt file.
enum DocOut {
    Plain(BufWriter<File>),
    Gz(BufWriter<GzEncoder<File>>),
}

impl Write for DocOut {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            DocOut::Plain(w) => w.write(buf),
            DocOut::Gz(w) => w.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            DocOut::Plain(w) => w.flush(),
            DocOut::Gz(w) => w.flush(),
        }
    }
}

/// Streaming writer for the UCI docword format (`.gz` when the path
/// ends in `.gz`).
pub struct DocwordWriter {
    out: DocOut,
    nnz_written: usize,
    declared: DocwordHeader,
}

impl DocwordWriter {
    /// Create the file and write the three-line header.
    pub fn create(path: &Path, header: DocwordHeader) -> Result<DocwordWriter, LsspcaError> {
        let f = File::create(path)
            .map_err(|e| LsspcaError::io_at(path, format!("create docword: {e}")))?;
        let mut out = if path.extension().is_some_and(|e| e == "gz") {
            DocOut::Gz(BufWriter::with_capacity(1 << 20, GzEncoder::new(f)))
        } else {
            DocOut::Plain(BufWriter::with_capacity(1 << 20, f))
        };
        write!(out, "{}\n{}\n{}\n", header.num_docs, header.vocab_size, header.nnz)
            .map_err(|e| LsspcaError::io(format!("write header: {e}")))?;
        Ok(DocwordWriter { out, nnz_written: 0, declared: header })
    }

    /// Write one document's `(word_id_0based, count)` pairs.
    pub fn write_doc(
        &mut self,
        doc_id_0based: usize,
        words: &[(u32, f64)],
    ) -> Result<(), LsspcaError> {
        for &(w, c) in words {
            // counts in UCI files are integers; keep integer formatting when exact
            if c.fract() == 0.0 {
                writeln!(self.out, "{} {} {}", doc_id_0based + 1, w + 1, c as i64)
            } else {
                writeln!(self.out, "{} {} {}", doc_id_0based + 1, w + 1, c)
            }
            .map_err(|e| LsspcaError::io(format!("write doc: {e}")))?;
            self.nnz_written += 1;
        }
        Ok(())
    }

    /// Verify the declared nnz, then flush and finalize (the gzip trailer
    /// is written here, with errors surfaced, not in a silent Drop).
    pub fn finish(self) -> Result<(), LsspcaError> {
        if self.nnz_written != self.declared.nnz {
            return Err(LsspcaError::io(format!(
                "nnz mismatch: declared {} wrote {}",
                self.declared.nnz, self.nnz_written
            )));
        }
        match self.out {
            DocOut::Plain(mut w) => {
                w.flush().map_err(|e| LsspcaError::io(format!("flush: {e}")))?
            }
            DocOut::Gz(w) => {
                let enc = w
                    .into_inner()
                    .map_err(|e| LsspcaError::io(format!("flush gzip buffer: {e}")))?;
                enc.finish()
                    .map_err(|e| LsspcaError::io(format!("finalize gzip stream: {e}")))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_test_{}_{name}", std::process::id()));
        p
    }

    fn write_sample(path: &Path) {
        let hdr = DocwordHeader { num_docs: 3, vocab_size: 5, nnz: 5 };
        let mut w = DocwordWriter::create(path, hdr).unwrap();
        w.write_doc(0, &[(0, 2.0), (3, 1.0)]).unwrap();
        w.write_doc(1, &[(1, 4.0)]).unwrap();
        w.write_doc(2, &[(0, 1.0), (4, 7.0)]).unwrap();
        w.finish().unwrap();
    }

    #[test]
    fn roundtrip_plain() {
        let p = tmpfile("roundtrip.txt");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        assert_eq!(r.header(), DocwordHeader { num_docs: 3, vocab_size: 5, nnz: 5 });
        let chunk = r.next_chunk(10).unwrap().unwrap();
        assert_eq!(chunk.docs.len(), 3);
        assert_eq!(chunk.docs[0].words, vec![(0, 2.0), (3, 1.0)]);
        assert_eq!(chunk.docs[2].words, vec![(0, 1.0), (4, 7.0)]);
        assert!(r.next_chunk(10).unwrap().is_none());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_gzip() {
        let p = tmpfile("roundtrip.txt.gz");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        let chunk = r.next_chunk(10).unwrap().unwrap();
        assert_eq!(chunk.total_nnz(), 5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_boundaries_respected() {
        let p = tmpfile("chunks.txt");
        write_sample(&p);
        let mut r = DocwordReader::open(&p).unwrap();
        let c1 = r.next_chunk(2).unwrap().unwrap();
        assert_eq!(c1.docs.len(), 2);
        let c2 = r.next_chunk(2).unwrap().unwrap();
        assert_eq!(c2.docs.len(), 1);
        assert_eq!(c2.docs[0].id, 2);
        assert!(r.next_chunk(2).unwrap().is_none());
        assert_eq!(r.progress().0, 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_based_ids() {
        let p = tmpfile("zerobased.txt");
        std::fs::write(&p, "1\n5\n1\n0 3 1\n").unwrap();
        let mut r = DocwordReader::open(&p).unwrap();
        assert!(r.next_chunk(1).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_out_of_range_word() {
        let p = tmpfile("oor.txt");
        std::fs::write(&p, "1\n5\n1\n1 6 1\n").unwrap();
        let mut r = DocwordReader::open(&p).unwrap();
        assert!(r.next_chunk(1).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_header_errors() {
        let p = tmpfile("trunc.txt");
        std::fs::write(&p, "10\n").unwrap();
        assert!(DocwordReader::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_verifies_nnz() {
        let p = tmpfile("nnzmismatch.txt");
        let hdr = DocwordHeader { num_docs: 1, vocab_size: 2, nnz: 3 };
        let mut w = DocwordWriter::create(&p, hdr).unwrap();
        w.write_doc(0, &[(0, 1.0)]).unwrap();
        assert!(w.finish().is_err());
        std::fs::remove_file(&p).ok();
    }
}
