//! The on-disk corpus shard cache — the persistence layer behind the
//! out-of-core covariance backend (`[cov] backend = "disk"`).
//!
//! After safe elimination, the `gram_pass` produces the reduced,
//! doc-id-sorted sparse term matrix `A` (rows = documents with ≥ 1 kept
//! feature, cols = kept features). This module writes that matrix **once**
//! as a set of fixed-byte-budget *column-range shards* plus a manifest,
//! keyed by `(corpus digest, elimination digest)` so later runs on the
//! same corpus and elimination mask reuse the cache without re-streaming
//! the corpus.
//!
//! Why column ranges: every operation the solver needs from the implicit
//! covariance `Σ = AᵀA/m − μμᵀ` decomposes over *feature* (column) blocks
//! of `A` — a Σ-row gather is a set of column dot products, and the
//! second half of a matvec (`y = Aᵀ(Ax)`) writes disjoint `y` ranges per
//! block — so [`crate::cov_disk::DiskGramCov`] can stream one shard at a
//! time under a fixed memory budget, in parallel where the outputs are
//! disjoint. Within each shard, columns store their `(doc, value)` pairs
//! in ascending document order (CSC of the doc-id-sorted CSR), which is
//! exactly the summation order of the in-memory [`crate::covop::GramCov`]
//! kernels — the property that makes disk-backed solves **bitwise
//! identical** to in-memory ones.
//!
//! ## Layout and integrity
//!
//! All files are little-endian with the `checkpoint.rs`-style framing:
//! magic, `u32` version, payload, trailing xor-fold checksum.
//!
//! - `shards_<corpus>_<elim>.lssm` — the manifest: both digests, corpus
//!   document count `m`, reduced shape and nnz, the per-shard column
//!   ranges and payload checksums, and the precomputed per-feature means
//!   and Σ diagonal (so opening the cache costs one small file read, not
//!   a pass over every shard).
//! - `shards_<corpus>_<elim>.s<idx>.lss` — one shard: its index and
//!   column range (cross-checked against the manifest at load), then the
//!   CSC arrays `colptr` / `rowidx` / `values`.
//!
//! Every load path re-verifies checksums and cross-checks the shard
//! header against the manifest record, so a truncated shard, a corrupt
//! manifest, or a stale mix of files from different runs is rejected
//! with an error instead of silently feeding wrong numbers to the solver.
//!
//! The digests and checksums are *integrity* checks (FNV + xor-fold),
//! not authentication: they catch rot, truncation, and staleness, not a
//! co-resident adversary who can write the cache directory. Point
//! `corpus.cache_dir` at a directory you trust; the no-config fallback
//! is a per-user directory created with user-only permissions on Unix.

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::data::sparse::CsrMatrix;
use crate::elim::SafeElimination;
use crate::error::LsspcaError;
use crate::util::xor_fold_checksum as checksum;
use crate::util::{atomic_write, faultinject, retry};

const MANIFEST_MAGIC: &[u8; 4] = b"LSSM";
const SHARD_MAGIC: &[u8; 4] = b"LSSH";
const VERSION: u32 = 1;

/// Identity of a shard cache: which corpus and which elimination mask
/// the shards were built from. Both digests appear in the file names and
/// inside every payload; a mismatch on open means a stale cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardCacheKey {
    /// FNV-1a digest of the corpus identity string (see
    /// [`crate::checkpoint::corpus_key`]).
    pub corpus_digest: u64,
    /// Digest of the elimination mask (λ̂, original n, kept indices) —
    /// see [`elim_digest`].
    pub elim_digest: u64,
}

/// FNV-1a digest of an elimination result: λ̂ bits, the original feature
/// count, and every kept index in order. Two eliminations that keep the
/// same features of the same corpus at the same λ̂ share a cache; any
/// difference (re-tuned target, different vocabulary) misses.
pub fn elim_digest(elim: &SafeElimination) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(elim.lambda.to_bits());
    eat(elim.original as u64);
    eat(elim.kept.len() as u64);
    for &k in &elim.kept {
        eat(k as u64);
    }
    h
}

/// Manifest record for one shard: the column range it covers and the
/// checksum its payload must carry (the staleness cross-check).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    /// First reduced column in this shard.
    pub col_start: usize,
    /// Number of columns in this shard.
    pub ncols: usize,
    /// Stored nonzeros in this shard.
    pub nnz: usize,
    /// Payload checksum of the shard file (duplicated from the shard's
    /// own trailer so a shard from a *different* write of the same key
    /// is caught).
    pub checksum: u64,
}

/// The shard cache manifest: everything [`crate::cov_disk::DiskGramCov`]
/// needs to serve Σ except the shard payloads themselves.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    /// Cache identity (corpus + elimination digests).
    pub key: ShardCacheKey,
    /// Total corpus document count `m` (the centering denominator,
    /// including documents with no kept features).
    pub total_docs: u64,
    /// Rows of the reduced matrix (documents with ≥ 1 kept feature).
    pub rows: usize,
    /// Reduced feature count n̂ (columns).
    pub nhat: usize,
    /// Total stored nonzeros across all shards.
    pub nnz: usize,
    /// The byte budget each shard was packed against.
    pub shard_bytes: usize,
    /// Per-shard column ranges and checksums, in column order.
    pub shards: Vec<ShardMeta>,
    /// Per-feature mean `μ_j` over all `m` documents (same summation
    /// order as [`crate::covop::GramCov::new`], so bitwise equal).
    pub mean: Vec<f64>,
    /// Precomputed diagonal `Σ_jj` (bitwise equal to the in-memory
    /// backend's).
    pub diag: Vec<f64>,
}

/// One decoded shard: the CSC arrays of columns
/// `col_start .. col_start + ncols` of the reduced term matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardBlock {
    /// First reduced column this shard covers.
    pub col_start: usize,
    /// Columns in this shard.
    pub ncols: usize,
    /// Rows of the full reduced matrix (shared by all shards).
    pub rows: usize,
    /// Column pointers, local to the shard (`len == ncols + 1`).
    pub colptr: Vec<usize>,
    /// Row (document) indices, ascending within each column.
    pub rowidx: Vec<u32>,
    /// Nonzero values, aligned with `rowidx`.
    pub values: Vec<f64>,
}

impl ShardBlock {
    /// Iterate local column `c`'s `(row, value)` pairs in ascending row
    /// order — the same order [`crate::data::CscMatrix::col`] yields.
    pub fn col(&self, c: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.colptr[c], self.colptr[c + 1]);
        self.rowidx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&r, &v)| (r as usize, v))
    }
}

fn stem(key: &ShardCacheKey) -> String {
    format!("shards_{:016x}_{:016x}", key.corpus_digest, key.elim_digest)
}

/// Manifest path for a key inside a cache directory.
pub fn manifest_path(dir: &Path, key: &ShardCacheKey) -> PathBuf {
    dir.join(format!("{}.lssm", stem(key)))
}

/// Shard file path for a key and shard index inside a cache directory.
pub fn shard_path(dir: &Path, key: &ShardCacheKey, idx: usize) -> PathBuf {
    dir.join(format!("{}.s{idx:04}.lss", stem(key)))
}

// --- little-endian payload helpers -----------------------------------------

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked reader (truncation surfaces as `Err`, never a panic).
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], LsspcaError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| LsspcaError::cache("shard cache: truncated payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64, LsspcaError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize, LsspcaError> {
        usize::try_from(self.u64()?)
            .map_err(|_| LsspcaError::cache("shard cache: length overflows usize"))
    }

    fn f64(&mut self) -> Result<f64, LsspcaError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Frame a payload (magic + version + payload + checksum) and write it
/// crash-atomically (tmp + fsync + rename via
/// [`crate::util::atomic_write`]) with transient-I/O retry. `tag` names
/// the fault-injection stream (`"manifest"` / `"shard"`).
fn write_framed(path: &Path, magic: &[u8; 4], tag: &str, payload: &[u8]) -> Result<(), LsspcaError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::cache(format!("mkdir {}: {e}", dir.display())))?;
    }
    let sum = checksum(payload);
    let mut bytes = Vec::with_capacity(16 + payload.len());
    bytes.extend_from_slice(magic);
    bytes.extend_from_slice(&VERSION.to_le_bytes());
    bytes.extend_from_slice(payload);
    bytes.extend_from_slice(&sum.to_le_bytes());
    retry::with_retry(&retry::policy(), || atomic_write(path, tag, &bytes)).map_err(|e| {
        let msg = e.describe(&format!("write {}", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })
}

/// Read a framed file back, verifying magic, version and checksum.
/// Returns the payload bytes. Transient read failures retry under the
/// process [`retry::policy`].
fn read_framed(path: &Path, magic: &[u8; 4], what: &str) -> Result<Vec<u8>, LsspcaError> {
    let tag = if magic == MANIFEST_MAGIC { "manifest" } else { "shard" };
    let buf = retry::with_retry(&retry::policy(), || {
        let f = std::fs::File::open(path)?;
        let mut r = faultinject::wrap_read(tag, f);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        Ok(buf)
    })
    .map_err(|e| {
        let msg = e.describe(&format!("{what} {}", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })?;
    if buf.len() < 16 || &buf[..4] != magic {
        return Err(LsspcaError::cache(format!(
            "{what} {}: bad magic or truncated header",
            path.display()
        )));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(LsspcaError::cache(format!(
            "{what} {}: version {version}, want {VERSION}",
            path.display()
        )));
    }
    let payload = &buf[8..buf.len() - 8];
    let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored {
        return Err(LsspcaError::cache(format!(
            "{what} {}: checksum mismatch (corrupt file)",
            path.display()
        )));
    }
    Ok(payload.to_vec())
}

/// Approximate on-disk bytes of a shard holding `ncols` columns and
/// `nnz` entries (colptr + rowidx + values).
fn shard_payload_bytes(ncols: usize, nnz: usize) -> usize {
    8 * (ncols + 1) + 12 * nnz
}

/// Pack columns into shards greedily under `shard_bytes` per shard
/// (every shard holds at least one column). Returns `(col_start, ncols)`
/// ranges covering `0..nhat` in order.
pub fn plan_shards(col_nnz: &[usize], shard_bytes: usize) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut start = 0;
    while start < col_nnz.len() {
        let mut end = start + 1;
        let mut nnz = col_nnz[start];
        while end < col_nnz.len() {
            let next = nnz + col_nnz[end];
            if shard_payload_bytes(end + 1 - start, next) > shard_bytes {
                break;
            }
            nnz = next;
            end += 1;
        }
        ranges.push((start, end - start));
        start = end;
    }
    if ranges.is_empty() {
        ranges.push((0, 0));
    }
    ranges
}

/// Write the shard cache for a reduced, doc-id-sorted CSR under `dir`.
///
/// `total_docs` is the full corpus size `m` (centering denominator);
/// `shard_bytes` is the per-shard byte budget. Returns the manifest that
/// was written. The per-feature means and Σ diagonal are computed here
/// with the identical summation order used by
/// [`crate::covop::GramCov::new`], so a [`crate::cov_disk::DiskGramCov`]
/// opened from this cache serves bitwise-identical values.
///
/// # Example: write → reopen roundtrip
///
/// ```
/// use lsspca::data::shardcache::{self, ShardCacheKey};
/// use lsspca::data::TripletMatrix;
///
/// let mut t = TripletMatrix::new(3, 2);
/// t.push(0, 0, 2.0);
/// t.push(2, 1, 1.0);
/// let csr = t.to_csr();
/// let dir = std::env::temp_dir()
///     .join(format!("lsspca_doctest_shards_{}", std::process::id()));
/// let key = ShardCacheKey { corpus_digest: 1, elim_digest: 2 };
/// let written = shardcache::write(&dir, &key, &csr, 3, 1 << 20).unwrap();
/// let reopened = shardcache::open(&dir, &key).unwrap().expect("cache hit");
/// assert_eq!(reopened, written); // manifest verified: magic + checksum + key
/// # for i in 0..written.shards.len() {
/// #     std::fs::remove_file(shardcache::shard_path(&dir, &key, i)).ok();
/// # }
/// # std::fs::remove_file(shardcache::manifest_path(&dir, &key)).ok();
/// # std::fs::remove_dir(&dir).ok();
/// ```
pub fn write(
    dir: &Path,
    key: &ShardCacheKey,
    csr: &CsrMatrix,
    total_docs: u64,
    shard_bytes: usize,
) -> Result<ShardManifest, LsspcaError> {
    let nhat = csr.cols;
    // The one shared definition of the mean/diagonal folds — bitwise
    // equality with GramCov holds by construction, not by transcription.
    let (mean, diag) = crate::covop::reduced_means_and_diag(csr, total_docs);
    // Column-major view for slicing shards.
    let csc = csr.to_csc();
    let col_nnz: Vec<usize> = (0..nhat).map(|c| csc.col_nnz(c)).collect();
    let ranges = plan_shards(&col_nnz, shard_bytes.max(1));

    let mut shards = Vec::with_capacity(ranges.len());
    for (idx, &(col_start, ncols)) in ranges.iter().enumerate() {
        let (lo, hi) = (csc.colptr[col_start], csc.colptr[col_start + ncols]);
        let mut payload = Vec::with_capacity(64 + shard_payload_bytes(ncols, hi - lo));
        put_u64(&mut payload, key.corpus_digest);
        put_u64(&mut payload, key.elim_digest);
        put_u64(&mut payload, idx as u64);
        put_u64(&mut payload, col_start as u64);
        put_u64(&mut payload, ncols as u64);
        put_u64(&mut payload, csr.rows as u64);
        put_u64(&mut payload, (hi - lo) as u64);
        for &p in &csc.colptr[col_start..=col_start + ncols] {
            put_u64(&mut payload, (p - lo) as u64);
        }
        for &r in &csc.rowidx[lo..hi] {
            payload.extend_from_slice(&r.to_le_bytes());
        }
        for &v in &csc.values[lo..hi] {
            put_f64(&mut payload, v);
        }
        let sum = checksum(&payload);
        write_framed(&shard_path(dir, key, idx), SHARD_MAGIC, "shard", &payload)?;
        shards.push(ShardMeta { col_start, ncols, nnz: hi - lo, checksum: sum });
    }

    let manifest = ShardManifest {
        key: *key,
        total_docs,
        rows: csr.rows,
        nhat,
        nnz: csr.nnz(),
        shard_bytes,
        shards,
        mean,
        diag,
    };
    write_manifest(dir, &manifest)?;
    Ok(manifest)
}

/// Write the cache for `new_key` reusing a previous manifest's *column
/// partition* — the incremental-append path.
///
/// Appending documents adds rows to the reduced CSR but leaves the
/// feature (column) axis untouched, so instead of re-planning shards
/// from scratch the new cache keeps `old`'s `(col_start, ncols)` ranges.
/// Shard payloads embed the row count and the digests, so whole files
/// cannot be reused — but for every column range **no appended document
/// touched**, the CSC array section of the payload (everything past the
/// 7-word header) is byte-for-byte identical to the old shard's, and
/// shard sizes stay stable across appends (pinned by
/// `extend_keeps_ranges_and_untouched_column_payloads`). Errors if the
/// reduced column count changed (that is a re-elimination: [`write`] a
/// fresh cache instead).
pub fn extend(
    dir: &Path,
    old: &ShardManifest,
    new_key: &ShardCacheKey,
    csr: &CsrMatrix,
    total_docs: u64,
) -> Result<ShardManifest, LsspcaError> {
    if old.nhat != csr.cols {
        return Err(LsspcaError::cache(format!(
            "shard extend: reduced column count changed ({} -> {}); rewrite the cache",
            old.nhat, csr.cols
        )));
    }
    let (mean, diag) = crate::covop::reduced_means_and_diag(csr, total_docs);
    let csc = csr.to_csc();
    let mut shards = Vec::with_capacity(old.shards.len());
    for (idx, meta) in old.shards.iter().enumerate() {
        let (col_start, ncols) = (meta.col_start, meta.ncols);
        let (lo, hi) = (csc.colptr[col_start], csc.colptr[col_start + ncols]);
        let mut payload = Vec::with_capacity(64 + shard_payload_bytes(ncols, hi - lo));
        put_u64(&mut payload, new_key.corpus_digest);
        put_u64(&mut payload, new_key.elim_digest);
        put_u64(&mut payload, idx as u64);
        put_u64(&mut payload, col_start as u64);
        put_u64(&mut payload, ncols as u64);
        put_u64(&mut payload, csr.rows as u64);
        put_u64(&mut payload, (hi - lo) as u64);
        for &p in &csc.colptr[col_start..=col_start + ncols] {
            put_u64(&mut payload, (p - lo) as u64);
        }
        for &r in &csc.rowidx[lo..hi] {
            payload.extend_from_slice(&r.to_le_bytes());
        }
        for &v in &csc.values[lo..hi] {
            put_f64(&mut payload, v);
        }
        let sum = checksum(&payload);
        write_framed(&shard_path(dir, new_key, idx), SHARD_MAGIC, "shard", &payload)?;
        shards.push(ShardMeta { col_start, ncols, nnz: hi - lo, checksum: sum });
    }
    let manifest = ShardManifest {
        key: *new_key,
        total_docs,
        rows: csr.rows,
        nhat: csr.cols,
        nnz: csr.nnz(),
        shard_bytes: old.shard_bytes,
        shards,
        mean,
        diag,
    };
    write_manifest(dir, &manifest)?;
    Ok(manifest)
}

fn write_manifest(dir: &Path, man: &ShardManifest) -> Result<(), LsspcaError> {
    let mut payload = Vec::new();
    put_u64(&mut payload, man.key.corpus_digest);
    put_u64(&mut payload, man.key.elim_digest);
    put_u64(&mut payload, man.total_docs);
    put_u64(&mut payload, man.rows as u64);
    put_u64(&mut payload, man.nhat as u64);
    put_u64(&mut payload, man.nnz as u64);
    put_u64(&mut payload, man.shard_bytes as u64);
    put_u64(&mut payload, man.shards.len() as u64);
    for s in &man.shards {
        put_u64(&mut payload, s.col_start as u64);
        put_u64(&mut payload, s.ncols as u64);
        put_u64(&mut payload, s.nnz as u64);
        put_u64(&mut payload, s.checksum);
    }
    for &v in &man.mean {
        put_f64(&mut payload, v);
    }
    for &v in &man.diag {
        put_f64(&mut payload, v);
    }
    write_framed(&manifest_path(dir, &man.key), MANIFEST_MAGIC, "manifest", &payload)
}

/// Open a shard cache: `Ok(None)` when no manifest exists for the key
/// (a cache miss — build and [`write`] it), `Err` on corruption or a
/// stale manifest whose stored digests disagree with `key`.
///
/// Shard payloads are *not* read here; [`load_shard`] verifies each one
/// on first touch.
pub fn open(dir: &Path, key: &ShardCacheKey) -> Result<Option<ShardManifest>, LsspcaError> {
    let path = manifest_path(dir, key);
    if !path.exists() {
        return Ok(None);
    }
    let payload = read_framed(&path, MANIFEST_MAGIC, "shard manifest")?;
    let mut r = Reader::new(&payload);
    let stored = ShardCacheKey { corpus_digest: r.u64()?, elim_digest: r.u64()? };
    if stored != *key {
        return Err(LsspcaError::cache(format!(
            "shard manifest {}: key mismatch (stored {:016x}/{:016x}, want {:016x}/{:016x}) \
             — stale cache",
            path.display(),
            stored.corpus_digest,
            stored.elim_digest,
            key.corpus_digest,
            key.elim_digest
        )));
    }
    let total_docs = r.u64()?;
    let rows = r.usize()?;
    let nhat = r.usize()?;
    let nnz = r.usize()?;
    let shard_bytes = r.usize()?;
    let nshards = r.usize()?;
    if nshards > payload.len() || nhat > payload.len() {
        return Err(LsspcaError::cache("shard manifest: implausible shard or column count"));
    }
    let mut shards = Vec::with_capacity(nshards);
    for _ in 0..nshards {
        shards.push(ShardMeta {
            col_start: r.usize()?,
            ncols: r.usize()?,
            nnz: r.usize()?,
            checksum: r.u64()?,
        });
    }
    let mut mean = Vec::with_capacity(nhat);
    for _ in 0..nhat {
        mean.push(r.f64()?);
    }
    let mut diag = Vec::with_capacity(nhat);
    for _ in 0..nhat {
        diag.push(r.f64()?);
    }
    if !r.done() {
        return Err(LsspcaError::cache("shard manifest: trailing bytes (corrupt file)"));
    }
    // Structural sanity: shard ranges must tile 0..nhat in order.
    let mut expect = 0;
    let mut sum_nnz = 0;
    for s in &shards {
        if s.col_start != expect {
            return Err(LsspcaError::cache("shard manifest: shard ranges do not tile the columns"));
        }
        expect += s.ncols;
        sum_nnz += s.nnz;
    }
    if expect != nhat || sum_nnz != nnz {
        return Err(LsspcaError::cache("shard manifest: shard ranges inconsistent with shape"));
    }
    Ok(Some(ShardManifest {
        key: *key,
        total_docs,
        rows,
        nhat,
        nnz,
        shard_bytes,
        shards,
        mean,
        diag,
    }))
}

/// Load and verify one shard. The payload checksum must match both the
/// shard's own trailer and the manifest record, and the header must
/// agree with the manifest's column range — so a shard file left over
/// from a different write of the same key is rejected as stale.
pub fn load_shard(
    dir: &Path,
    man: &ShardManifest,
    idx: usize,
) -> Result<ShardBlock, LsspcaError> {
    let meta = man
        .shards
        .get(idx)
        .ok_or_else(|| {
            LsspcaError::cache(format!("shard cache: shard index {idx} out of range"))
        })?;
    let path = shard_path(dir, &man.key, idx);
    let payload = read_framed(&path, SHARD_MAGIC, "shard")?;
    if checksum(&payload) != meta.checksum {
        return Err(LsspcaError::cache(format!(
            "shard {}: checksum disagrees with manifest — stale shard file",
            path.display()
        )));
    }
    let mut r = Reader::new(&payload);
    let stored = ShardCacheKey { corpus_digest: r.u64()?, elim_digest: r.u64()? };
    let sidx = r.usize()?;
    let col_start = r.usize()?;
    let ncols = r.usize()?;
    let rows = r.usize()?;
    let nnz = r.usize()?;
    if stored != man.key
        || sidx != idx
        || col_start != meta.col_start
        || ncols != meta.ncols
        || rows != man.rows
        || nnz != meta.nnz
    {
        return Err(LsspcaError::cache(format!(
            "shard {}: header disagrees with manifest — stale shard file",
            path.display()
        )));
    }
    let mut colptr = Vec::with_capacity(ncols + 1);
    for _ in 0..=ncols {
        colptr.push(r.usize()?);
    }
    let mut rowidx = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        rowidx.push(u32::from_le_bytes(r.take(4)?.try_into().unwrap()));
    }
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        values.push(r.f64()?);
    }
    if !r.done() {
        return Err(LsspcaError::cache(format!(
            "shard {}: trailing bytes (corrupt file)",
            path.display()
        )));
    }
    if colptr.first() != Some(&0) || colptr.last() != Some(&nnz) {
        return Err(LsspcaError::cache(format!(
            "shard {}: bad column pointers",
            path.display()
        )));
    }
    for w in colptr.windows(2) {
        if w[0] > w[1] {
            return Err(LsspcaError::cache(format!(
                "shard {}: column pointers not monotone",
                path.display()
            )));
        }
    }
    if rowidx.iter().any(|&doc| doc as usize >= rows) {
        return Err(LsspcaError::cache(format!(
            "shard {}: row index out of range",
            path.display()
        )));
    }
    Ok(ShardBlock { col_start, ncols, rows, colptr, rowidx, values })
}

impl ShardManifest {
    /// Largest single shard's payload bytes — the unit the memory
    /// planner's "one decode wave" reserve must use (a column larger
    /// than the configured budget becomes one oversized shard).
    pub fn max_shard_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| shard_payload_bytes(s.ncols, s.nnz) as u64)
            .max()
            .unwrap_or(0)
    }
}

/// Verify every shard a manifest references: load, checksum, cross-check
/// against the manifest, drop. Shards verify on up to `threads` workers
/// (0 = all cores), one shard resident per worker — the same memory
/// bound as a solve-time decode wave. `Err` names a corrupt, truncated,
/// or stale shard. Run this on a cache hit *before* starting a solve:
/// [`crate::cov_disk::DiskGramCov`] cannot return errors mid-kernel, so
/// a bad shard discovered there panics, while a bad shard discovered
/// here lets the caller rebuild.
pub fn verify_shards(dir: &Path, man: &ShardManifest, threads: usize) -> Result<(), LsspcaError> {
    let results = crate::util::parallel::par_map_indexed(threads, man.shards.len(), |idx| {
        load_shard(dir, man, idx).map(|_| ())
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TripletMatrix;
    use crate::util::check::property;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize) -> CsrMatrix {
        let mut t = TripletMatrix::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.bool(0.3) {
                    t.push(r, c, (1 + rng.below(6)) as f64);
                }
            }
        }
        t.to_csr()
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_shardcache_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn key(a: u64, b: u64) -> ShardCacheKey {
        ShardCacheKey { corpus_digest: a, elim_digest: b }
    }

    #[test]
    fn plan_shards_tiles_and_respects_budget() {
        let col_nnz = vec![10, 0, 5, 100, 1, 1, 1, 40];
        for budget in [1usize, 200, 600, 1 << 20] {
            let ranges = plan_shards(&col_nnz, budget);
            let mut expect = 0;
            for &(s, n) in &ranges {
                assert_eq!(s, expect);
                assert!(n >= 1);
                expect += n;
                // a multi-column shard never exceeds the budget
                if n > 1 {
                    let nnz: usize = col_nnz[s..s + n].iter().sum();
                    assert!(shard_payload_bytes(n, nnz) <= budget);
                }
            }
            assert_eq!(expect, col_nnz.len());
        }
    }

    #[test]
    fn prop_roundtrip_bitwise_vs_in_memory_csc() {
        property("shard cache roundtrips the CSC bitwise", 10, |rng| {
            let rows = rng.range(2, 50);
            let cols = rng.range(1, 20);
            let csr = random_csr(rng, rows, cols);
            let csc = csr.to_csc();
            let dir = tmpdir("rt");
            let k = key(rng.below(1 << 30) as u64, 7);
            // small budget to force several shards
            let man = write(&dir, &k, &csr, rows as u64 + 2, 256).unwrap();
            assert_eq!(man.rows, csr.rows);
            assert_eq!(man.nnz, csr.nnz());
            let reopened = open(&dir, &k).unwrap().expect("manifest must exist");
            assert_eq!(reopened, man);
            // reassemble every column from shards; must match the CSC bit
            // for bit, in order
            for (idx, meta) in man.shards.iter().enumerate() {
                let block = load_shard(&dir, &man, idx).unwrap();
                assert_eq!(block.col_start, meta.col_start);
                for c in 0..block.ncols {
                    let got: Vec<(usize, u64)> =
                        block.col(c).map(|(r, v)| (r, v.to_bits())).collect();
                    let want: Vec<(usize, u64)> =
                        csc.col(meta.col_start + c).map(|(r, v)| (r, v.to_bits())).collect();
                    if got != want {
                        return Err(format!("column {} differs", meta.col_start + c));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn missing_manifest_is_none() {
        let dir = tmpdir("miss");
        assert!(open(&dir, &key(1, 2)).unwrap().is_none());
    }

    #[test]
    fn corrupt_manifest_rejected() {
        let mut rng = Rng::seed_from(5);
        let dir = tmpdir("cm");
        let k = key(11, 22);
        let csr = random_csr(&mut rng, 20, 6);
        write(&dir, &k, &csr, 20, 512).unwrap();
        let path = manifest_path(&dir, &k);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&dir, &k).unwrap_err();
        assert!(matches!(err, LsspcaError::Cache { .. }));
        assert!(err.to_string().contains("checksum"), "{err}");
        // truncation also rejected
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(open(&dir, &k).is_err());
    }

    #[test]
    fn stale_manifest_key_mismatch_rejected() {
        let mut rng = Rng::seed_from(6);
        let dir = tmpdir("stale");
        let k_old = key(1, 1);
        let k_new = key(2, 2);
        let csr = random_csr(&mut rng, 15, 5);
        write(&dir, &k_old, &csr, 15, 512).unwrap();
        // simulate a stale cache: a manifest written for another key is
        // dropped at the new key's path
        std::fs::rename(manifest_path(&dir, &k_old), manifest_path(&dir, &k_new)).unwrap();
        let err = open(&dir, &k_new).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
    }

    #[test]
    fn corrupt_or_truncated_shard_rejected() {
        let mut rng = Rng::seed_from(7);
        let dir = tmpdir("cs");
        let k = key(3, 4);
        let csr = random_csr(&mut rng, 30, 8);
        let man = write(&dir, &k, &csr, 30, 128).unwrap();
        assert!(man.shards.len() > 1, "want several shards");
        let path = shard_path(&dir, &k, 0);
        let good = std::fs::read(&path).unwrap();
        // bit flip in the payload
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        assert!(load_shard(&dir, &man, 0).is_err());
        // truncation
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(load_shard(&dir, &man, 0).is_err());
        // restore; other shards were never affected
        std::fs::write(&path, &good).unwrap();
        load_shard(&dir, &man, 0).unwrap();
        load_shard(&dir, &man, 1).unwrap();
    }

    #[test]
    fn verify_shards_catches_any_bad_shard() {
        let mut rng = Rng::seed_from(9);
        let dir = tmpdir("vs");
        let k = key(7, 8);
        let csr = random_csr(&mut rng, 40, 10);
        let man = write(&dir, &k, &csr, 40, 128).unwrap();
        assert!(man.shards.len() > 2);
        for threads in [1, 4] {
            verify_shards(&dir, &man, threads).unwrap();
        }
        assert!(man.max_shard_bytes() > 0);
        // corrupt the *last* shard: the sweep must still find it
        let idx = man.shards.len() - 1;
        let path = shard_path(&dir, &k, idx);
        let good = std::fs::read(&path).unwrap();
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        assert!(verify_shards(&dir, &man, 2).is_err());
        // a missing shard is caught too
        std::fs::remove_file(&path).unwrap();
        assert!(verify_shards(&dir, &man, 2).is_err());
        std::fs::write(&path, &good).unwrap();
        verify_shards(&dir, &man, 2).unwrap();
    }

    #[test]
    fn extend_keeps_ranges_and_untouched_column_payloads() {
        let dir = tmpdir("ext");
        let (rows, cols) = (30usize, 8usize);
        // deterministic base triplets, regenerated for the extended build
        let base_entries = |t: &mut TripletMatrix| {
            let mut rng = Rng::seed_from(42);
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bool(0.3) {
                        t.push(r, c, (1 + rng.below(6)) as f64);
                    }
                }
            }
        };
        let mut tb = TripletMatrix::new(rows, cols);
        base_entries(&mut tb);
        let base = tb.to_csr();
        let k_old = key(100, 7);
        // ~1 column per shard at this budget → most ranges miss cols 0/1
        let old = write(&dir, &k_old, &base, rows as u64, 128).unwrap();
        assert!(old.shards.len() > 2, "want several shards");

        // append 3 docs touching ONLY columns 0 and 1
        let mut te = TripletMatrix::new(rows + 3, cols);
        base_entries(&mut te);
        for i in 0..3 {
            te.push(rows + i, 0, 2.0);
            te.push(rows + i, 1, 3.0);
        }
        let ext = te.to_csr();
        let k_new = key(200, 7);
        let new = extend(&dir, &old, &k_new, &ext, rows as u64 + 3).unwrap();

        // the column partition is reused verbatim; shape bookkeeping moves
        let old_ranges: Vec<(usize, usize)> =
            old.shards.iter().map(|s| (s.col_start, s.ncols)).collect();
        let new_ranges: Vec<(usize, usize)> =
            new.shards.iter().map(|s| (s.col_start, s.ncols)).collect();
        assert_eq!(new_ranges, old_ranges);
        assert_eq!(new.rows, rows + 3);
        assert_eq!(new.nnz, ext.nnz());
        assert_eq!(new.shard_bytes, old.shard_bytes);

        // untouched column ranges: the CSC section of the payload — past
        // the 8-byte frame header and 56-byte (7×u64) shard header, before
        // the 8-byte checksum trailer — is byte-for-byte the old shard's
        let mut untouched_checked = 0;
        for (idx, meta) in new.shards.iter().enumerate() {
            let ob = std::fs::read(shard_path(&dir, &k_old, idx)).unwrap();
            let nb = std::fs::read(shard_path(&dir, &k_new, idx)).unwrap();
            if meta.col_start >= 2 {
                assert_eq!(
                    &ob[8 + 56..ob.len() - 8],
                    &nb[8 + 56..nb.len() - 8],
                    "shard {idx} (cols {}..{}) payload changed",
                    meta.col_start,
                    meta.col_start + meta.ncols
                );
                untouched_checked += 1;
            }
        }
        assert!(untouched_checked > 0, "no untouched shard exercised the pin");

        // the extended cache is a valid cache: reopen + bitwise column check
        let reopened = open(&dir, &k_new).unwrap().expect("manifest must exist");
        assert_eq!(reopened, new);
        let csc = ext.to_csc();
        for (idx, meta) in new.shards.iter().enumerate() {
            let block = load_shard(&dir, &new, idx).unwrap();
            for c in 0..block.ncols {
                let got: Vec<(usize, u64)> =
                    block.col(c).map(|(r, v)| (r, v.to_bits())).collect();
                let want: Vec<(usize, u64)> =
                    csc.col(meta.col_start + c).map(|(r, v)| (r, v.to_bits())).collect();
                assert_eq!(got, want, "column {}", meta.col_start + c);
            }
        }

        // a changed column count is a re-elimination, not an extension
        let mut tw = TripletMatrix::new(rows + 3, cols + 1);
        base_entries(&mut tw);
        tw.push(rows, cols, 1.0);
        let err = extend(&dir, &old, &key(300, 7), &tw.to_csr(), rows as u64 + 3).unwrap_err();
        assert!(err.to_string().contains("column count changed"), "{err}");
    }

    #[test]
    fn shard_from_other_write_rejected_as_stale() {
        let mut rng = Rng::seed_from(8);
        let dir = tmpdir("sw");
        let k = key(5, 6);
        let csr_a = random_csr(&mut rng, 25, 6);
        let man_a = write(&dir, &k, &csr_a, 25, 128).unwrap();
        let shard0_a = std::fs::read(shard_path(&dir, &k, 0)).unwrap();
        // a second write of the same key with different data
        let csr_b = random_csr(&mut rng, 25, 6);
        let man_b = write(&dir, &k, &csr_b, 25, 128).unwrap();
        assert_ne!(man_a, man_b);
        // drop shard 0 from the old write next to the new manifest
        std::fs::write(shard_path(&dir, &k, 0), &shard0_a).unwrap();
        let err = load_shard(&dir, &man_b, 0).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
    }

    #[test]
    fn elim_digest_distinguishes_masks() {
        let base = SafeElimination {
            lambda: 0.5,
            original: 100,
            kept: vec![3, 1, 4],
            kept_variances: vec![0.0; 3],
        };
        let mut other = base.clone();
        other.kept = vec![3, 1, 5];
        assert_ne!(elim_digest(&base), elim_digest(&other));
        let mut lam = base.clone();
        lam.lambda = 0.25;
        assert_ne!(elim_digest(&base), elim_digest(&lam));
        assert_eq!(elim_digest(&base), elim_digest(&base.clone()));
    }
}
