//! Dense symmetric matrix stored as a full row-major `n × n` buffer.
//!
//! Full (not packed-triangular) storage is a deliberate hot-path choice:
//! Algorithm 1's inner loops walk whole rows (`Y[j]·u` dot products and the
//! column write-back `y = Yu/τ`), and contiguous rows keep those loops
//! vectorizable and prefetch-friendly. Symmetry is maintained by the
//! mutators (`set` writes both `(i,j)` and `(j,i)`).

use crate::util::rng::Rng;

/// Dense symmetric matrix of order `n`, full row-major storage.
#[derive(Clone, Debug, PartialEq)]
pub struct SymMat {
    n: usize,
    data: Vec<f64>,
}

impl SymMat {
    /// Zero matrix of order `n`.
    pub fn zeros(n: usize) -> SymMat {
        SymMat { n, data: vec![0.0; n * n] }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> SymMat {
        let mut m = SymMat::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a full row-major buffer, verifying symmetry.
    pub fn from_rows(n: usize, data: Vec<f64>) -> Result<SymMat, crate::error::LsspcaError> {
        use crate::error::LsspcaError;
        if data.len() != n * n {
            return Err(LsspcaError::numeric(format!(
                "expected {} elements, got {}",
                n * n,
                data.len()
            )));
        }
        for i in 0..n {
            for j in (i + 1)..n {
                let (a, b) = (data[i * n + j], data[j * n + i]);
                if (a - b).abs() > 1e-9 * (1.0 + a.abs().max(b.abs())) {
                    return Err(LsspcaError::numeric(format!(
                        "not symmetric at ({i},{j}): {a} vs {b}"
                    )));
                }
            }
        }
        Ok(SymMat { n, data })
    }

    /// Build from a function of `(i, j)` (evaluated for `i ≤ j`).
    pub fn from_fn(n: usize, mut f: impl FnMut(usize, usize) -> f64) -> SymMat {
        let mut m = SymMat::zeros(n);
        for i in 0..n {
            for j in i..n {
                let v = f(i, j);
                m.data[i * n + j] = v;
                m.data[j * n + i] = v;
            }
        }
        m
    }

    /// Gram matrix `FᵀF / m` of an `m × n` row-major factor matrix — the
    /// covariance convention used throughout (population, uncentered unless
    /// the caller centers `F` first).
    pub fn gram(m_rows: usize, n: usize, f_rowmajor: &[f64]) -> SymMat {
        assert_eq!(f_rowmajor.len(), m_rows * n);
        let mut g = SymMat::zeros(n);
        // Accumulate row-by-row outer products: cache-friendly over F.
        // The inner update is an axpy (element-wise, so the SIMD tiers
        // are bitwise-identical to the scalar loop it replaces).
        for r in 0..m_rows {
            let row = &f_rowmajor[r * n..(r + 1) * n];
            for i in 0..n {
                let fi = row[i];
                if fi == 0.0 {
                    continue;
                }
                let gi = &mut g.data[i * n..(i + 1) * n];
                crate::kernels::axpy(fi, row, gi);
            }
        }
        let inv = 1.0 / m_rows as f64;
        for v in &mut g.data {
            *v *= inv;
        }
        g
    }

    /// Random PSD matrix `FᵀF/m + ridge·I` (test helper).
    pub fn random_psd(n: usize, m_rows: usize, ridge: f64, rng: &mut Rng) -> SymMat {
        let f: Vec<f64> = (0..m_rows * n).map(|_| rng.gauss()).collect();
        let mut g = SymMat::gram(m_rows, n, &f);
        for i in 0..n {
            g.data[i * n + i] += ridge;
        }
        g
    }

    /// Matrix order.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    /// Set both `(i,j)` and `(j,i)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
        self.data[j * self.n + i] = v;
    }

    /// Contiguous row `i` (equals column `i` by symmetry).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Full backing buffer, row-major.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing buffer — callers must preserve symmetry.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.data[i * self.n + i]).sum()
    }

    /// Sum of absolute values of all entries (the ‖·‖₁ of problem (1)).
    pub fn l1_norm(&self) -> f64 {
        self.data.iter().map(|v| v.abs()).sum()
    }

    /// Frobenius inner product `Tr(AᵀB) = Σ AᵢⱼBᵢⱼ`.
    pub fn frob_dot(&self, other: &SymMat) -> f64 {
        assert_eq!(self.n, other.n);
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Matrix–vector product `y = A x`.
    ///
    /// Each row dot runs through [`crate::kernels::dot`] — the fixed
    /// 4-lane reduction order shared by every dispatch tier, so this is
    /// bitwise-identical across `scalar`/`avx2`/`neon` and defines the
    /// row-dot order every dense-row consumer (the QP's
    /// `DenseRows::matvec` default, [`quad_form`](SymMat::quad_form))
    /// must share.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            let row = &self.data[i * self.n..(i + 1) * self.n];
            y[i] = crate::kernels::dot(row, x);
        }
    }

    /// Quadratic form `xᵀ A x` — same per-row dot order as
    /// [`matvec`](SymMat::matvec), skipping rows with `x[i] == 0`.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.n);
        let mut total = 0.0;
        for i in 0..self.n {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &self.data[i * self.n..(i + 1) * self.n];
            total += xi * crate::kernels::dot(row, x);
        }
        total
    }

    /// Extract the principal submatrix on the given (sorted or not) indices.
    pub fn submatrix(&self, idx: &[usize]) -> SymMat {
        let k = idx.len();
        let mut m = SymMat::zeros(k);
        for (a, &i) in idx.iter().enumerate() {
            for (b, &j) in idx.iter().enumerate() {
                m.data[a * k + b] = self.get(i, j);
            }
        }
        m
    }

    /// Zero-pad to order `n_pad ≥ n` (new rows/cols are zero).
    pub fn pad_to(&self, n_pad: usize) -> SymMat {
        assert!(n_pad >= self.n);
        let mut m = SymMat::zeros(n_pad);
        for i in 0..self.n {
            m.data[i * n_pad..i * n_pad + self.n]
                .copy_from_slice(&self.data[i * self.n..(i + 1) * self.n]);
        }
        m
    }

    /// Maximum absolute asymmetry `max |Aᵢⱼ − Aⱼᵢ|` (diagnostic).
    pub fn asymmetry(&self) -> f64 {
        let mut worst = 0.0f64;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                worst = worst.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        worst
    }

    /// Re-symmetrize in place: `A ← (A + Aᵀ)/2`.
    pub fn symmetrize(&mut self) {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let v = 0.5 * (self.data[i * self.n + j] + self.data[j * self.n + i]);
                self.data[i * self.n + j] = v;
                self.data[j * self.n + i] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_and_trace() {
        let m = SymMat::identity(4);
        assert_eq!(m.trace(), 4.0);
        assert_eq!(m.get(2, 2), 1.0);
        assert_eq!(m.get(0, 1), 0.0);
    }

    #[test]
    fn from_rows_rejects_asymmetric() {
        assert!(SymMat::from_rows(2, vec![1.0, 2.0, 3.0, 4.0]).is_err());
        assert!(SymMat::from_rows(2, vec![1.0, 2.0, 2.0, 4.0]).is_ok());
        assert!(SymMat::from_rows(2, vec![1.0]).is_err());
    }

    #[test]
    fn gram_small() {
        // F = [[1,0],[1,1]] → FᵀF = [[2,1],[1,1]], /m=2
        let g = SymMat::gram(2, 2, &[1.0, 0.0, 1.0, 1.0]);
        assert!((g.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((g.get(0, 1) - 0.5).abs() < 1e-12);
        assert!((g.get(1, 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn matvec_and_quadform_agree() {
        let mut rng = Rng::seed_from(21);
        let a = SymMat::random_psd(8, 12, 0.1, &mut rng);
        let x = rng.gauss_vec(8);
        let mut y = vec![0.0; 8];
        a.matvec(&x, &mut y);
        let xay: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((xay - a.quad_form(&x)).abs() < 1e-9 * (1.0 + xay.abs()));
    }

    #[test]
    fn random_psd_is_psd_diag() {
        let mut rng = Rng::seed_from(22);
        let a = SymMat::random_psd(10, 20, 0.0, &mut rng);
        // PSD implies non-negative diagonal and |a_ij| <= sqrt(a_ii a_jj)
        for i in 0..10 {
            assert!(a.get(i, i) >= 0.0);
            for j in 0..10 {
                assert!(a.get(i, j).abs() <= (a.get(i, i) * a.get(j, j)).sqrt() + 1e-9);
            }
        }
    }

    #[test]
    fn submatrix_picks_entries() {
        let m = SymMat::from_fn(4, |i, j| (i * 10 + j) as f64);
        let s = m.submatrix(&[1, 3]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.get(0, 0), m.get(1, 1));
        assert_eq!(s.get(0, 1), m.get(1, 3));
        assert_eq!(s.get(1, 1), m.get(3, 3));
    }

    #[test]
    fn pad_preserves_block() {
        let m = SymMat::from_fn(3, |i, j| (i + j) as f64);
        let p = m.pad_to(5);
        assert_eq!(p.n(), 5);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(p.get(i, j), m.get(i, j));
            }
        }
        assert_eq!(p.get(4, 4), 0.0);
        assert_eq!(p.get(0, 4), 0.0);
    }

    #[test]
    fn symmetrize_fixes_drift() {
        let mut m = SymMat::zeros(3);
        m.as_mut_slice()[1] = 1.0; // (0,1) only
        assert!(m.asymmetry() > 0.0);
        m.symmetrize();
        assert_eq!(m.asymmetry(), 0.0);
        assert_eq!(m.get(0, 1), 0.5);
    }

    #[test]
    fn l1_and_frob() {
        let a = SymMat::from_fn(2, |i, j| if i == j { 1.0 } else { -2.0 });
        assert_eq!(a.l1_norm(), 6.0);
        assert_eq!(a.frob_dot(&a), 1.0 + 4.0 + 4.0 + 1.0);
    }
}
