//! Wall-clock timing helpers used by the profiling instrumentation and the
//! bench harness (criterion is unavailable offline; see DESIGN.md §3).

use std::time::Instant;

/// A simple wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart, returning the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let t = self.secs();
        self.start = Instant::now();
        t
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates named timing sections; the poor-man's profiler used in the
/// §Perf pass (no `perf`/flamegraph in the container).
#[derive(Debug, Default)]
pub struct Profiler {
    sections: Vec<(String, f64, u64)>,
}

impl Profiler {
    /// Empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `secs` to the named section.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.sections.iter_mut().find(|(n, _, _)| n == name) {
            e.1 += secs;
            e.2 += 1;
        } else {
            self.sections.push((name.to_string(), secs, 1));
        }
    }

    /// Time a closure under the given section name.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }

    /// Total time across all sections.
    pub fn total(&self) -> f64 {
        self.sections.iter().map(|(_, s, _)| s).sum()
    }

    /// Render a profile table sorted by time, descending.
    pub fn report(&self) -> String {
        let total = self.total().max(1e-12);
        let mut rows = self.sections.clone();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut out = String::from("section                          time        calls   share\n");
        for (name, secs, calls) in rows {
            out.push_str(&format!(
                "{:<30}  {:>10}  {:>7}  {:>5.1}%\n",
                name,
                crate::util::human_secs(secs),
                calls,
                100.0 * secs / total
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(3));
        let l1 = t.lap();
        let l2 = t.secs();
        assert!(l1 >= 0.002);
        assert!(l2 < l1);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = Profiler::new();
        p.add("a", 1.0);
        p.add("a", 2.0);
        p.add("b", 0.5);
        assert!((p.total() - 3.5).abs() < 1e-12);
        let rep = p.report();
        assert!(rep.contains('a'));
        let first_data_line = rep.lines().nth(1).unwrap();
        assert!(first_data_line.starts_with('a'), "{rep}");
    }

    #[test]
    fn profiler_time_closure() {
        let mut p = Profiler::new();
        let v = p.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(p.sections.len(), 1);
    }
}
