//! Minimal gzip (RFC 1952) + DEFLATE (RFC 1951) support — the offline
//! substitute for `flate2` (see DESIGN.md §3 and EXPERIMENTS.md §Perf for
//! why the default build carries zero external dependencies).
//!
//! - [`GzDecoder`] is a full streaming *inflate*: stored, fixed-Huffman and
//!   dynamic-Huffman blocks, 32 KiB back-reference window, CRC32 + ISIZE
//!   trailer verification, and *multi-member* (concatenated) streams —
//!   real `docword.*.txt.gz` dumps are sometimes produced by appending
//!   gzip members, and RFC 1952 §2.2 requires a decompressor to handle
//!   that as one logical stream. It reads anything the UCI distribution
//!   (or any standard gzip) produces, in bounded memory.
//! - [`GzEncoder`] emits valid gzip using *stored* (uncompressed) DEFLATE
//!   blocks. The synthetic-corpus writer is the only producer in this
//!   repository and its output is consumed once by our own reader, so
//!   byte-exact validity matters and ratio does not.

use std::io::{self, Read, Write};

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected, poly 0xEDB88320)
// ---------------------------------------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Running CRC32 checksum.
#[derive(Clone)]
pub struct Crc32 {
    table: [u32; 256],
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32 { table: crc32_table(), state: !0 }
    }
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Crc32 {
        Crc32::default()
    }

    /// Restart the checksum (keeps the table): one CRC per gzip member.
    pub fn reset(&mut self) {
        self.state = !0;
    }

    /// Fold `data` into the checksum.
    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = self.table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Final CRC-32 value (state is not consumed).
    pub fn finish(&self) -> u32 {
        !self.state
    }
}

// ---------------------------------------------------------------------------
// Encoder: gzip container around stored DEFLATE blocks
// ---------------------------------------------------------------------------

const STORED_BLOCK_MAX: usize = 0xFFFF;
const ENCODER_BUF: usize = 32 * 1024;

/// Streaming gzip writer (stored blocks). Finalizes on [`GzEncoder::finish`]
/// or, as a fallback, on drop (errors ignored there, matching `flate2`).
pub struct GzEncoder<W: Write> {
    inner: Option<W>,
    buf: Vec<u8>,
    crc: Crc32,
    total: u64,
    wrote_header: bool,
}

impl<W: Write> GzEncoder<W> {
    /// Wrap a writer; the gzip header is emitted on first write.
    pub fn new(inner: W) -> GzEncoder<W> {
        GzEncoder {
            inner: Some(inner),
            buf: Vec::with_capacity(ENCODER_BUF),
            crc: Crc32::new(),
            total: 0,
            wrote_header: false,
        }
    }

    fn write_header(&mut self) -> io::Result<()> {
        if !self.wrote_header {
            // magic, CM=deflate, FLG=0, MTIME=0, XFL=0, OS=unknown
            let hdr = [0x1F, 0x8B, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xFF];
            self.inner.as_mut().unwrap().write_all(&hdr)?;
            self.wrote_header = true;
        }
        Ok(())
    }

    /// Emit the buffered bytes as non-final stored blocks.
    fn drain_buf(&mut self) -> io::Result<()> {
        self.write_header()?;
        let out = self.inner.as_mut().unwrap();
        for chunk in self.buf.chunks(STORED_BLOCK_MAX) {
            let len = chunk.len() as u16;
            let header = [0x00u8, len as u8, (len >> 8) as u8, !len as u8, (!len >> 8) as u8];
            out.write_all(&header)?;
            out.write_all(chunk)?;
        }
        self.buf.clear();
        Ok(())
    }

    /// Write the final (empty) block and the CRC32/ISIZE trailer, returning
    /// the inner writer.
    pub fn finish(mut self) -> io::Result<W> {
        self.finish_in_place()?;
        Ok(self.inner.take().unwrap())
    }

    fn finish_in_place(&mut self) -> io::Result<()> {
        self.drain_buf()?;
        let crc = self.crc.finish();
        let isize_ = (self.total & 0xFFFF_FFFF) as u32;
        let out = self.inner.as_mut().unwrap();
        // final stored block, LEN = 0
        out.write_all(&[0x01, 0x00, 0x00, 0xFF, 0xFF])?;
        out.write_all(&crc.to_le_bytes())?;
        out.write_all(&isize_.to_le_bytes())?;
        out.flush()
    }
}

impl<W: Write> Write for GzEncoder<W> {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        self.crc.update(data);
        self.total += data.len() as u64;
        self.buf.extend_from_slice(data);
        if self.buf.len() >= ENCODER_BUF {
            self.drain_buf()?;
        }
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.drain_buf()?;
        self.inner.as_mut().unwrap().flush()
    }
}

impl<W: Write> Drop for GzEncoder<W> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            let _ = self.finish_in_place();
        }
    }
}

// ---------------------------------------------------------------------------
// Decoder: bit reader + canonical Huffman (puff-style) + LZ77 window
// ---------------------------------------------------------------------------

const WINDOW: usize = 32 * 1024;
const MAX_BITS: usize = 15;

/// Canonical Huffman table: symbol counts per code length plus symbols in
/// canonical order (the compact representation used by zlib's `puff.c`).
struct Huffman {
    count: [u16; MAX_BITS + 1],
    symbol: Vec<u16>,
}

impl Huffman {
    /// Build from per-symbol code lengths (0 = unused symbol).
    fn build(lengths: &[u8]) -> io::Result<Huffman> {
        let mut count = [0u16; MAX_BITS + 1];
        for &l in lengths {
            if l as usize > MAX_BITS {
                return Err(bad("code length exceeds 15"));
            }
            count[l as usize] += 1;
        }
        if count[0] as usize == lengths.len() {
            // no codes at all — legal for an unused distance table
            return Ok(Huffman { count, symbol: Vec::new() });
        }
        // over-subscription check
        let mut left: i32 = 1;
        for len in 1..=MAX_BITS {
            left <<= 1;
            left -= count[len] as i32;
            if left < 0 {
                return Err(bad("over-subscribed Huffman code"));
            }
        }
        // offsets into symbol table per length
        let mut offs = [0usize; MAX_BITS + 1];
        for len in 1..MAX_BITS {
            offs[len + 1] = offs[len] + count[len] as usize;
        }
        let mut symbol = vec![0u16; lengths.len()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize]] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("gzip: {msg}"))
}

/// DEFLATE length codes 257–285: (extra bits, base length).
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
/// Distance codes 0–29: (extra bits, base distance).
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
/// Order in which code-length code lengths are stored (RFC 1951 §3.2.7).
const CLEN_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

enum DecodeState {
    Header,
    Block,
    Done,
}

/// Streaming gzip reader. Handles *concatenated* members transparently
/// (like `flate2::read::MultiGzDecoder`): after one member's trailer
/// verifies, a following gzip magic starts the next member; EOF or any
/// non-magic trailing byte ends the stream cleanly (`gzip -d` likewise
/// ignores trailing garbage such as NUL padding).
pub struct GzDecoder<R: Read> {
    inner: R,
    /// Lookahead bytes (at most the two magic bytes) pushed back while
    /// probing for a following member at a member boundary.
    peeked: Vec<u8>,
    bit_buf: u32,
    bit_count: u32,
    state: DecodeState,
    /// Sliding back-reference window (ring buffer).
    window: Vec<u8>,
    wpos: usize,
    wfull: bool,
    /// Decoded-but-unread output.
    pending: Vec<u8>,
    pending_off: usize,
    crc: Crc32,
    total: u64,
}

impl<R: Read> GzDecoder<R> {
    /// Wrap a reader positioned at a gzip header.
    pub fn new(inner: R) -> GzDecoder<R> {
        GzDecoder {
            inner,
            peeked: Vec::new(),
            bit_buf: 0,
            bit_count: 0,
            state: DecodeState::Header,
            window: vec![0u8; WINDOW],
            wpos: 0,
            wfull: false,
            pending: Vec::with_capacity(64 * 1024),
            pending_off: 0,
            crc: Crc32::new(),
            total: 0,
        }
    }

    /// Next byte, or `None` at clean EOF.
    fn try_read_byte(&mut self) -> io::Result<Option<u8>> {
        if !self.peeked.is_empty() {
            return Ok(Some(self.peeked.remove(0)));
        }
        let mut b = [0u8; 1];
        loop {
            match self.inner.read(&mut b) {
                Ok(0) => return Ok(None),
                Ok(_) => return Ok(Some(b[0])),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    fn read_byte(&mut self) -> io::Result<u8> {
        self.try_read_byte()?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "gzip: truncated stream")
        })
    }

    fn bits(&mut self, n: u32) -> io::Result<u32> {
        debug_assert!(n <= 16);
        while self.bit_count < n {
            let b = self.read_byte()?;
            self.bit_buf |= (b as u32) << self.bit_count;
            self.bit_count += 8;
        }
        let v = self.bit_buf & ((1u32 << n) - 1);
        self.bit_buf >>= n;
        self.bit_count -= n;
        Ok(v)
    }

    fn align_byte(&mut self) {
        self.bit_buf = 0;
        self.bit_count = 0;
    }

    fn decode_symbol(&mut self, h: &Huffman) -> io::Result<u16> {
        let mut code: u32 = 0;
        let mut first: u32 = 0;
        let mut index: usize = 0;
        for len in 1..=MAX_BITS {
            code |= self.bits(1)?;
            let count = h.count[len] as u32;
            if code >= first && code - first < count {
                return Ok(h.symbol[index + (code - first) as usize]);
            }
            index += count as usize;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(bad("invalid Huffman code"))
    }

    fn emit(&mut self, b: u8) {
        self.pending.push(b);
        self.window[self.wpos] = b;
        self.wpos += 1;
        if self.wpos == WINDOW {
            self.wpos = 0;
            self.wfull = true;
        }
    }

    fn window_byte(&mut self, dist: usize) -> io::Result<u8> {
        let avail = if self.wfull { WINDOW } else { self.wpos };
        if dist == 0 || dist > avail {
            return Err(bad("back-reference before start of stream"));
        }
        let idx = (self.wpos + WINDOW - dist) % WINDOW;
        Ok(self.window[idx])
    }

    fn parse_header(&mut self) -> io::Result<()> {
        // Magic first (via read_byte so the member-boundary lookahead is
        // honored, and so trailing garbage fails as bad magic rather than
        // a truncation error), then the remaining 8 header bytes.
        if self.read_byte()? != 0x1F || self.read_byte()? != 0x8B {
            return Err(bad("not a gzip stream (bad magic)"));
        }
        let mut hdr = [0u8; 8];
        for b in hdr.iter_mut() {
            *b = self.read_byte()?;
        }
        if hdr[0] != 8 {
            return Err(bad("unsupported compression method"));
        }
        let flg = hdr[1];
        if flg & 0x04 != 0 {
            // FEXTRA
            let lo = self.read_byte()? as usize;
            let hi = self.read_byte()? as usize;
            for _ in 0..(lo | (hi << 8)) {
                self.read_byte()?;
            }
        }
        if flg & 0x08 != 0 {
            while self.read_byte()? != 0 {} // FNAME
        }
        if flg & 0x10 != 0 {
            while self.read_byte()? != 0 {} // FCOMMENT
        }
        if flg & 0x02 != 0 {
            self.read_byte()?; // FHCRC
            self.read_byte()?;
        }
        Ok(())
    }

    fn check_trailer(&mut self) -> io::Result<()> {
        self.align_byte();
        let mut tr = [0u8; 8];
        self.inner.read_exact(&mut tr)?;
        let crc = u32::from_le_bytes([tr[0], tr[1], tr[2], tr[3]]);
        let isize_ = u32::from_le_bytes([tr[4], tr[5], tr[6], tr[7]]);
        if crc != self.crc.finish() {
            return Err(bad("CRC32 mismatch"));
        }
        if isize_ != (self.total & 0xFFFF_FFFF) as u32 {
            return Err(bad("ISIZE mismatch"));
        }
        Ok(())
    }

    fn inflate_stored(&mut self) -> io::Result<()> {
        self.align_byte();
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        let len = u16::from_le_bytes([b[0], b[1]]);
        let nlen = u16::from_le_bytes([b[2], b[3]]);
        if len != !nlen {
            return Err(bad("stored block LEN/NLEN mismatch"));
        }
        let mut buf = vec![0u8; len as usize];
        self.inner.read_exact(&mut buf)?;
        for &x in &buf {
            self.emit(x);
        }
        Ok(())
    }

    fn fixed_tables() -> io::Result<(Huffman, Huffman)> {
        let mut litlen = [0u8; 288];
        for (i, l) in litlen.iter_mut().enumerate() {
            *l = match i {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        let dist = [5u8; 30];
        Ok((Huffman::build(&litlen)?, Huffman::build(&dist)?))
    }

    fn dynamic_tables(&mut self) -> io::Result<(Huffman, Huffman)> {
        let hlit = self.bits(5)? as usize + 257;
        let hdist = self.bits(5)? as usize + 1;
        let hclen = self.bits(4)? as usize + 4;
        if hlit > 286 || hdist > 30 {
            return Err(bad("too many litlen/dist codes"));
        }
        let mut clen = [0u8; 19];
        for &pos in CLEN_ORDER.iter().take(hclen) {
            clen[pos] = self.bits(3)? as u8;
        }
        let clen_tab = Huffman::build(&clen)?;
        let mut lengths = vec![0u8; hlit + hdist];
        let mut i = 0;
        while i < lengths.len() {
            let sym = self.decode_symbol(&clen_tab)?;
            match sym {
                0..=15 => {
                    lengths[i] = sym as u8;
                    i += 1;
                }
                16 => {
                    if i == 0 {
                        return Err(bad("repeat with no previous length"));
                    }
                    let prev = lengths[i - 1];
                    let reps = 3 + self.bits(2)? as usize;
                    for _ in 0..reps {
                        if i >= lengths.len() {
                            return Err(bad("length repeat overflows table"));
                        }
                        lengths[i] = prev;
                        i += 1;
                    }
                }
                17 => {
                    let reps = 3 + self.bits(3)? as usize;
                    if i + reps > lengths.len() {
                        return Err(bad("zero repeat overflows table"));
                    }
                    i += reps;
                }
                18 => {
                    let reps = 11 + self.bits(7)? as usize;
                    if i + reps > lengths.len() {
                        return Err(bad("zero repeat overflows table"));
                    }
                    i += reps;
                }
                _ => return Err(bad("bad code-length symbol")),
            }
        }
        if lengths[256] == 0 {
            return Err(bad("missing end-of-block code"));
        }
        let litlen = Huffman::build(&lengths[..hlit])?;
        let dist = Huffman::build(&lengths[hlit..])?;
        Ok((litlen, dist))
    }

    fn inflate_huffman(&mut self, litlen: &Huffman, dist: &Huffman) -> io::Result<()> {
        loop {
            let sym = self.decode_symbol(litlen)?;
            match sym {
                0..=255 => self.emit(sym as u8),
                256 => return Ok(()),
                257..=285 => {
                    let idx = sym as usize - 257;
                    let len =
                        LEN_BASE[idx] as usize + self.bits(LEN_EXTRA[idx] as u32)? as usize;
                    let dsym = self.decode_symbol(dist)? as usize;
                    if dsym >= 30 {
                        return Err(bad("bad distance symbol"));
                    }
                    let d =
                        DIST_BASE[dsym] as usize + self.bits(DIST_EXTRA[dsym] as u32)? as usize;
                    for _ in 0..len {
                        let b = self.window_byte(d)?;
                        self.emit(b);
                    }
                }
                _ => return Err(bad("bad literal/length symbol")),
            }
        }
    }

    /// Decode one DEFLATE block into `pending`. Returns whether the stream
    /// is finished (final block decoded and trailer verified).
    fn decode_block(&mut self) -> io::Result<bool> {
        let final_block = self.bits(1)? == 1;
        let btype = self.bits(2)?;
        let before = self.pending.len();
        match btype {
            0 => self.inflate_stored()?,
            1 => {
                let (l, d) = Self::fixed_tables()?;
                self.inflate_huffman(&l, &d)?;
            }
            2 => {
                let (l, d) = self.dynamic_tables()?;
                self.inflate_huffman(&l, &d)?;
            }
            _ => return Err(bad("reserved block type")),
        }
        let new = self.pending.len() - before;
        self.crc.update(&self.pending[before..]);
        self.total += new as u64;
        if final_block {
            self.check_trailer()?;
        }
        Ok(final_block)
    }

    /// After a member's trailer: probe for a following concatenated
    /// member. Returns `true` (and resets per-member state) only when
    /// BOTH gzip magic bytes follow; EOF or any other trailing bytes end
    /// the stream cleanly — `gzip -d` likewise ignores trailing garbage
    /// (NUL padding from archival tools is common, and it may even start
    /// with a lone 0x1F), and the pre-multi-member reader never looked
    /// past the first trailer. A member that starts with the full magic
    /// but is malformed past it is reported by `parse_header`/decoding.
    fn begin_next_member(&mut self) -> io::Result<bool> {
        debug_assert_eq!(self.bit_count, 0, "trailer read must leave byte alignment");
        let Some(b1) = self.try_read_byte()? else {
            return Ok(false);
        };
        if b1 != 0x1F {
            return Ok(false);
        }
        let Some(b2) = self.try_read_byte()? else {
            return Ok(false);
        };
        if b2 != 0x8B {
            return Ok(false);
        }
        // A real member follows: push the magic back for parse_header.
        self.peeked = vec![b1, b2];
        // CRC32/ISIZE are per member; back-references never cross a
        // member boundary (each member is an independent DEFLATE
        // stream), so the window resets too.
        self.crc.reset();
        self.total = 0;
        self.wpos = 0;
        self.wfull = false;
        self.bit_buf = 0;
        self.bit_count = 0;
        Ok(true)
    }
}

impl<R: Read> Read for GzDecoder<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            if self.pending_off < self.pending.len() {
                let n = (self.pending.len() - self.pending_off).min(buf.len());
                buf[..n].copy_from_slice(&self.pending[self.pending_off..self.pending_off + n]);
                self.pending_off += n;
                if self.pending_off == self.pending.len() {
                    self.pending.clear();
                    self.pending_off = 0;
                }
                return Ok(n);
            }
            match self.state {
                DecodeState::Done => return Ok(0),
                DecodeState::Header => {
                    self.parse_header()?;
                    self.state = DecodeState::Block;
                }
                DecodeState::Block => {
                    if self.decode_block()? {
                        // Member finished (trailer verified). Concatenated
                        // members continue the logical stream.
                        self.state = if self.begin_next_member()? {
                            DecodeState::Header
                        } else {
                            DecodeState::Done
                        };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn decode_all(raw: &[u8]) -> Vec<u8> {
        let mut d = GzDecoder::new(raw);
        let mut out = Vec::new();
        d.read_to_end(&mut out).unwrap();
        out
    }

    // `gzip.compress(data, 6, mtime=0)` of the repeated pangram line —
    // first block is BTYPE=2 (dynamic Huffman), covering the general path.
    const GZ_DYNAMIC: &[u8] = &[
        0x1f, 0x8b, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xff, 0xed, 0xcb, 0xc9, 0x15,
        0x40, 0x30, 0x14, 0x05, 0xd0, 0xbd, 0x2a, 0x5e, 0x09, 0xe6, 0xa1, 0x1c, 0x24, 0x66,
        0x3e, 0x91, 0x98, 0xaa, 0xa7, 0x08, 0xcb, 0xb7, 0xbe, 0xe7, 0xda, 0x4e, 0x63, 0x73,
        0x7d, 0x3d, 0xa2, 0x32, 0x72, 0x2e, 0x68, 0xe4, 0xc2, 0xe0, 0xe6, 0x75, 0x87, 0x1c,
        0xda, 0xc0, 0x7e, 0x3c, 0x95, 0xcf, 0x0d, 0x25, 0x2d, 0xfc, 0x20, 0x8c, 0xe2, 0x24,
        0xcd, 0xf2, 0xc2, 0xb3, 0x6c, 0x6c, 0x6c, 0x6c, 0x6c, 0x6c, 0x6c, 0x6c, 0x7f, 0xb7,
        0x17, 0x35, 0x61, 0x78, 0x79, 0x98, 0x08, 0x00, 0x00,
    ];

    // `gzip.compress(b"hello hello hello gzip", 6, mtime=0)` — BTYPE=1
    // (fixed Huffman) with back-references.
    const GZ_SMALL: &[u8] = &[
        0x1f, 0x8b, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xff, 0xcb, 0x48, 0xcd, 0xc9,
        0xc9, 0x57, 0xc8, 0x40, 0x22, 0xd3, 0xab, 0x32, 0x0b, 0x00, 0x47, 0x3a, 0x59, 0x1c,
        0x16, 0x00, 0x00, 0x00,
    ];

    #[test]
    fn decodes_dynamic_huffman_stream() {
        let want: Vec<u8> =
            b"the quick brown fox jumps over the lazy dog 0123456789\n".repeat(40);
        assert_eq!(decode_all(GZ_DYNAMIC), want);
    }

    #[test]
    fn decodes_fixed_huffman_stream() {
        assert_eq!(decode_all(GZ_SMALL), b"hello hello hello gzip");
    }

    #[test]
    fn corrupted_crc_is_rejected() {
        let mut raw = GZ_SMALL.to_vec();
        let n = raw.len();
        raw[n - 6] ^= 0xFF; // flip a CRC byte
        let mut d = GzDecoder::new(&raw[..]);
        let mut out = Vec::new();
        assert!(d.read_to_end(&mut out).is_err());
    }

    #[test]
    fn encoder_roundtrip_small() {
        let data = b"stored-block roundtrip \x00\x01\x02 with binary bytes";
        let mut enc = GzEncoder::new(Vec::new());
        enc.write_all(data).unwrap();
        let raw = enc.finish().unwrap();
        assert_eq!(decode_all(&raw), data);
    }

    #[test]
    fn encoder_roundtrip_large_random() {
        // > one stored block and > encoder buffer, exercising chunking.
        let mut rng = Rng::seed_from(404);
        let data: Vec<u8> = (0..200_000).map(|_| rng.below(256) as u8).collect();
        let mut enc = GzEncoder::new(Vec::new());
        // uneven write sizes
        let mut off = 0;
        let mut step = 1;
        while off < data.len() {
            let end = (off + step).min(data.len());
            enc.write_all(&data[off..end]).unwrap();
            off = end;
            step = (step * 7 + 3) % 4096 + 1;
        }
        let raw = enc.finish().unwrap();
        assert_eq!(decode_all(&raw), data);
    }

    #[test]
    fn encoder_empty_input() {
        let enc = GzEncoder::new(Vec::new());
        let raw = enc.finish().unwrap();
        assert_eq!(decode_all(&raw), b"");
    }

    #[test]
    fn drop_finalizes_stream() {
        let mut sink = Vec::new();
        {
            let mut enc = GzEncoder::new(&mut sink);
            enc.write_all(b"finalized on drop").unwrap();
        } // drop writes the trailer
        assert_eq!(decode_all(&sink), b"finalized on drop");
    }

    #[test]
    fn multi_member_concatenation_decodes_as_one_stream() {
        // RFC 1952 §2.2: concatenated gzip members decompress to the
        // concatenation of their contents — the shape real appended
        // docword dumps take. Mix encoder output with the fixed- and
        // dynamic-Huffman fixtures to cover every block type across a
        // member boundary.
        let mut enc = GzEncoder::new(Vec::new());
        enc.write_all(b"first member; ").unwrap();
        let first = enc.finish().unwrap();

        let mut raw = first.clone();
        raw.extend_from_slice(GZ_SMALL);
        let mut want = b"first member; ".to_vec();
        want.extend_from_slice(b"hello hello hello gzip");
        assert_eq!(decode_all(&raw), want);

        // three members, dynamic-Huffman in the middle
        let mut raw3 = first.clone();
        raw3.extend_from_slice(GZ_DYNAMIC);
        raw3.extend_from_slice(GZ_SMALL);
        let mut want3 = b"first member; ".to_vec();
        want3.extend(b"the quick brown fox jumps over the lazy dog 0123456789\n".repeat(40));
        want3.extend_from_slice(b"hello hello hello gzip");
        assert_eq!(decode_all(&raw3), want3);
    }

    #[test]
    fn multi_member_empty_members_are_fine() {
        let empty = GzEncoder::new(Vec::new()).finish().unwrap();
        let mut raw = empty.clone();
        raw.extend_from_slice(&empty);
        raw.extend_from_slice(GZ_SMALL);
        assert_eq!(decode_all(&raw), b"hello hello hello gzip");
    }

    #[test]
    fn multi_member_crc_checked_per_member() {
        // Corrupt the SECOND member's CRC: the first member must decode,
        // the stream as a whole must error.
        let mut enc = GzEncoder::new(Vec::new());
        enc.write_all(b"ok part").unwrap();
        let mut raw = enc.finish().unwrap();
        let mut second = GZ_SMALL.to_vec();
        let n = second.len();
        second[n - 6] ^= 0xFF;
        raw.extend_from_slice(&second);
        let mut d = GzDecoder::new(&raw[..]);
        let mut out = Vec::new();
        assert!(d.read_to_end(&mut out).is_err());
    }

    #[test]
    fn trailing_garbage_is_ignored_like_gzip_cli() {
        // `gzip -d` ignores trailing non-member bytes (NUL padding from
        // tape/archival tools); so do we — the decoded data is complete
        // and the stream ends cleanly. Includes garbage that starts with
        // a lone magic byte, and a bare 0x1F at EOF.
        for garbage in [&b"NOT GZIP"[..], &[0u8; 512][..], &[0x1F, 0x00, 0x08][..], &[0x1F][..]] {
            let mut raw = GZ_SMALL.to_vec();
            raw.extend_from_slice(garbage);
            assert_eq!(decode_all(&raw), b"hello hello hello gzip");
        }
    }

    #[test]
    fn truncated_second_member_is_an_error() {
        // A trailing byte that DOES start the gzip magic is a member;
        // malformation past that point must surface, not be swallowed.
        let mut raw = GZ_SMALL.to_vec();
        raw.extend_from_slice(&[0x1F, 0x8B, 0x08]); // magic, then truncation
        let mut d = GzDecoder::new(&raw[..]);
        let mut out = Vec::new();
        assert!(d.read_to_end(&mut out).is_err());
    }

    #[test]
    fn crc32_known_value() {
        // CRC32("123456789") = 0xCBF43926 (classic check value)
        let mut c = Crc32::new();
        c.update(b"123456789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }
}
