//! Scoped data-parallel helpers built on the hand-rolled bounded channel
//! from [`crate::stream`] — the same std-only worker-pool idiom the
//! streaming passes use, packaged for compute kernels (λ-search probes,
//! path grids, Gram shards, deflation row blocks). No external deps.
//!
//! Determinism contract (relied on by the `threads=1 == threads=4`
//! property tests): work decomposition is fixed by the *inputs*, never by
//! the thread count. Each index/chunk is processed exactly once by a pure
//! function, and results are merged in index order, so outputs are
//! bitwise identical for any `threads`.

use crate::stream::bounded;

/// Resolve a thread-count knob: `0` means "ask the OS", anything else is
/// taken literally. Always ≥ 1.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Map `f` over `0..n` on up to `threads` scoped workers, returning the
/// results in index order. `threads <= 1` (or tiny `n`) runs inline.
///
/// Work is distributed dynamically through a bounded channel, so uneven
/// per-index costs (e.g. λ probes whose safe-elimination sizes differ)
/// balance across workers.
pub fn par_map_indexed<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = resolve_threads(threads).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(n, || None);
    let collected: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let (tx, rx) = bounded::<usize>(n);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = rx.clone();
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                while let Some(i) = rx.recv() {
                    out.push((i, f(i)));
                }
                out
            }));
        }
        drop(rx);
        for i in 0..n {
            if tx.send(i).is_err() {
                break; // all workers gone (panic); join below re-raises
            }
        }
        tx.close();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel worker panicked"))
            .collect()
    });
    for (i, v) in collected.into_iter().flatten() {
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("parallel worker dropped an index"))
        .collect()
}

/// Apply `f(offset, chunk)` to consecutive `chunk_len`-sized pieces of
/// `data` on up to `threads` scoped workers. Chunk boundaries depend only
/// on `chunk_len`, so the mutation is deterministic for any thread count
/// (chunks are disjoint and each is processed exactly once).
pub fn par_chunks_mut<T, F>(threads: usize, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let threads = resolve_threads(threads);
    if threads <= 1 || data.len() <= chunk_len {
        let mut off = 0;
        for c in data.chunks_mut(chunk_len) {
            let len = c.len();
            f(off, c);
            off += len;
        }
        return;
    }
    let f = &f;
    std::thread::scope(|scope| {
        let (tx, rx) = bounded::<(usize, &mut [T])>(2 * threads);
        for _ in 0..threads {
            let rx = rx.clone();
            scope.spawn(move || {
                while let Some((off, c)) = rx.recv() {
                    f(off, c);
                }
            });
        }
        drop(rx);
        let mut off = 0;
        for c in data.chunks_mut(chunk_len) {
            let len = c.len();
            if tx.send((off, c)).is_err() {
                break;
            }
            off += len;
        }
        tx.close();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_semantics() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn par_map_matches_serial_any_thread_count() {
        let f = |i: usize| (i as f64 + 1.0).sqrt() * 3.0;
        let want: Vec<f64> = (0..97).map(f).collect();
        for t in [1, 2, 4, 7] {
            let got = par_map_indexed(t, 97, f);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let got: Vec<usize> = par_map_indexed(4, 0, |i| i);
        assert!(got.is_empty());
        let got = par_map_indexed(4, 1, |i| i * 2);
        assert_eq!(got, vec![0]);
    }

    #[test]
    fn par_chunks_mut_covers_everything_once() {
        let mut data: Vec<u64> = (0..10_001).collect();
        par_chunks_mut(4, &mut data, 128, |off, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                assert_eq!(*v, (off + k) as u64, "offset bookkeeping");
                *v += 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn uneven_work_still_complete() {
        let got = par_map_indexed(3, 40, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i * i
        });
        assert_eq!(got.len(), 40);
        assert_eq!(got[39], 39 * 39);
    }
}
