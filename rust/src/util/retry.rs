//! Retry with capped exponential backoff for transient I/O.
//!
//! The cache-layer files (variance checkpoints, covariance shards, job
//! state) live on whatever filesystem the operator points `cache_dir`
//! at — often network-attached at the corpus scales the paper targets —
//! where reads and writes can fail *transiently* (`EINTR`, a timeout, a
//! momentarily unreachable mount). Aborting a multi-hour streaming pass
//! on the first `Interrupted` is exactly the fragility this layer
//! removes: [`with_retry`] re-runs the operation with deterministic
//! capped exponential backoff and only surfaces the error once the
//! attempt budget is spent, tagging it so callers can map it to
//! [`crate::error::LsspcaError::is_transient`].
//!
//! Only *transient* [`std::io::ErrorKind`]s are retried (see
//! [`is_transient_kind`]); permanent failures — `NotFound`,
//! `PermissionDenied`, `UnexpectedEof` (truncation is damage, not
//! weather) — surface immediately on the first attempt.

use std::io;
use std::sync::Mutex;
use std::time::Duration;

/// Deterministic capped-exponential-backoff schedule. No jitter: runs
/// must be reproducible, and the in-process contention jitter exists to
/// fight does not apply to the single-writer cache files involved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts, including the first (`>= 1`). 1 = no retry.
    pub attempts: u32,
    /// Backoff before the first retry, in milliseconds; doubles each
    /// retry after that.
    pub base_delay_ms: u64,
    /// Ceiling on any single backoff, in milliseconds.
    pub max_delay_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 3, base_delay_ms: 10, max_delay_ms: 1000 }
    }
}

impl RetryPolicy {
    /// The backoff before retry number `retry` (0-based):
    /// `min(base_delay_ms << retry, max_delay_ms)`.
    pub fn delay_ms(&self, retry: u32) -> u64 {
        let shifted = self.base_delay_ms.checked_shl(retry).unwrap_or(u64::MAX);
        shifted.min(self.max_delay_ms)
    }
}

/// `true` for [`std::io::ErrorKind`]s worth retrying: the OS or the
/// fault-injection harness said "try again", not "this file is gone".
pub fn is_transient_kind(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted | io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock
    )
}

/// Outcome of [`with_retry`] when every attempt failed.
#[derive(Debug)]
pub struct RetryError {
    /// The error from the final attempt.
    pub error: io::Error,
    /// Attempts actually made.
    pub attempts: u32,
    /// `true` when the final error was a transient kind — i.e. the
    /// budget ran out on retryable weather; `false` means the operation
    /// hit a permanent failure (no further attempts were made).
    pub transient: bool,
}

impl RetryError {
    /// Render as `"<what>: <error> (after N attempts)"` — the message
    /// shape the cache-layer error constructors wrap.
    pub fn describe(&self, what: &str) -> String {
        if self.attempts > 1 {
            format!("{what}: {} (after {} attempts)", self.error, self.attempts)
        } else {
            format!("{what}: {}", self.error)
        }
    }
}

/// Run `op`, retrying transient failures per `policy`. Permanent errors
/// return after the first attempt with `transient: false`.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    mut op: impl FnMut() -> io::Result<T>,
) -> Result<T, RetryError> {
    let attempts = policy.attempts.max(1);
    let mut made = 0;
    loop {
        made += 1;
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let transient = is_transient_kind(e.kind());
                if !transient || made >= attempts {
                    return Err(RetryError { error: e, attempts: made, transient });
                }
                std::thread::sleep(Duration::from_millis(policy.delay_ms(made - 1)));
            }
        }
    }
}

static GLOBAL_POLICY: Mutex<RetryPolicy> =
    Mutex::new(RetryPolicy { attempts: 3, base_delay_ms: 10, max_delay_ms: 1000 });

/// Install the process-wide policy the cache layers use (set from
/// `[robustness] retry_attempts` / `retry_base_ms` at pipeline start).
pub fn set_policy(policy: RetryPolicy) {
    *GLOBAL_POLICY.lock().unwrap() = policy;
}

/// The current process-wide policy.
pub fn policy() -> RetryPolicy {
    *GLOBAL_POLICY.lock().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn interrupted() -> io::Error {
        io::Error::new(io::ErrorKind::Interrupted, "fake EINTR")
    }

    #[test]
    fn first_try_success_needs_no_retries() {
        let mut calls = 0;
        let r = with_retry(&RetryPolicy::default(), || {
            calls += 1;
            Ok::<_, io::Error>(42)
        });
        assert_eq!(r.unwrap(), 42);
        assert_eq!(calls, 1);
    }

    #[test]
    fn transient_errors_retry_until_success() {
        let fast = RetryPolicy { attempts: 5, base_delay_ms: 0, max_delay_ms: 0 };
        let mut calls = 0;
        let r = with_retry(&fast, || {
            calls += 1;
            if calls < 3 { Err(interrupted()) } else { Ok(7) }
        });
        assert_eq!(r.unwrap(), 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn budget_exhaustion_reports_transient() {
        let fast = RetryPolicy { attempts: 3, base_delay_ms: 0, max_delay_ms: 0 };
        let mut calls = 0;
        let e = with_retry(&fast, || -> io::Result<()> {
            calls += 1;
            Err(interrupted())
        })
        .unwrap_err();
        assert_eq!(calls, 3);
        assert_eq!(e.attempts, 3);
        assert!(e.transient);
        assert!(e.describe("reading x").contains("after 3 attempts"), "{}", e.describe("reading x"));
    }

    #[test]
    fn permanent_errors_fail_fast() {
        let mut calls = 0;
        let e = with_retry(&RetryPolicy::default(), || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::NotFound, "gone"))
        })
        .unwrap_err();
        assert_eq!(calls, 1, "permanent errors must not burn the budget");
        assert!(!e.transient);
    }

    #[test]
    fn truncation_is_not_transient() {
        // UnexpectedEof means the file is damaged; retrying re-reads the
        // same damage.
        assert!(!is_transient_kind(io::ErrorKind::UnexpectedEof));
        assert!(is_transient_kind(io::ErrorKind::Interrupted));
        assert!(is_transient_kind(io::ErrorKind::TimedOut));
        assert!(is_transient_kind(io::ErrorKind::WouldBlock));
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy { attempts: 10, base_delay_ms: 10, max_delay_ms: 35 };
        assert_eq!(p.delay_ms(0), 10);
        assert_eq!(p.delay_ms(1), 20);
        assert_eq!(p.delay_ms(2), 35); // 40 capped
        assert_eq!(p.delay_ms(63), 35); // shift overflow saturates, then caps
    }
}
