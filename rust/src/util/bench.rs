//! Micro-benchmark harness (offline substitute for `criterion`, see
//! DESIGN.md §3) used by every `cargo bench` target.
//!
//! Each measurement runs warmups, then samples wall time until a time or
//! iteration budget is exhausted, and reports min/median/p95. Results
//! print in a stable, grep-friendly format that EXPERIMENTS.md quotes
//! directly.

use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Configuration for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Minimum timed iterations.
    pub min_iters: usize,
    /// Maximum timed iterations.
    pub max_iters: usize,
    /// Stop sampling after this many seconds (after min_iters).
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 2, min_iters: 5, max_iters: 200, max_seconds: 5.0 }
    }
}

impl BenchConfig {
    /// Budget for expensive end-to-end benches.
    pub fn slow() -> BenchConfig {
        BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 20, max_seconds: 20.0 }
    }
}

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Timing summary over the samples.
    pub summary: Summary,
}

impl BenchResult {
    /// Print the one-line `bench <name> ...` summary.
    pub fn print(&self) {
        let s = &self.summary;
        println!(
            "bench {:<44} min {:>10}  p50 {:>10}  p95 {:>10}  (n={})",
            self.name,
            crate::util::human_secs(s.min),
            crate::util::human_secs(s.p50),
            crate::util::human_secs(s.p95),
            s.n
        );
    }
}

/// Measure a closure. The closure's return value is black-boxed to keep
/// the optimizer honest.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::new();
    let budget = Timer::start();
    for i in 0..cfg.max_iters {
        let t = Timer::start();
        black_box(f());
        samples.push(t.secs());
        if i + 1 >= cfg.min_iters && budget.secs() > cfg.max_seconds {
            break;
        }
    }
    let result = BenchResult { name: name.to_string(), summary: Summary::of(&samples) };
    result.print();
    result
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

/// Print a `key = value` metric line (grep-friendly: `metric <name> = ...`).
pub fn metric(name: &str, value: impl std::fmt::Display) {
    println!("metric {name} = {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench(
            "noop",
            BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 5, max_seconds: 0.1 },
            || 1 + 1,
        );
        assert_eq!(r.name, "noop");
        assert!(r.summary.n >= 3);
        assert!(r.summary.min >= 0.0);
    }

    #[test]
    fn respects_time_budget() {
        let t = Timer::start();
        bench(
            "sleepy",
            BenchConfig { warmup_iters: 0, min_iters: 2, max_iters: 1000, max_seconds: 0.05 },
            || std::thread::sleep(std::time::Duration::from_millis(5)),
        );
        assert!(t.secs() < 2.0);
    }
}
