//! Deterministic pseudo-random number generation.
//!
//! Xoshiro256++ seeded through SplitMix64 — the standard pairing recommended
//! by the xoshiro authors. Deterministic across platforms, which the test
//! suite and the synthetic-corpus generators rely on (every experiment in
//! EXPERIMENTS.md is reproducible from a fixed seed).

/// SplitMix64 step: used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box–Muller, with caching of the pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Rejection-free polar-less Box–Muller; avoid u = 0.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal deviate with the given mean and standard deviation.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Poisson deviate (Knuth for small λ, normal approximation for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let v = self.gauss_ms(lambda, lambda.sqrt()) + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normal deviates.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Split off an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::seed_from(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::seed_from(3);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gauss();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Rng::seed_from(4);
        for &lambda in &[0.5, 3.0, 50.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| rng.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < 0.1 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::seed_from(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 7 * counts[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(6);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(7);
        let idx = rng.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::seed_from(8);
        let mut b = a.split();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
