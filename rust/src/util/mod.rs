//! Small self-contained utilities: RNG, timers, running statistics, ASCII
//! plotting and a property-testing mini-framework.
//!
//! The execution environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `criterion`, `proptest`) are re-implemented here at the scale this
//! repository needs. See DESIGN.md §3 (substitutions).

pub mod bench;
pub mod check;
pub mod faultinject;
pub mod gzip;
pub mod json;
pub mod parallel;
pub mod plot;
pub mod retry;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::RunningStats;
pub use timer::Timer;

/// Order-sensitive xor-fold checksum over 8-byte little-endian lanes —
/// the integrity check shared by the on-disk binary formats
/// ([`crate::checkpoint`] `.lspv` and [`crate::model`] `.lspm`). Cheap
/// and order-sensitive enough to catch truncation and bit rot; not
/// cryptographic.
pub fn xor_fold_checksum(buf: &[u8]) -> u64 {
    let mut acc: u64 = 0x9e3779b97f4a7c15;
    for (i, chunk) in buf.chunks(8).enumerate() {
        let mut lane = [0u8; 8];
        lane[..chunk.len()].copy_from_slice(chunk);
        acc ^= u64::from_le_bytes(lane).rotate_left((i % 63) as u32);
    }
    acc
}

/// Crash-atomic file replacement: write `bytes` to `<path>.tmp`, fsync,
/// then rename over `path`. A crash (or injected kill) at any point
/// leaves either the old file or the new one — never a half-written
/// hybrid — because the rename is the only step that touches `path` and
/// POSIX renames within a directory are atomic. The write stream runs
/// through [`faultinject::wrap_write`] under `tag`, so tests can tear
/// or kill it at scripted offsets; the orphaned `.tmp` is removed
/// best-effort on failure.
pub fn atomic_write(path: &std::path::Path, tag: &str, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let tmp = path.with_extension(match path.extension() {
        Some(ext) => format!("{}.tmp", ext.to_string_lossy()),
        None => "tmp".to_string(),
    });
    let result = (|| {
        let file = std::fs::File::create(&tmp)?;
        let mut w = faultinject::wrap_write(tag, file);
        w.write_all(bytes)?;
        w.flush()?;
        // Durability before visibility: the data must be on disk before
        // the rename can make it the canonical file.
        let file = w.into_inner();
        file.sync_all()?;
        drop(file);
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Format a number of bytes in a human-friendly way (KiB/MiB/GiB).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Format a duration in seconds with adaptive precision.
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn atomic_write_replaces_or_preserves_never_tears() {
        let _g = faultinject::test_guard();
        let dir = std::env::temp_dir().join(format!("lsspca_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("target.bin");
        atomic_write(&path, "t", b"original contents").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"original contents");
        // A torn write mid-replacement must leave the original intact
        // and no .tmp debris behind.
        faultinject::scoped(faultinject::FaultPlan::parse("wtorn:t@4").unwrap(), || {
            let e = atomic_write(&path, "t", b"replacement that tears").unwrap_err();
            assert!(e.to_string().contains("torn"), "{e}");
        });
        assert_eq!(std::fs::read(&path).unwrap(), b"original contents");
        assert!(!path.with_extension("bin.tmp").exists(), "tmp file must be cleaned up");
        // With the plan spent, the same replacement goes through.
        atomic_write(&path, "t", b"replacement that lands").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"replacement that lands");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn human_secs_ranges() {
        assert!(human_secs(5e-9).ends_with("ns"));
        assert!(human_secs(5e-5).ends_with("µs"));
        assert!(human_secs(5e-2).ends_with("ms"));
        assert!(human_secs(5.0).ends_with(" s"));
        assert!(human_secs(500.0).ends_with("min"));
    }
}
