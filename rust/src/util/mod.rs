//! Small self-contained utilities: RNG, timers, running statistics, ASCII
//! plotting and a property-testing mini-framework.
//!
//! The execution environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `criterion`, `proptest`) are re-implemented here at the scale this
//! repository needs. See DESIGN.md §3 (substitutions).

pub mod bench;
pub mod check;
pub mod gzip;
pub mod json;
pub mod parallel;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::RunningStats;
pub use timer::Timer;

/// Order-sensitive xor-fold checksum over 8-byte little-endian lanes —
/// the integrity check shared by the on-disk binary formats
/// ([`crate::checkpoint`] `.lspv` and [`crate::model`] `.lspm`). Cheap
/// and order-sensitive enough to catch truncation and bit rot; not
/// cryptographic.
pub fn xor_fold_checksum(buf: &[u8]) -> u64 {
    let mut acc: u64 = 0x9e3779b97f4a7c15;
    for (i, chunk) in buf.chunks(8).enumerate() {
        let mut lane = [0u8; 8];
        lane[..chunk.len()].copy_from_slice(chunk);
        acc ^= u64::from_le_bytes(lane).rotate_left((i % 63) as u32);
    }
    acc
}

/// Format a number of bytes in a human-friendly way (KiB/MiB/GiB).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Format a duration in seconds with adaptive precision.
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_ranges() {
        assert!(human_secs(5e-9).ends_with("ns"));
        assert!(human_secs(5e-5).ends_with("µs"));
        assert!(human_secs(5e-2).ends_with("ms"));
        assert!(human_secs(5.0).ends_with(" s"));
        assert!(human_secs(500.0).ends_with("min"));
    }
}
