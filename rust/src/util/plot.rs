//! Minimal ASCII plotting for figure reproduction in a terminal-only
//! environment (Fig 1 convergence curves, Fig 2 variance decay).

/// Render one or more named series as an ASCII scatter/line chart.
///
/// Each series is a list of `(x, y)` points. Axes can independently be
/// log-scaled (points with non-positive coordinates are dropped under log).
pub struct AsciiPlot {
    width: usize,
    height: usize,
    logx: bool,
    logy: bool,
    title: String,
    series: Vec<(String, char, Vec<(f64, f64)>)>,
}

impl AsciiPlot {
    /// Empty plot with the default 72×20 canvas.
    pub fn new(title: &str) -> Self {
        AsciiPlot {
            width: 72,
            height: 20,
            logx: false,
            logy: false,
            title: title.to_string(),
            series: Vec::new(),
        }
    }

    /// Set the canvas size (clamped to a sane minimum).
    pub fn size(mut self, width: usize, height: usize) -> Self {
        self.width = width.max(16);
        self.height = height.max(6);
        self
    }

    /// Log-scale the x axis.
    pub fn logx(mut self) -> Self {
        self.logx = true;
        self
    }

    /// Log-scale the y axis.
    pub fn logy(mut self) -> Self {
        self.logy = true;
        self
    }

    /// Add a named point series drawn with `marker`.
    pub fn series(mut self, name: &str, marker: char, pts: &[(f64, f64)]) -> Self {
        self.series.push((name.to_string(), marker, pts.to_vec()));
        self
    }

    fn tx(&self, x: f64) -> Option<f64> {
        if self.logx {
            (x > 0.0).then(|| x.log10())
        } else {
            Some(x)
        }
    }

    fn ty(&self, y: f64) -> Option<f64> {
        if self.logy {
            (y > 0.0).then(|| y.log10())
        } else {
            Some(y)
        }
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut pts_all: Vec<(f64, f64)> = Vec::new();
        for (_, _, pts) in &self.series {
            for &(x, y) in pts {
                if let (Some(tx), Some(ty)) = (self.tx(x), self.ty(y)) {
                    pts_all.push((tx, ty));
                }
            }
        }
        if pts_all.is_empty() {
            return format!("{}\n<no data>\n", self.title);
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts_all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if (xmax - xmin).abs() < 1e-300 {
            xmax = xmin + 1.0;
        }
        if (ymax - ymin).abs() < 1e-300 {
            ymax = ymin + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (_, marker, pts) in &self.series {
            for &(x, y) in pts {
                if let (Some(tx), Some(ty)) = (self.tx(x), self.ty(y)) {
                    let cx = ((tx - xmin) / (xmax - xmin) * (self.width - 1) as f64).round() as usize;
                    let cy = ((ty - ymin) / (ymax - ymin) * (self.height - 1) as f64).round() as usize;
                    let row = self.height - 1 - cy.min(self.height - 1);
                    grid[row][cx.min(self.width - 1)] = *marker;
                }
            }
        }
        let fmt = |v: f64, log: bool| -> String {
            if log {
                format!("{:.3e}", 10f64.powf(v))
            } else {
                format!("{v:.4}")
            }
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        for (name, marker, _) in &self.series {
            out.push_str(&format!("  [{marker}] {name}\n"));
        }
        let ytop = fmt(ymax, self.logy);
        let ybot = fmt(ymin, self.logy);
        for (i, row) in grid.iter().enumerate() {
            let label = if i == 0 {
                ytop.clone()
            } else if i == self.height - 1 {
                ybot.clone()
            } else {
                String::new()
            };
            out.push_str(&format!("{label:>11} |{}|\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{:>11}  {:<w$}{}\n",
            "",
            fmt(xmin, self.logx),
            fmt(xmax, self.logx),
            w = self.width.saturating_sub(8)
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points() {
        let p = AsciiPlot::new("test")
            .size(40, 10)
            .series("line", '*', &[(0.0, 0.0), (1.0, 1.0), (2.0, 2.0)]);
        let r = p.render();
        assert!(r.contains("test"));
        assert!(r.matches('*').count() >= 3);
    }

    #[test]
    fn log_drops_nonpositive() {
        let p = AsciiPlot::new("log")
            .logy()
            .series("s", 'o', &[(1.0, 0.0), (2.0, 10.0), (3.0, 100.0)]);
        let r = p.render();
        // y=0 dropped, two points remain
        assert!(r.matches('o').count() >= 2);
    }

    #[test]
    fn empty_series_ok() {
        let p = AsciiPlot::new("empty").series("s", 'x', &[]);
        assert!(p.render().contains("<no data>"));
    }

    #[test]
    fn constant_series_no_panic() {
        let p = AsciiPlot::new("const").series("s", '#', &[(1.0, 5.0), (2.0, 5.0)]);
        let _ = p.render();
    }
}
