//! Running statistics (Welford) and simple descriptive statistics over
//! sample vectors — used by the bench harness and by the streaming moment
//! engine's per-worker accumulators.

/// Welford running mean/variance accumulator.
///
/// Numerically stable single-pass; two accumulators can be merged with
/// [`RunningStats::merge`] (Chan et al.'s parallel combination), which is
/// what the sharded moment workers rely on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunningStats {
    /// Number of observations.
    pub n: u64,
    /// Running mean.
    pub mean: f64,
    /// Sum of squared deviations from the mean (M2).
    pub m2: f64,
}

impl RunningStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Add `k` identical observations of value `x` in O(1).
    ///
    /// This is the workhorse for bag-of-words data where a feature is zero
    /// in most documents: the zeros are folded in with a single call.
    #[inline]
    pub fn push_repeated(&mut self, x: f64, k: u64) {
        if k == 0 {
            return;
        }
        let other = RunningStats { n: k, mean: x, m2: 0.0 };
        self.merge(&other);
    }

    /// Merge another accumulator into this one (parallel combination).
    #[inline]
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }

    /// Population variance (divides by n, matching the covariance matrix
    /// convention Σ = AᵀA/m used throughout).
    #[inline]
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).max(0.0)
        }
    }

    /// Sample variance (divides by n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).max(0.0)
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Descriptive summary of a sample: used by the bench harness.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample (sorts a copy).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut rs = RunningStats::new();
        for &x in &s {
            rs.push(x);
        }
        Summary {
            n: s.len(),
            mean: rs.mean,
            stddev: rs.sample_variance().sqrt(),
            min: s[0],
            p50: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
            max: *s.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Ordinary least squares fit of `y = a + b x`; returns `(a, b)`.
///
/// Used by the complexity bench to fit `log(time) = a + b log(n)` and report
/// the measured exponent.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_naive() {
        let mut rng = Rng::seed_from(10);
        let xs: Vec<f64> = (0..1000).map(|_| rng.gauss_ms(3.0, 2.0)).collect();
        let mut rs = RunningStats::new();
        for &x in &xs {
            rs.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((rs.mean - mean).abs() < 1e-10);
        assert!((rs.variance() - var).abs() < 1e-10);
    }

    #[test]
    fn merge_equals_single_pass() {
        let mut rng = Rng::seed_from(11);
        let xs: Vec<f64> = (0..500).map(|_| rng.gauss()).collect();
        let mut whole = RunningStats::new();
        for &x in &xs {
            whole.push(x);
        }
        // Split at an arbitrary point and merge.
        let (a, b) = xs.split_at(137);
        let mut ra = RunningStats::new();
        let mut rb = RunningStats::new();
        a.iter().for_each(|&x| ra.push(x));
        b.iter().for_each(|&x| rb.push(x));
        ra.merge(&rb);
        assert_eq!(ra.n, whole.n);
        assert!((ra.mean - whole.mean).abs() < 1e-12);
        assert!((ra.m2 - whole.m2).abs() < 1e-9);
    }

    #[test]
    fn push_repeated_equals_loop() {
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        a.push(2.0);
        a.push_repeated(0.0, 7);
        a.push(5.0);
        for x in [2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 5.0] {
            b.push(x);
        }
        assert_eq!(a.n, b.n);
        assert!((a.mean - b.mean).abs() < 1e-12);
        assert!((a.m2 - b.m2).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = RunningStats::new();
        a.push(1.0);
        let before = a;
        a.merge(&RunningStats::new());
        assert_eq!(a, before);
        let mut e = RunningStats::new();
        e.merge(&a);
        assert_eq!(e, a);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_sane() {
        let sm = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(sm.n, 5);
        assert!((sm.mean - 3.0).abs() < 1e-12);
        assert_eq!(sm.min, 1.0);
        assert_eq!(sm.max, 5.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 + 3.0 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
    }
}
