//! Minimal JSON parser/serializer (offline substitute for `serde_json`,
//! see DESIGN.md §3), shared by the serving layer (`crate::serve`) and
//! the bench-regression gate (`lsspca bench --compare`).
//!
//! Covers the full JSON grammar the repo produces and consumes: objects
//! (insertion-ordered), arrays, strings with escapes (including `\uXXXX`
//! with surrogate pairs), numbers as `f64`, booleans and null. Parsing is
//! depth-limited because the server feeds it untrusted request bodies.

/// Maximum nesting depth accepted by the parser (the server parses
/// untrusted bodies; unbounded recursion would be a stack-overflow DoS).
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (parsed as f64).
    Num(f64),
    /// String (escapes decoded).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Key→value pairs in insertion order (duplicate keys: last wins on
    /// lookup, all are preserved for serialization).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    /// Failures are [`crate::error::LsspcaError::Config`] — malformed
    /// input handed to the parser, whatever its transport.
    pub fn parse(text: &str) -> Result<Json, crate::error::LsspcaError> {
        use crate::error::LsspcaError;
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos, 0).map_err(LsspcaError::config)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(LsspcaError::config(format!("trailing data at byte {pos}")));
        }
        Ok(v)
    }

    /// Object field lookup (last occurrence wins).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["gate", "qp_micro_median_secs"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Numeric view, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Boolean view, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String view, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array view, if this is a [`Json::Arr`].
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }
}

/// Serialization (compact, no trailing newline) via `Display`/`to_string`.
/// `f64` uses Rust's shortest-roundtrip formatting, so numbers survive a
/// parse→write→parse cycle bitwise; non-finite numbers serialize as
/// `null` (JSON has no representation for them).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write_value(self, f)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err("nesting too deep".into());
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos, depth + 1)? {
                    Json::Str(s) => s,
                    _ => return Err(format!("object key must be a string (byte {pos})")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos, depth + 1)?;
                pairs.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "non-utf8 number".to_string())?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{text}' at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = parse_hex4(b, *pos + 1)?;
                        *pos += 4;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair: expect \uXXXX low surrogate
                            if b.get(*pos + 1) == Some(&b'\\') && b.get(*pos + 2) == Some(&b'u') {
                                let lo = parse_hex4(b, *pos + 3)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                *pos += 6;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                return Err("lone high surrogate".into());
                            }
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err("lone low surrogate".into());
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| "invalid codepoint".to_string())?,
                        );
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => return Err("raw control char in string".into()),
            Some(&c) => {
                // copy one utf-8 scalar (input is a &str, so the encoding
                // is valid; the length comes from the leading byte)
                let len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let chunk = b
                    .get(*pos..*pos + len)
                    .ok_or_else(|| "truncated utf-8 sequence".to_string())?;
                let s = std::str::from_utf8(chunk)
                    .map_err(|_| "invalid utf-8 in string".to_string())?;
                out.push_str(s);
                *pos += len;
            }
        }
    }
}

fn parse_hex4(b: &[u8], at: usize) -> Result<u32, String> {
    if at + 4 > b.len() {
        return Err("truncated \\u escape".into());
    }
    let s = std::str::from_utf8(&b[at..at + 4]).map_err(|_| "non-utf8 \\u escape".to_string())?;
    u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape '{s}'"))
}

fn write_value<W: std::fmt::Write>(v: &Json, out: &mut W) -> std::fmt::Result {
    match v {
        Json::Null => out.write_str("null"),
        Json::Bool(b) => out.write_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.is_finite() {
                write!(out, "{x}")
            } else {
                out.write_str("null")
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(xs) => {
            out.write_char('[')?;
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.write_char(',')?;
                }
                write_value(x, out)?;
            }
            out.write_char(']')
        }
        Json::Obj(pairs) => {
            out.write_char('{')?;
            for (i, (k, x)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.write_char(',')?;
                }
                write_string(k, out)?;
                out.write_char(':')?;
                write_value(x, out)?;
            }
            out.write_char('}')
        }
    }
}

fn write_string<W: std::fmt::Write>(s: &str, out: &mut W) -> std::fmt::Result {
    out.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\r' => out.write_str("\\r")?,
            '\t' => out.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => out.write_char(c)?,
        }
    }
    out.write_char('"')
}

/// Convenience builders used by the server handlers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a JSON array from a float slice.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get_path(&["c"]), Some(&Json::Null));
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::parse(r#""a\"b\\c\nd\u0041\uD83D\uDE00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA😀");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn numbers_roundtrip_bitwise() {
        for x in [0.1, 1.0 / 3.0, 123456.789e-5, f64::MIN_POSITIVE, -0.0] {
            let s = Json::Num(x).to_string();
            let y = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "\"\\q\"", "1 2", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn writer_escapes() {
        let v = obj(vec![("k\n", Json::Str("v\"".into()))]);
        assert_eq!(v.to_string(), r#"{"k\n":"v\""}"#);
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn builders() {
        let v = obj(vec![("xs", arr_f64(&[1.0, 2.5]))]);
        assert_eq!(v.to_string(), r#"{"xs":[1,2.5]}"#);
    }
}
