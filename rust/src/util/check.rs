//! Property-testing mini-framework (offline substitute for `proptest`, see
//! DESIGN.md §3).
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it for
//! many independent seeds and, on failure, reports the *seed* that broke it
//! so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries don't receive the xla rpath rustflags,
//! # // so they cannot load libxla_extension's libstdc++ in this image.
//! use lsspca::util::check::property;
//! property("addition commutes", 64, |rng| {
//!     let a = rng.range_f64(-1e6, 1e6);
//!     let b = rng.range_f64(-1e6, 1e6);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::error::LsspcaError;
use crate::util::rng::Rng;

/// Base seed; combined with the case index so each case is independent but
/// the whole suite is reproducible. Override with `LSSPCA_CHECK_SEED`.
fn base_seed() -> u64 {
    std::env::var("LSSPCA_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_1dea_cafe_f00d)
}

/// Number of cases multiplier (`LSSPCA_CHECK_FACTOR`, default 1).
fn case_factor() -> usize {
    std::env::var("LSSPCA_CHECK_FACTOR")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// Run `cases` randomized checks of the property; panic on first failure
/// with the offending seed.
pub fn property<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    let total = cases * case_factor();
    for case in 0..total {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{total} (seed={seed:#x}):\n  {msg}\n\
                 replay with LSSPCA_CHECK_SEED={base} (case index {case})"
            );
        }
    }
}

/// Assert two floats are close in absolute-or-relative terms.
///
/// Failures are [`LsspcaError::Numeric`]; inside [`property`] closures
/// (which return `Result<(), String>`) `?` still works through the
/// `From<LsspcaError> for String` bridge.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), LsspcaError> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(LsspcaError::numeric(format!(
            "{a} !~ {b} (tol {tol}, |diff|={})",
            (a - b).abs()
        )))
    }
}

/// Assert two slices are elementwise close.
pub fn close_slice(a: &[f64], b: &[f64], tol: f64) -> Result<(), LsspcaError> {
    if a.len() != b.len() {
        return Err(LsspcaError::numeric(format!(
            "length mismatch {} vs {}",
            a.len(),
            b.len()
        )));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, tol)
            .map_err(|e| LsspcaError::numeric(format!("at index {i}: {}", e.message())))?;
    }
    Ok(())
}

/// Assert a boolean condition with a message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), LsspcaError> {
    if cond {
        Ok(())
    } else {
        Err(LsspcaError::numeric(msg.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        property("tautology", 32, |rng| {
            let x = rng.f64();
            ensure((0.0..1.0).contains(&x), "uniform out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        property("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_handles_relative() {
        assert!(close(1e9, 1e9 + 1.0, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }

    #[test]
    fn close_slice_reports_index() {
        let e = close_slice(&[1.0, 2.0], &[1.0, 3.0], 1e-9).unwrap_err();
        assert!(e.to_string().contains("index 1"));
        assert!(matches!(e, LsspcaError::Numeric { .. }));
        assert!(close_slice(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }
}
