//! Deterministic fault injection for the fault-tolerance test surface.
//!
//! Real I/O faults — `EINTR` mid-read, a torn write at a power cut, a
//! SIGKILL between `write` and `rename` — are timing accidents, which
//! makes tests of the recovery paths flaky by construction. This module
//! replaces timing with *scripted byte offsets*: a [`FaultPlan`] lists
//! faults as `op:tag@offset` entries, and the I/O sites that opt in
//! ([`wrap_read`] / [`wrap_write`], tagged `"checkpoint"`,
//! `"jobstate"`, `"manifest"`, `"shard"`, `"docword"`, and — for the
//! distributed pass — `"distshard"` / `"distshard<index>"` on worker
//! shard writes, `"distmanifest-init"` on the coordinator's manifest
//! creation and `"distmanifest"` on its post-shard updates) fire each
//! entry exactly once when their cumulative byte position crosses the
//! scripted offset. The same corpus plus the same plan always fails at
//! the same byte.
//!
//! Plans come from three places, in priority order: a programmatic
//! [`scoped`] call (unit tests), the `LSSPCA_FAULTS` environment
//! variable (CLI-level integration tests, read once per process), or
//! `[robustness] faults` in the config (operator drills). When no plan
//! is active the wrappers are a single relaxed atomic load of overhead.
//!
//! Fault operations:
//!
//! | op           | effect at the scripted offset                            |
//! |--------------|----------------------------------------------------------|
//! | `rinterrupt` | read fails once with [`std::io::ErrorKind::Interrupted`] |
//! | `rshort`     | read is truncated at the offset; at/past it, one `Ok(0)` |
//! | `winterrupt` | write fails once with `Interrupted`, no bytes consumed   |
//! | `wtorn`      | write lands bytes up to the offset, then fails permanently |
//! | `wkill`      | write lands bytes up to the offset, flushes, then aborts the process |

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once};

/// One scripted fault.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEntry {
    /// Which failure to inject (see the module table).
    pub op: FaultOp,
    /// The wrapper tag this entry targets (`"checkpoint"`, `"docword"`, …).
    pub tag: String,
    /// Cumulative byte offset within one wrapped stream at which to fire.
    pub offset: u64,
    fired: bool,
}

/// The failure kind a [`FaultEntry`] injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    /// Read fails once with `ErrorKind::Interrupted`.
    ReadInterrupt,
    /// Read is cut short at the offset (one early EOF if at/past it).
    ReadShort,
    /// Write fails once with `ErrorKind::Interrupted`, consuming nothing.
    WriteInterrupt,
    /// Write lands a prefix then fails with a permanent error — the
    /// half-written file stays on disk (the atomic-write regression case).
    WriteTorn,
    /// Write lands a prefix, flushes it, then `std::process::abort()`s —
    /// a real mid-write kill for subprocess-level tests.
    WriteKill,
}

impl FaultOp {
    fn parse(s: &str) -> Option<FaultOp> {
        Some(match s {
            "rinterrupt" => FaultOp::ReadInterrupt,
            "rshort" => FaultOp::ReadShort,
            "winterrupt" => FaultOp::WriteInterrupt,
            "wtorn" => FaultOp::WriteTorn,
            "wkill" => FaultOp::WriteKill,
            _ => return None,
        })
    }

    fn is_read(self) -> bool {
        matches!(self, FaultOp::ReadInterrupt | FaultOp::ReadShort)
    }
}

/// A parsed fault script: the entries fire independently, each at most
/// once per process.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scripted faults.
    pub entries: Vec<FaultEntry>,
}

impl FaultPlan {
    /// Parse a spec string: `;`-separated `op:tag@offset` entries, e.g.
    /// `"wtorn:checkpoint@100;rinterrupt:jobstate@8"`. Empty spec =
    /// empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut entries = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (op_s, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("fault '{part}': want op:tag@offset"))?;
            let (tag, off_s) = rest
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': want op:tag@offset"))?;
            let op = FaultOp::parse(op_s).ok_or_else(|| {
                format!("fault '{part}': unknown op '{op_s}' (want rinterrupt|rshort|winterrupt|wtorn|wkill)")
            })?;
            if tag.is_empty() {
                return Err(format!("fault '{part}': empty tag"));
            }
            let offset: u64 = off_s
                .parse()
                .map_err(|_| format!("fault '{part}': bad offset '{off_s}'"))?;
            entries.push(FaultEntry { op, tag: tag.to_string(), offset, fired: false });
        }
        Ok(FaultPlan { entries })
    }
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
static ENV_ONCE: Once = Once::new();

/// Serializes tests that install process-global plans. Unit tests that
/// call [`scoped`] must hold this guard, or concurrently running tests
/// would see each other's faults.
pub fn test_guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

fn load_env_plan() {
    ENV_ONCE.call_once(|| {
        if let Ok(spec) = std::env::var("LSSPCA_FAULTS") {
            match FaultPlan::parse(&spec) {
                Ok(plan) if !plan.entries.is_empty() => {
                    crate::warn_!("fault injection active from LSSPCA_FAULTS: {spec}");
                    *PLAN.lock().unwrap() = Some(plan);
                    ACTIVE.store(true, Ordering::SeqCst);
                }
                Ok(_) => {}
                Err(e) => crate::warn_!("ignoring bad LSSPCA_FAULTS: {e}"),
            }
        }
    });
}

/// Install a process-global plan (from `[robustness] faults`). An empty
/// plan deactivates injection.
pub fn install(plan: FaultPlan) {
    load_env_plan();
    let active = !plan.entries.is_empty();
    *PLAN.lock().unwrap() = if active { Some(plan) } else { None };
    ACTIVE.store(active, Ordering::SeqCst);
}

/// Remove any active plan.
pub fn clear() {
    load_env_plan();
    *PLAN.lock().unwrap() = None;
    ACTIVE.store(false, Ordering::SeqCst);
}

/// Run `f` with `plan` installed, restoring the previous plan after —
/// the unit-test entry point (hold [`test_guard`] around it).
pub fn scoped<T>(plan: FaultPlan, f: impl FnOnce() -> T) -> T {
    load_env_plan();
    let prev = {
        let mut slot = PLAN.lock().unwrap();
        let prev = slot.take();
        let active = !plan.entries.is_empty();
        *slot = if active { Some(plan) } else { None };
        ACTIVE.store(active, Ordering::SeqCst);
        prev
    };
    let out = f();
    let active = prev.is_some();
    *PLAN.lock().unwrap() = prev;
    ACTIVE.store(active, Ordering::SeqCst);
    out
}

/// What the active plan says about the I/O about to happen on `tag`
/// covering stream bytes `[pos, pos + len)`.
enum Verdict {
    Pass,
    Interrupt,
    /// Allow only this many bytes of the request (then the entry is spent;
    /// for reads a 0 means one early EOF, for torn/kill writes the prefix
    /// lands before the failure).
    Partial(usize, FaultOp),
}

fn consult(tag: &str, reading: bool, pos: u64, len: usize) -> Verdict {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Verdict::Pass;
    }
    load_env_plan();
    let mut slot = PLAN.lock().unwrap();
    let Some(plan) = slot.as_mut() else { return Verdict::Pass };
    let end = pos + len as u64;
    for e in plan.entries.iter_mut() {
        if e.fired || e.op.is_read() != reading || e.tag != tag || e.offset >= end {
            continue;
        }
        e.fired = true;
        let keep = e.offset.saturating_sub(pos) as usize;
        return match e.op {
            FaultOp::ReadInterrupt | FaultOp::WriteInterrupt => Verdict::Interrupt,
            op => Verdict::Partial(keep, op),
        };
    }
    Verdict::Pass
}

/// Wrap a reader so the active plan's `tag` read-entries fire against
/// it. Byte offsets count from this wrapper's construction.
pub fn wrap_read<R: Read>(tag: &str, inner: R) -> FaultRead<R> {
    FaultRead { inner, tag: tag.to_string(), pos: 0 }
}

/// Wrap a writer so the active plan's `tag` write-entries fire against
/// it. Byte offsets count from this wrapper's construction.
pub fn wrap_write<W: Write>(tag: &str, inner: W) -> FaultWrite<W> {
    FaultWrite { inner, tag: tag.to_string(), pos: 0 }
}

/// A [`Read`] that injects scripted faults (see [`wrap_read`]).
pub struct FaultRead<R> {
    inner: R,
    tag: String,
    pos: u64,
}

impl<R: Read> Read for FaultRead<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match consult(&self.tag, true, self.pos, buf.len()) {
            Verdict::Pass => {}
            Verdict::Interrupt => {
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    format!("injected read interrupt ({} at byte {})", self.tag, self.pos),
                ));
            }
            Verdict::Partial(keep, _) => {
                // rshort: deliver only up to the scripted offset; a keep
                // of 0 is one early EOF.
                let n = self.inner.read(&mut buf[..keep])?;
                self.pos += n as u64;
                return Ok(n);
            }
        }
        let n = self.inner.read(buf)?;
        self.pos += n as u64;
        Ok(n)
    }
}

/// A [`Write`] that injects scripted faults (see [`wrap_write`]).
pub struct FaultWrite<W: Write> {
    inner: W,
    tag: String,
    pos: u64,
}

impl<W: Write> FaultWrite<W> {
    /// Unwrap the inner writer (for a final `sync_all` on a `File`).
    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for FaultWrite<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match consult(&self.tag, false, self.pos, buf.len()) {
            Verdict::Pass => {}
            Verdict::Interrupt => {
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    format!("injected write interrupt ({} at byte {})", self.tag, self.pos),
                ));
            }
            Verdict::Partial(keep, op) => {
                // Land the prefix so the torn file is really on disk.
                self.inner.write_all(&buf[..keep])?;
                self.inner.flush()?;
                self.pos += keep as u64;
                if op == FaultOp::WriteKill {
                    std::process::abort();
                }
                return Err(io::Error::other(format!(
                    "injected torn write ({} at byte {})",
                    self.tag, self.pos
                )));
            }
        }
        let n = self.inner.write(buf)?;
        self.pos += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_rejects() {
        let p = FaultPlan::parse("wtorn:checkpoint@100; rinterrupt:jobstate@8").unwrap();
        assert_eq!(p.entries.len(), 2);
        assert_eq!(p.entries[0].op, FaultOp::WriteTorn);
        assert_eq!(p.entries[0].tag, "checkpoint");
        assert_eq!(p.entries[0].offset, 100);
        assert_eq!(p.entries[1].op, FaultOp::ReadInterrupt);
        assert!(FaultPlan::parse("").unwrap().entries.is_empty());
        for bad in ["boom:x@1", "wtorn:@1", "wtorn:x@ten", "wtorn:x", "justwords"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn read_interrupt_fires_once_at_offset() {
        let _g = test_guard();
        let data = vec![7u8; 64];
        scoped(FaultPlan::parse("rinterrupt:t@10").unwrap(), || {
            let mut r = wrap_read("t", &data[..]);
            let mut buf = [0u8; 8];
            r.read_exact(&mut buf).unwrap(); // bytes 0..8: clean
            let e = r.read(&mut buf).unwrap_err(); // would cross 10
            assert_eq!(e.kind(), io::ErrorKind::Interrupted);
            r.read_exact(&mut buf).unwrap(); // entry spent: clean again
        });
    }

    #[test]
    fn short_read_truncates_then_resumes() {
        let _g = test_guard();
        let data: Vec<u8> = (0..32).collect();
        scoped(FaultPlan::parse("rshort:t@5").unwrap(), || {
            let mut r = wrap_read("t", &data[..]);
            let mut buf = [0u8; 16];
            let n = r.read(&mut buf).unwrap();
            assert_eq!(n, 5, "cut at the scripted offset");
            assert_eq!(&buf[..5], &[0, 1, 2, 3, 4]);
            let n = r.read(&mut buf).unwrap(); // entry spent
            assert_eq!(&buf[..n], &data[5..5 + n]);
        });
    }

    #[test]
    fn torn_write_lands_prefix_then_permanent_error() {
        let _g = test_guard();
        let mut sink = Vec::new();
        scoped(FaultPlan::parse("wtorn:t@6").unwrap(), || {
            let mut w = wrap_write("t", &mut sink);
            let e = w.write_all(&[1u8; 10]).unwrap_err();
            assert_ne!(e.kind(), io::ErrorKind::Interrupted, "torn writes are permanent");
            assert!(e.to_string().contains("torn"), "{e}");
        });
        assert_eq!(sink.len(), 6, "exactly the pre-offset prefix landed");
    }

    #[test]
    fn untagged_streams_unaffected() {
        let _g = test_guard();
        scoped(FaultPlan::parse("rinterrupt:other@0;wtorn:other@0").unwrap(), || {
            let mut r = wrap_read("t", &[1u8, 2, 3][..]);
            let mut buf = [0u8; 3];
            r.read_exact(&mut buf).unwrap();
            let mut sink = Vec::new();
            wrap_write("t", &mut sink).write_all(&[9u8; 4]).unwrap();
            assert_eq!(sink.len(), 4);
        });
    }

    #[test]
    fn scoped_restores_inactive() {
        let _g = test_guard();
        scoped(FaultPlan::parse("rinterrupt:t@0").unwrap(), || {});
        let mut r = wrap_read("t", &[1u8][..]);
        let mut buf = [0u8; 1];
        r.read_exact(&mut buf).unwrap();
    }

    #[test]
    fn write_interrupt_consumes_nothing() {
        let _g = test_guard();
        let mut sink = Vec::new();
        scoped(FaultPlan::parse("winterrupt:t@0").unwrap(), || {
            let mut w = wrap_write("t", &mut sink);
            let e = w.write(&[1u8; 4]).unwrap_err();
            assert_eq!(e.kind(), io::ErrorKind::Interrupted);
            w.write_all(&[1u8; 4]).unwrap(); // spent: retry succeeds
        });
        assert_eq!(sink.len(), 4);
    }
}
