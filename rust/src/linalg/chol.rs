//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Used as the PSD certificate in tests (BCA must keep `X ≻ 0` — the
//! log-det barrier guarantees it analytically; Cholesky verifies it
//! numerically) and for solving small positive-definite systems.

use crate::data::SymMat;

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`, stored row-major
/// (upper part zero). Returns `None` if the matrix is not numerically
/// positive definite (a pivot fell below `tol`).
pub fn cholesky(a: &SymMat, tol: f64) -> Option<Vec<f64>> {
    let n = a.n();
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= tol {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Whether `A + shift·I` is numerically positive definite.
pub fn is_psd(a: &SymMat, shift: f64) -> bool {
    let n = a.n();
    let mut b = a.clone();
    for i in 0..n {
        let v = b.get(i, i) + shift;
        b.set(i, i, v);
    }
    cholesky(&b, -1e-30).is_some()
}

/// Solve `A x = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn solve_spd(a: &SymMat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.n();
    assert_eq!(b.len(), n);
    let l = cholesky(a, 0.0)?;
    // Forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // Backward: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close_slice, ensure, property};

    #[test]
    fn factor_reconstructs() {
        property("LLᵀ = A", 25, |rng| {
            let n = rng.range(1, 12);
            let a = SymMat::random_psd(n, n + 5, 0.5, rng);
            let l = cholesky(&a, 0.0).ok_or("expected PD")?;
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    crate::util::check::close(s, a.get(i, j), 1e-8)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_indefinite() {
        let m = SymMat::from_fn(2, |i, j| if i == j { 0.0 } else { 1.0 });
        assert!(cholesky(&m, 0.0).is_none());
        assert!(!is_psd(&m, 0.0));
        assert!(is_psd(&m, 1.5)); // eigenvalues -1, 1 shifted by 1.5
    }

    #[test]
    fn identity_is_psd() {
        assert!(is_psd(&SymMat::identity(5), 0.0));
    }

    #[test]
    fn solve_spd_matches_matvec() {
        property("A(solve(A,b)) = b", 25, |rng| {
            let n = rng.range(1, 10);
            let a = SymMat::random_psd(n, n + 6, 1.0, rng);
            let b = rng.gauss_vec(n);
            let x = solve_spd(&a, &b).ok_or("factor failed")?;
            let mut ax = vec![0.0; n];
            a.matvec(&x, &mut ax);
            close_slice(&ax, &b, 1e-7)
        });
    }

    #[test]
    fn psd_boundary_semidefinite() {
        // Rank-1 PSD matrix: xxᵀ is PSD but not PD; is_psd with tiny shift holds.
        let x = [1.0, 2.0, 3.0];
        let m = SymMat::from_fn(3, |i, j| x[i] * x[j]);
        property("rank-1 semidefinite detected", 1, move |_| {
            ensure(cholesky(&m, 1e-12).is_none(), "rank-1 should fail strict PD")?;
            ensure(is_psd(&m, 1e-9), "rank-1 + shift should pass")
        });
    }
}
