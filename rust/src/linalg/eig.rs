//! Cyclic Jacobi eigensolver for dense symmetric matrices.
//!
//! Quadratically convergent, unconditionally stable, and dependency-free —
//! the right tool at the post-elimination problem sizes (n̂ ≤ ~1000). It
//! backs the first-order DSPCA baseline (which needs a full
//! eigendecomposition of the smoothed gradient every iteration) and the
//! extraction of the leading eigenvector from the BCA solution `X*`.

use crate::data::SymMat;

/// Full symmetric eigendecomposition `A = V diag(w) Vᵀ`.
#[derive(Clone, Debug)]
pub struct JacobiEig {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
    /// Eigenvectors, row-major `n × n`: row `k` is the eigenvector for
    /// `values[k]`.
    pub vectors: Vec<f64>,
    /// Number of Jacobi sweeps performed.
    pub sweeps: usize,
}

impl JacobiEig {
    /// Decompose with default tolerance.
    pub fn new(a: &SymMat) -> JacobiEig {
        Self::with_tol(a, 1e-12, 64)
    }

    /// Decompose, stopping when the off-diagonal Frobenius norm falls below
    /// `tol · ‖A‖_F` or after `max_sweeps`.
    pub fn with_tol(a: &SymMat, tol: f64, max_sweeps: usize) -> JacobiEig {
        let n = a.n();
        let mut m = a.as_slice().to_vec();
        // V starts as identity; accumulated rotations give eigenvectors.
        let mut v = vec![0.0f64; n * n];
        for i in 0..n {
            v[i * n + i] = 1.0;
        }
        let frob: f64 = m.iter().map(|x| x * x).sum::<f64>().sqrt();
        let threshold = tol * frob.max(1e-300);
        let mut sweeps = 0;
        while sweeps < max_sweeps {
            let off: f64 = {
                let mut s = 0.0;
                for i in 0..n {
                    for j in (i + 1)..n {
                        s += 2.0 * m[i * n + j] * m[i * n + j];
                    }
                }
                s.sqrt()
            };
            if off <= threshold {
                break;
            }
            sweeps += 1;
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = m[p * n + q];
                    if apq.abs() <= 1e-300 {
                        continue;
                    }
                    let app = m[p * n + p];
                    let aqq = m[q * n + q];
                    // Stable rotation computation (Golub & Van Loan 8.4).
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = if theta >= 0.0 {
                        1.0 / (theta + (1.0 + theta * theta).sqrt())
                    } else {
                        1.0 / (theta - (1.0 + theta * theta).sqrt())
                    };
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = t * c;
                    // Update rows/cols p and q of m.
                    for k in 0..n {
                        let akp = m[k * n + p];
                        let akq = m[k * n + q];
                        m[k * n + p] = c * akp - s * akq;
                        m[k * n + q] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = m[p * n + k];
                        let aqk = m[q * n + k];
                        m[p * n + k] = c * apk - s * aqk;
                        m[q * n + k] = s * apk + c * aqk;
                    }
                    // Accumulate rotation into V (rows are eigenvectors).
                    for k in 0..n {
                        let vpk = v[p * n + k];
                        let vqk = v[q * n + k];
                        v[p * n + k] = c * vpk - s * vqk;
                        v[q * n + k] = s * vpk + c * vqk;
                    }
                }
            }
        }
        // Extract eigenvalues, sort descending with vectors.
        let mut order: Vec<usize> = (0..n).collect();
        let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
        order.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap());
        let mut values = Vec::with_capacity(n);
        let mut vectors = vec![0.0; n * n];
        for (dst, &src) in order.iter().enumerate() {
            values.push(diag[src]);
            vectors[dst * n..(dst + 1) * n].copy_from_slice(&v[src * n..(src + 1) * n]);
        }
        JacobiEig { values, vectors, sweeps }
    }

    /// Matrix order.
    pub fn n(&self) -> usize {
        self.values.len()
    }

    /// Eigenvector `k` (sorted by descending eigenvalue).
    pub fn vector(&self, k: usize) -> &[f64] {
        let n = self.n();
        &self.vectors[k * n..(k + 1) * n]
    }

    /// Largest eigenvalue.
    pub fn lambda_max(&self) -> f64 {
        self.values[0]
    }

    /// Reconstruct `f(A) = V diag(f(w)) Vᵀ` for a scalar function `f` —
    /// used by the first-order baseline's matrix exponential.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> SymMat {
        let n = self.n();
        let fw: Vec<f64> = self.values.iter().map(|&w| f(w)).collect();
        SymMat::from_fn(n, |i, j| {
            let mut s = 0.0;
            for k in 0..n {
                s += fw[k] * self.vectors[k * n + i] * self.vectors[k * n + j];
            }
            s
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec::{dot, norm2};
    use crate::util::check::{close, property};
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix_eigs() {
        let d = SymMat::from_fn(3, |i, j| if i == j { [3.0, 1.0, 2.0][i] } else { 0.0 });
        let e = JacobiEig::new(&d);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 3, 1
        let a = SymMat::from_fn(2, |i, j| if i == j { 2.0 } else { 1.0 });
        let e = JacobiEig::new(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 1.0).abs() < 1e-10);
        // eigenvector for 3 is (1,1)/√2 up to sign
        let v = e.vector(0);
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((v[0] - v[1]).abs() < 1e-8);
    }

    #[test]
    fn prop_decomposition_properties() {
        property("eig: Av = wv, orthonormal V, trace preserved", 20, |rng| {
            let n = rng.range(2, 14);
            let a = SymMat::random_psd(n, n + 3, 0.1, rng);
            let e = JacobiEig::new(&a);
            // residuals
            for k in 0..n {
                let v = e.vector(k);
                let mut av = vec![0.0; n];
                a.matvec(v, &mut av);
                for i in 0..n {
                    close(av[i], e.values[k] * v[i], 1e-7)?;
                }
            }
            // orthonormality
            for i in 0..n {
                close(norm2(e.vector(i)), 1.0, 1e-9)?;
                for j in (i + 1)..n {
                    close(dot(e.vector(i), e.vector(j)), 0.0, 1e-9)?;
                }
            }
            // trace and descending order
            let sum: f64 = e.values.iter().sum();
            close(sum, a.trace(), 1e-8)?;
            for k in 1..n {
                if e.values[k] > e.values[k - 1] + 1e-10 {
                    return Err(format!("values not sorted at {k}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn apply_fn_exponential() {
        let mut rng = Rng::seed_from(77);
        let a = SymMat::random_psd(6, 10, 0.1, &mut rng);
        let e = JacobiEig::new(&a);
        let expa = e.apply_fn(f64::exp);
        // Tr exp(A) = Σ exp(w)
        let want: f64 = e.values.iter().map(|&w| w.exp()).sum();
        assert!((expa.trace() - want).abs() < 1e-8 * want);
        // identity function reconstructs A
        let same = e.apply_fn(|w| w);
        for i in 0..6 {
            for j in 0..6 {
                assert!((same.get(i, j) - a.get(i, j)).abs() < 1e-8);
            }
        }
    }
}
