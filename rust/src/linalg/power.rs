//! Power iteration — the O(n²)-per-step PCA workhorse the paper's
//! complexity comparison is framed against ("we can compute one principal
//! component with a complexity of O(n²)").

use crate::data::SymMat;
use crate::linalg::vec::{dot, max_abs_diff, normalize};
use crate::util::rng::Rng;

/// Result of a power-iteration run.
#[derive(Clone, Debug)]
pub struct PowerResult {
    /// Estimated leading eigenvector (unit norm).
    pub vector: Vec<f64>,
    /// Estimated leading eigenvalue (Rayleigh quotient).
    pub value: f64,
    /// Iterations performed.
    pub iters: usize,
    /// Final successive-iterate change (ℓ∞).
    pub delta: f64,
}

/// Leading eigenpair of a symmetric PSD matrix by power iteration.
///
/// Deterministic given the RNG seed used for the start vector. Converges
/// linearly at rate |λ₂/λ₁|; `max_iters` bounds the work.
pub fn power_iteration(a: &SymMat, max_iters: usize, tol: f64, rng: &mut Rng) -> PowerResult {
    let n = a.n();
    assert!(n > 0);
    let mut v = rng.gauss_vec(n);
    normalize(&mut v);
    let mut av = vec![0.0; n];
    let mut delta = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iters {
        a.matvec(&v, &mut av);
        let norm = normalize(&mut av);
        if norm <= 1e-300 {
            // a annihilated v (possible for singular A): restart randomly
            av = rng.gauss_vec(n);
            normalize(&mut av);
        }
        // Sign-align to previous iterate so the convergence check is
        // meaningful for eigenvectors of either sign.
        if dot(&av, &v) < 0.0 {
            for x in &mut av {
                *x = -*x;
            }
        }
        delta = max_abs_diff(&av, &v);
        std::mem::swap(&mut v, &mut av);
        iters = it + 1;
        if delta < tol {
            break;
        }
    }
    a.matvec(&v, &mut av);
    let value = dot(&v, &av);
    PowerResult { vector: v, value, iters, delta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::JacobiEig;
    use crate::util::check::{close, property};

    #[test]
    fn diagonal_leading() {
        let d = SymMat::from_fn(4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let mut rng = Rng::seed_from(41);
        let r = power_iteration(&d, 500, 1e-12, &mut rng);
        assert!((r.value - 4.0).abs() < 1e-8);
        assert!(r.vector[3].abs() > 0.999);
    }

    #[test]
    fn prop_agrees_with_jacobi() {
        property("power iteration matches Jacobi λ₁", 15, |rng| {
            let n = rng.range(2, 12);
            let a = SymMat::random_psd(n, n + 4, 0.05, rng);
            let e = JacobiEig::new(&a);
            let r = power_iteration(&a, 5000, 1e-12, rng);
            // Eigenvalue gap can be tiny for random matrices; allow loose tol
            close(r.value, e.lambda_max(), 1e-4)
        });
    }

    #[test]
    fn zero_matrix_no_panic() {
        let a = SymMat::zeros(5);
        let mut rng = Rng::seed_from(43);
        let r = power_iteration(&a, 10, 1e-10, &mut rng);
        assert!(r.value.abs() < 1e-12);
    }
}
