//! Dense linear algebra built from scratch (no BLAS/LAPACK in the offline
//! environment): vector kernels, Cholesky, the cyclic Jacobi symmetric
//! eigensolver, and power iteration.
//!
//! These are the substrates the solvers sit on: Cholesky backs the PSD
//! property checks, Jacobi backs the first-order DSPCA baseline (which
//! needs full eigendecompositions) and the solution-extraction step, and
//! power iteration is the PCA baseline the paper compares complexity
//! against (O(n²) per iteration).

pub mod chol;
pub mod eig;
pub mod elastic_net;
pub mod power;
pub mod vec;

pub use chol::{cholesky, is_psd};
pub use eig::JacobiEig;
pub use power::{power_iteration, PowerResult};
pub use vec::{axpy, dot, norm2, normalize, scale};
