//! Vector kernels on the solver hot path, routed through the
//! runtime-dispatched [`crate::kernels`] backends (scalar / AVX2 / NEON).
//! Every tier is bitwise-identical, so these remain the crate's
//! deterministic reference primitives (see §Perf and ARCHITECTURE
//! §Compute kernels).

/// Dot product with the crate's fixed 4-lane reduction order
/// (`(s0 + s1) + (s2 + s3)` over 4-element chunks, sequential
/// remainder); dispatched to the active SIMD tier.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    crate::kernels::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`; dispatched to the active SIMD tier (element-wise,
/// bitwise-identical on every tier).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    crate::kernels::axpy(alpha, x, y);
}

/// `x *= alpha`; dispatched to the active SIMD tier (element-wise,
/// bitwise-identical on every tier).
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    crate::kernels::scale(alpha, x);
}

/// Normalize to unit Euclidean norm; returns the original norm.
/// Leaves the vector untouched if its norm is (near) zero.
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm2(x);
    if n > 1e-300 {
        scale(1.0 / n, x);
    }
    n
}

/// Number of entries with magnitude above `tol` (the ‖·‖₀ of problem (2)).
pub fn cardinality(x: &[f64], tol: f64) -> usize {
    x.iter().filter(|v| v.abs() > tol).count()
}

/// Indices of entries with magnitude above `tol`.
pub fn support(x: &[f64], tol: f64) -> Vec<usize> {
    x.iter()
        .enumerate()
        .filter(|(_, v)| v.abs() > tol)
        .map(|(i, _)| i)
        .collect()
}

/// ℓ∞ distance between two vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, property};
    use crate::util::rng::Rng;

    #[test]
    fn dot_matches_naive() {
        property("unrolled dot == naive dot", 50, |rng| {
            let n = rng.range(0, 67);
            let a: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            close(dot(&a, &b), naive, 1e-12)
        });
    }

    #[test]
    fn axpy_scale_norm() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(2.0, &[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, 2.0, 1.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![1.5, 1.0, 0.5]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit() {
        let mut rng = Rng::seed_from(31);
        let mut x = rng.gauss_vec(10);
        let n0 = norm2(&x);
        let returned = normalize(&mut x);
        assert!((returned - n0).abs() < 1e-12);
        assert!((norm2(&x) - 1.0).abs() < 1e-12);
        // zero vector untouched
        let mut z = vec![0.0; 4];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, vec![0.0; 4]);
    }

    #[test]
    fn cardinality_and_support() {
        let x = [0.0, 0.5, -1e-12, 2.0];
        assert_eq!(cardinality(&x, 1e-9), 2);
        assert_eq!(support(&x, 1e-9), vec![1, 3]);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}
