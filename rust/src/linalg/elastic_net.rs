//! Elastic-net regression by coordinate descent — the substrate under the
//! SPCA baseline of Zou, Hastie & Tibshirani [8].
//!
//! Solves
//!
//! ```text
//! min_b  ½‖y − X b‖² + λ₁‖b‖₁ + ½λ₂‖b‖²
//! ```
//!
//! with the standard one-at-a-time soft-thresholding updates. Only dense
//! problems at post-elimination sizes are needed here, so the
//! implementation favors clarity + testability over sparse-data tricks.

use crate::linalg::vec::dot;

/// Options for the coordinate-descent solve.
#[derive(Clone, Copy, Debug)]
pub struct EnetOptions {
    /// Maximum coordinate-descent sweeps.
    pub max_sweeps: usize,
    /// Stop when the largest coefficient move falls below this.
    pub tol: f64,
}

impl Default for EnetOptions {
    fn default() -> Self {
        EnetOptions { max_sweeps: 500, tol: 1e-10 }
    }
}

#[inline]
fn soft(z: f64, g: f64) -> f64 {
    if z > g {
        z - g
    } else if z < -g {
        z + g
    } else {
        0.0
    }
}

/// Solve the elastic net for a dense column-major design matrix
/// `x` (m rows × p cols, column `j` at `x[j*m..(j+1)*m]`).
pub fn solve(
    x: &[f64],
    m: usize,
    p: usize,
    y: &[f64],
    lambda1: f64,
    lambda2: f64,
    opts: EnetOptions,
) -> Vec<f64> {
    assert_eq!(x.len(), m * p);
    assert_eq!(y.len(), m);
    // Precompute column squared norms.
    let colsq: Vec<f64> = (0..p).map(|j| dot(&x[j * m..(j + 1) * m], &x[j * m..(j + 1) * m])).collect();
    let mut b = vec![0.0f64; p];
    let mut resid = y.to_vec(); // r = y − Xb (b = 0)
    for _ in 0..opts.max_sweeps {
        let mut max_move = 0.0f64;
        for j in 0..p {
            let xj = &x[j * m..(j + 1) * m];
            let denom = colsq[j] + lambda2;
            if denom <= 0.0 {
                continue;
            }
            // z = xjᵀ r + colsq_j * b_j  (partial residual correlation)
            let z = dot(xj, &resid) + colsq[j] * b[j];
            let new = soft(z, lambda1) / denom;
            let delta = new - b[j];
            if delta != 0.0 {
                for (r, &xv) in resid.iter_mut().zip(xj) {
                    *r -= delta * xv;
                }
                b[j] = new;
                max_move = max_move.max(delta.abs());
            }
        }
        if max_move <= opts.tol {
            break;
        }
    }
    b
}

/// Objective value (test helper).
pub fn objective(
    x: &[f64],
    m: usize,
    p: usize,
    y: &[f64],
    lambda1: f64,
    lambda2: f64,
    b: &[f64],
) -> f64 {
    let mut resid = y.to_vec();
    for j in 0..p {
        let xj = &x[j * m..(j + 1) * m];
        for (r, &xv) in resid.iter_mut().zip(xj) {
            *r -= b[j] * xv;
        }
    }
    0.5 * dot(&resid, &resid)
        + lambda1 * b.iter().map(|v| v.abs()).sum::<f64>()
        + 0.5 * lambda2 * dot(b, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, ensure, property};
    use crate::util::rng::Rng;

    fn random_problem(rng: &mut Rng, m: usize, p: usize) -> (Vec<f64>, Vec<f64>) {
        let x: Vec<f64> = (0..m * p).map(|_| rng.gauss()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.gauss()).collect();
        (x, y)
    }

    #[test]
    fn ridge_only_matches_normal_equations() {
        // p = 1: b = xᵀy / (xᵀx + λ₂)
        let mut rng = Rng::seed_from(211);
        let (x, y) = random_problem(&mut rng, 20, 1);
        let b = solve(&x, 20, 1, &y, 0.0, 0.7, EnetOptions::default());
        let want = dot(&x, &y) / (dot(&x, &x) + 0.7);
        close(b[0], want, 1e-9).unwrap();
    }

    #[test]
    fn huge_l1_zeroes_everything() {
        let mut rng = Rng::seed_from(212);
        let (x, y) = random_problem(&mut rng, 15, 4);
        let b = solve(&x, 15, 4, &y, 1e9, 0.1, EnetOptions::default());
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prop_solution_beats_perturbations() {
        property("enet optimum ≤ perturbed objectives", 15, |rng| {
            let m = rng.range(5, 25);
            let p = rng.range(1, 8);
            let (x, y) = random_problem(rng, m, p);
            let l1 = rng.range_f64(0.0, 2.0);
            let l2 = rng.range_f64(0.01, 1.0);
            let b = solve(&x, m, p, &y, l1, l2, EnetOptions::default());
            let f0 = objective(&x, m, p, &y, l1, l2, &b);
            for _ in 0..10 {
                let mut bp = b.clone();
                let j = rng.below(p);
                bp[j] += rng.range_f64(-0.2, 0.2);
                let f1 = objective(&x, m, p, &y, l1, l2, &bp);
                ensure(f0 <= f1 + 1e-8 * (1.0 + f1.abs()), format!("{f0} > {f1}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn recovers_sparse_signal() {
        // y = 3·x₂ + noise; lasso should pick column 2.
        let mut rng = Rng::seed_from(213);
        let (m, p) = (60, 6);
        let x: Vec<f64> = (0..m * p).map(|_| rng.gauss()).collect();
        let mut y = vec![0.0; m];
        for i in 0..m {
            y[i] = 3.0 * x[2 * m + i] + 0.05 * rng.gauss();
        }
        let b = solve(&x, m, p, &y, 3.0, 0.01, EnetOptions::default());
        assert!(b[2] > 1.0, "b = {b:?}");
        for (j, &v) in b.iter().enumerate() {
            if j != 2 {
                assert!(v.abs() < 0.2, "b = {b:?}");
            }
        }
    }
}
