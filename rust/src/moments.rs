//! Per-feature streaming moments — the substrate of the paper's
//! pre-processing pass.
//!
//! Safe feature elimination (Theorem 2.1) needs every feature's variance
//! `Σ_ii`, computed over corpora too large to hold in memory. Each worker
//! folds a chunk of documents into a [`FeatureMoments`] accumulator; the
//! accumulators merge associatively (Chan et al.), so the pass parallelizes
//! exactly as the paper notes ("this task is easy to parallelize").
//!
//! Bag-of-words sparsity is exploited: a document only touches the
//! accumulators of the words it contains; the implicit zeros are folded in
//! *once per feature* at finalization time in O(1) each via
//! [`RunningStats::push_repeated`].

use crate::data::docword::DocChunk;
use crate::util::stats::RunningStats;

/// Accumulated first and second moments for every feature.
#[derive(Clone, Debug)]
pub struct FeatureMoments {
    /// Per-feature stats over the *nonzero* observations only; zeros are
    /// folded in by [`finalize`](FeatureMoments::finalize).
    stats: Vec<RunningStats>,
    /// Documents folded in so far.
    pub docs: u64,
    /// Nonzero entries folded in so far.
    pub nnz: u64,
}

/// Finalized per-feature statistics (zeros included).
#[derive(Clone, Debug)]
pub struct FeatureVariances {
    /// Population variance per feature: the `Σ_ii` of Theorem 2.1 for
    /// mean-centered data.
    pub variance: Vec<f64>,
    /// Mean per feature.
    pub mean: Vec<f64>,
    /// Uncentered second moment `E[x²]` per feature — the `Σ_ii = aᵢᵀaᵢ/m`
    /// of the *uncentered* covariance convention.
    pub second_moment: Vec<f64>,
    /// Documents folded in.
    pub docs: u64,
}

impl FeatureMoments {
    /// Zeroed accumulator over `num_features` features.
    pub fn new(num_features: usize) -> FeatureMoments {
        FeatureMoments {
            stats: vec![RunningStats::new(); num_features],
            docs: 0,
            nnz: 0,
        }
    }

    /// Feature count this accumulator covers.
    pub fn num_features(&self) -> usize {
        self.stats.len()
    }

    /// Fold one document (sparse `(word, count)` pairs) into the moments.
    pub fn push_doc(&mut self, words: &[(u32, f64)]) {
        self.docs += 1;
        for &(w, c) in words {
            self.stats[w as usize].push(c);
            self.nnz += 1;
        }
    }

    /// Fold a whole chunk.
    pub fn push_chunk(&mut self, chunk: &DocChunk) {
        for doc in &chunk.docs {
            self.push_doc(&doc.words);
        }
    }

    /// Merge another accumulator (parallel combination; associative and
    /// commutative, see the property tests).
    pub fn merge(&mut self, other: &FeatureMoments) {
        assert_eq!(self.stats.len(), other.stats.len(), "feature count mismatch");
        for (a, b) in self.stats.iter_mut().zip(&other.stats) {
            a.merge(b);
        }
        self.docs += other.docs;
        self.nnz += other.nnz;
    }

    /// The raw per-feature accumulators (nonzero observations only) —
    /// what the job-state file serializes for kill-and-resume.
    pub fn stats(&self) -> &[RunningStats] {
        &self.stats
    }

    /// Rebuild an accumulator from serialized parts (the job-state
    /// loader's inverse of [`stats`](FeatureMoments::stats) plus the
    /// `docs`/`nnz` counters).
    pub fn from_parts(stats: Vec<RunningStats>, docs: u64, nnz: u64) -> FeatureMoments {
        FeatureMoments { stats, docs, nnz }
    }

    /// Fold in the implicit zeros and produce final variances.
    pub fn finalize(&self) -> FeatureVariances {
        self.finalize_par(1)
    }

    /// Parallel [`finalize`](FeatureMoments::finalize): the per-feature
    /// zero-folding is independent across features, so fixed shards of the
    /// vocabulary run on workers. Per-feature arithmetic is unchanged —
    /// the output is bitwise identical for any `threads` (at PubMed scale
    /// the vocabulary is ~10⁵ features, each finalized in O(1)).
    pub fn finalize_par(&self, threads: usize) -> FeatureVariances {
        let n = self.stats.len();
        let shard = 4096usize;
        let shards = n.div_ceil(shard).max(1);
        let parts = crate::util::parallel::par_map_indexed(threads, shards, |s| {
            let start = s * shard;
            let end = ((s + 1) * shard).min(n);
            let mut variance = Vec::with_capacity(end - start);
            let mut mean = Vec::with_capacity(end - start);
            let mut second_moment = Vec::with_capacity(end - start);
            for st in &self.stats[start..end] {
                debug_assert!(st.n <= self.docs, "feature seen more often than docs");
                let mut full = *st;
                full.push_repeated(0.0, self.docs - st.n);
                variance.push(full.variance());
                mean.push(full.mean);
                // E[x²] = var + mean² (population)
                second_moment.push(full.variance() + full.mean * full.mean);
            }
            (variance, mean, second_moment)
        });
        let mut variance = Vec::with_capacity(n);
        let mut mean = Vec::with_capacity(n);
        let mut second_moment = Vec::with_capacity(n);
        for (v, m, s2) in parts {
            variance.extend(v);
            mean.extend(m);
            second_moment.extend(s2);
        }
        FeatureVariances { variance, mean, second_moment, docs: self.docs }
    }
}

impl FeatureVariances {
    /// Features ranked by decreasing variance — the Fig 2 series and the
    /// input to the elimination threshold.
    pub fn ranked(&self) -> Vec<(usize, f64)> {
        let mut idx: Vec<(usize, f64)> = self.variance.iter().copied().enumerate().collect();
        idx.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        idx
    }

    /// The variance column, sorted descending (Fig 2's y-series).
    pub fn sorted_variances(&self) -> Vec<f64> {
        let mut v = self.variance.clone();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::docword::Doc;
    use crate::util::check::{close, close_slice, property};

    fn chunk(docs: Vec<Vec<(u32, f64)>>) -> DocChunk {
        DocChunk {
            docs: docs
                .into_iter()
                .enumerate()
                .map(|(id, words)| Doc { id, words })
                .collect(),
        }
    }

    #[test]
    fn variance_with_implicit_zeros() {
        // 4 docs over 2 features; feature 0 counts: 2,0,0,0 → mean .5,
        // var = (2.25 + 3*.25)/4 = .75
        let mut m = FeatureMoments::new(2);
        m.push_chunk(&chunk(vec![vec![(0, 2.0)], vec![], vec![(1, 1.0)], vec![]]));
        let f = m.finalize();
        assert_eq!(f.docs, 4);
        assert!((f.variance[0] - 0.75).abs() < 1e-12);
        assert!((f.mean[0] - 0.5).abs() < 1e-12);
        // second moment = E[x²] = 4/4 = 1
        assert!((f.second_moment[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_merge_equals_single_pass() {
        property("moments merge == single pass", 25, |rng| {
            let features = rng.range(1, 8);
            let ndocs = rng.range(1, 30);
            let docs: Vec<Vec<(u32, f64)>> = (0..ndocs)
                .map(|_| {
                    let k = rng.below(features + 1);
                    let mut ws: Vec<usize> = rng.sample_indices(features, k);
                    ws.sort_unstable();
                    ws.into_iter()
                        .map(|w| (w as u32, (1 + rng.below(9)) as f64))
                        .collect()
                })
                .collect();
            let mut whole = FeatureMoments::new(features);
            for d in &docs {
                whole.push_doc(d);
            }
            let cut = rng.below(ndocs + 1);
            let mut a = FeatureMoments::new(features);
            let mut b = FeatureMoments::new(features);
            for d in &docs[..cut] {
                a.push_doc(d);
            }
            for d in &docs[cut..] {
                b.push_doc(d);
            }
            a.merge(&b);
            let fa = a.finalize();
            let fw = whole.finalize();
            close_slice(&fa.variance, &fw.variance, 1e-10)?;
            close_slice(&fa.mean, &fw.mean, 1e-10)?;
            Ok(())
        });
    }

    #[test]
    fn prop_variance_matches_naive() {
        property("streamed variance == naive dense variance", 25, |rng| {
            let features = rng.range(1, 6);
            let ndocs = rng.range(1, 25);
            let mut dense = vec![0.0f64; ndocs * features];
            let mut m = FeatureMoments::new(features);
            for d in 0..ndocs {
                let mut words = Vec::new();
                for w in 0..features {
                    if rng.bool(0.4) {
                        let c = (1 + rng.below(5)) as f64;
                        dense[d * features + w] = c;
                        words.push((w as u32, c));
                    }
                }
                m.push_doc(&words);
            }
            let f = m.finalize();
            for w in 0..features {
                let col: Vec<f64> = (0..ndocs).map(|d| dense[d * features + w]).collect();
                let mean = col.iter().sum::<f64>() / ndocs as f64;
                let var = col.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / ndocs as f64;
                close(f.variance[w], var, 1e-10)?;
                let m2 = col.iter().map(|x| x * x).sum::<f64>() / ndocs as f64;
                close(f.second_moment[w], m2, 1e-10)?;
            }
            Ok(())
        });
    }

    #[test]
    fn ranked_is_descending() {
        let mut m = FeatureMoments::new(3);
        m.push_chunk(&chunk(vec![vec![(0, 1.0), (2, 10.0)], vec![(2, 5.0)]]));
        let f = m.finalize();
        let r = f.ranked();
        assert_eq!(r[0].0, 2);
        let sv = f.sorted_variances();
        assert!(sv.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    #[should_panic(expected = "feature count mismatch")]
    fn merge_rejects_mismatch() {
        let mut a = FeatureMoments::new(2);
        let b = FeatureMoments::new(3);
        a.merge(&b);
    }
}
