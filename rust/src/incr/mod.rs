//! Incremental-corpus subsystem: append-only covariance updates.
//!
//! Production corpora grow. The paper's pipeline (variance pass →
//! Thm-2.1 elimination → reduced covariance → BCA) is built from
//! mergeable accumulators, so an appended docword segment does not have
//! to force a cold re-stream: this module keeps the *master Welford
//! accumulator* of the base corpus alive between fits and folds new
//! segments into it in global chunk order — bitwise-identical to the
//! resumable variance pass over the concatenated corpus (pinned by the
//! `append_fold_matches_cold_resumable_pass` test below).
//!
//! Three invariants make the whole thing safe:
//!
//! 1. **Chained digest.** Every successful append advances the corpus
//!    identity `digest_{i+1} = H(digest_i ‖ segment_digest)` (see
//!    [`chain_digest`]). All caches keyed by corpus digest (checkpoints,
//!    job state, the shard cache) therefore never confuse an appended
//!    corpus with its base, and a failed append leaves the digest — and
//!    every cache keyed by it — untouched.
//! 2. **Chunk-aligned fold.** Appended documents are re-buffered into
//!    exactly the `chunk_docs`-sized chunks a cold stream over the
//!    concatenated corpus would produce, each folded into a *fresh*
//!    [`FeatureMoments`] and merged into the master in order — the same
//!    structure as [`crate::stream::resumable_variance_pass`], so the
//!    merged moments are bitwise-identical to a cold pass.
//! 3. **Drift gate.** The Thm-2.1 kept set stays provably valid as long
//!    as (a) no eliminated feature's merged variance rises above λ and
//!    (b) the kept variances have not shifted past `[incremental]
//!    drift_tol`. [`drift_gate`] checks both; only when it fires does
//!    the session re-run elimination (the monotone re-elimination path:
//!    newly loud features enter the kept set, everything is recomputed
//!    from the merged variances).
//!
//! The [`watch`] submodule turns this into a polling daemon
//! (`lsspca watch`) that feeds the serving layer's hot-reload watcher.

pub mod watch;

use crate::checkpoint;
use crate::data::docword::{Doc, DocChunk};
use crate::data::shardcache::ShardCacheKey;
use crate::data::sparse::CsrMatrix;
use crate::elim::SafeElimination;
use crate::error::LsspcaError;
use crate::moments::{FeatureMoments, FeatureVariances};
use crate::stream::{ChunkSource, StreamStats};

// ---------------------------------------------------------------------------
// Chained digest
// ---------------------------------------------------------------------------

/// Advance the chained corpus digest: `H(prev ‖ segment)`.
///
/// The hash is the same FNV-1a used for every other corpus identity in
/// the crate ([`checkpoint::corpus_key`]), applied to a canonical text
/// encoding of the two inputs. Chaining is order-sensitive — appending
/// segments A then B yields a different digest than B then A — and the
/// digest only advances on a *successful* append, so a crashed or
/// rejected segment can never poison downstream cache keys.
pub fn chain_digest(prev: u64, seg: u64) -> u64 {
    checkpoint::corpus_key(&format!("chain:{prev:016x}:{seg:016x}"))
}

// ---------------------------------------------------------------------------
// Drift gate
// ---------------------------------------------------------------------------

/// Outcome of the drift gate for one appended segment.
#[derive(Clone, Copy, Debug)]
pub struct DriftReport {
    /// An eliminated feature's merged variance rose above λ — the
    /// Thm-2.1 certificate for the old kept set no longer holds and
    /// re-elimination is *mandatory* regardless of tolerance.
    pub mandatory: bool,
    /// Largest relative shift of any kept feature's variance vs. the
    /// value recorded at elimination time.
    pub max_shift: f64,
    /// Whether the gate fired (mandatory, or `max_shift > drift_tol`).
    pub fired: bool,
}

/// Decide whether an append invalidates the current elimination.
///
/// `elim` is the plan in force (with the kept variances recorded when
/// it was computed), `merged` the variances after folding the segment,
/// and `tol` the `[incremental] drift_tol` quality threshold. The
/// mandatory condition — some *non*-kept feature now has variance
/// above `elim.lambda` — fires even at `tol = ∞`, because Thm 2.1 only
/// certifies zero loadings for features below λ.
pub fn drift_gate(elim: &SafeElimination, merged: &FeatureVariances, tol: f64) -> DriftReport {
    let n = merged.variance.len();
    debug_assert_eq!(n, elim.original, "drift gate: feature count mismatch");
    let mut is_kept = vec![false; n];
    for &j in &elim.kept {
        is_kept[j] = true;
    }
    // Mandatory: a feature we eliminated is no longer safely below λ.
    let mandatory = merged
        .variance
        .iter()
        .enumerate()
        .any(|(j, &v)| !is_kept[j] && v > elim.lambda);
    // Quality: how far the survivors drifted from the variances the
    // plan (and the λ-search bracket derived from them) was built on.
    let mut max_shift = 0.0f64;
    for (r, &j) in elim.kept.iter().enumerate() {
        let old = elim.kept_variances[r];
        let shift = (merged.variance[j] - old).abs() / old.max(1e-12);
        if shift > max_shift {
            max_shift = shift;
        }
    }
    DriftReport { mandatory, max_shift, fired: mandatory || max_shift > tol }
}

// ---------------------------------------------------------------------------
// Append report
// ---------------------------------------------------------------------------

/// What one `Session::append` call did.
#[derive(Clone, Copy, Debug)]
pub struct AppendReport {
    /// Documents folded from the segment.
    pub docs: u64,
    /// `(word, count)` pairs folded from the segment.
    pub nnz: u64,
    /// Whether the drift gate fired (elimination will re-run).
    pub drift: bool,
    /// The chained corpus digest after this append.
    pub digest: u64,
    /// Wall time of the append fold.
    pub seconds: f64,
}

// ---------------------------------------------------------------------------
// Incremental state
// ---------------------------------------------------------------------------

/// Reduced CSR cached across appends, tagged with the elimination it
/// was built under so a re-elimination invalidates it.
#[derive(Clone)]
pub(crate) struct CachedCsr {
    /// Canonical reduced matrix over documents `[0, docs)`.
    pub(crate) csr: CsrMatrix,
    /// Documents covered (base + appended at build time).
    pub(crate) docs: u64,
    /// `shardcache::elim_digest` of the plan the columns map through.
    pub(crate) elim_digest: u64,
}

/// Live incremental state held by a `Session` between appends.
///
/// Owns the master Welford accumulator (complete chunks only), the
/// re-buffer tail (documents short of a full chunk), and an in-memory
/// replay store of every appended document — the latter is what lets
/// both the zero-read CSR extension *and* a drift-forced full
/// re-reduction run without re-reading the appended segments from
/// their (possibly gone) sources.
#[derive(Clone)]
pub struct IncrState {
    /// Master accumulator: complete chunks, merged in global order.
    pub(crate) moments: FeatureMoments,
    /// Pending documents of the trailing partial chunk (`< chunk_docs`).
    pub(crate) tail: Vec<Vec<(u32, f64)>>,
    /// Complete chunks merged into `moments` so far.
    pub(crate) chunks_done: u64,
    /// Chunk size of the fold (must stay fixed across appends).
    pub(crate) chunk_docs: usize,
    /// Documents in the base corpus (before the first append).
    pub(crate) base_docs: u64,
    /// Replay store: appended doc `i` has global id `base_docs + i`.
    pub(crate) appended: Vec<Vec<(u32, f64)>>,
    /// Chained corpus digest — advances only on successful appends.
    pub(crate) digest: u64,
    /// Reduced CSR reused across appends while the plan holds.
    pub(crate) csr: Option<CachedCsr>,
    /// Shard-cache key of the last on-disk manifest we wrote/extended,
    /// so the next append can extend those shards instead of rewriting.
    pub(crate) last_shard_key: Option<ShardCacheKey>,
    /// Set when a drift-forced re-elimination happened after the last
    /// fit — the next refit must re-run the λ-search cold.
    pub(crate) drift_since_fit: bool,
    /// Per-component λ values of the last completed fit (the warm path
    /// refits at these fixed λs, skipping the search).
    pub(crate) last_lambdas: Vec<f64>,
}

impl IncrState {
    /// Build the incremental state by streaming the base corpus once.
    ///
    /// This is the one unavoidable full pass: checkpoints only store
    /// finalized variances, and Welford *merge order* matters bitwise,
    /// so the master accumulator has to be rebuilt chunk-by-chunk. The
    /// fold mirrors [`crate::stream::resumable_variance_pass`] exactly
    /// (fresh accumulator per chunk, merged in order), which is what
    /// makes every later append bitwise-identical to a cold stream.
    pub fn bootstrap<S: ChunkSource>(
        source: &mut S,
        chunk_docs: usize,
        digest: u64,
    ) -> Result<(IncrState, StreamStats), LsspcaError> {
        assert!(chunk_docs >= 1);
        let t0 = std::time::Instant::now();
        let nf = source.num_features();
        let mut st = IncrState {
            moments: FeatureMoments::new(nf),
            tail: Vec::new(),
            chunks_done: 0,
            chunk_docs,
            base_docs: 0,
            appended: Vec::new(),
            digest,
            csr: None,
            last_shard_key: None,
            drift_since_fit: false,
            last_lambdas: Vec::new(),
        };
        let mut stats = StreamStats::default();
        while let Some(chunk) = source.next_chunk(chunk_docs)? {
            stats.docs += chunk.docs.len() as u64;
            stats.nnz += chunk.total_nnz() as u64;
            stats.chunks += 1;
            for doc in chunk.docs {
                st.buffer_doc(doc.words);
            }
        }
        st.base_docs = st.total_docs();
        stats.seconds = t0.elapsed().as_secs_f64();
        Ok((st, stats))
    }

    /// Number of features the fold is sized for.
    pub fn num_features(&self) -> usize {
        self.moments.num_features()
    }

    /// Total documents folded so far (complete chunks + tail).
    pub fn total_docs(&self) -> u64 {
        self.moments.docs + self.tail.len() as u64
    }

    /// Total `(word, count)` pairs folded so far.
    pub fn total_nnz(&self) -> u64 {
        self.moments.nnz + self.tail.iter().map(|w| w.len() as u64).sum::<u64>()
    }

    /// The chained corpus digest.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Whether a drift-forced re-elimination happened since the last fit.
    pub fn drift_since_fit(&self) -> bool {
        self.drift_since_fit
    }

    /// Push one document into the re-buffer; fold a complete chunk.
    fn buffer_doc(&mut self, words: Vec<(u32, f64)>) {
        self.tail.push(words);
        if self.tail.len() == self.chunk_docs {
            self.fold_tail_chunk();
        }
    }

    /// Fold the (full) tail as one fresh chunk accumulator, merged in
    /// order — the exact structure of the resumable pass's merger.
    fn fold_tail_chunk(&mut self) {
        let mut fresh = FeatureMoments::new(self.num_features());
        for words in &self.tail {
            fresh.push_doc(words);
        }
        self.moments.merge(&fresh);
        self.chunks_done += 1;
        self.tail.clear();
    }

    /// Fold an appended segment into the master accumulator.
    ///
    /// Every segment document is retained in the replay store (global
    /// ids continue from the current total). `skip_folded` documents at
    /// the front go to the replay store *only* — they were already
    /// merged into `moments` by a resumed job state (see
    /// `Session::append`'s resume math: any persisted chunk count lies
    /// strictly past the pre-append total, so the skipped prefix is
    /// pure segment docs). `persist` fires after every `persist_every`
    /// chunk merges with the master accumulator and the *global*
    /// completed-chunk count, mirroring the resumable pass cadence.
    ///
    /// Returns `(docs, nnz)` of the full segment (including skipped).
    pub fn append_docs<S, F>(
        &mut self,
        source: &mut S,
        persist_every: u64,
        mut persist: F,
        skip_folded: u64,
    ) -> Result<(u64, u64), LsspcaError>
    where
        S: ChunkSource,
        F: FnMut(&FeatureMoments, u64) -> Result<(), LsspcaError>,
    {
        if source.num_features() != self.num_features() {
            return Err(LsspcaError::config(format!(
                "append: segment has {} features, session has {}",
                source.num_features(),
                self.num_features()
            )));
        }
        let (mut docs, mut nnz) = (0u64, 0u64);
        let mut skip = skip_folded;
        let mut unsaved = 0u64;
        while let Some(chunk) = source.next_chunk(self.chunk_docs)? {
            for doc in chunk.docs {
                docs += 1;
                nnz += doc.words.len() as u64;
                self.appended.push(doc.words.clone());
                if skip > 0 {
                    skip -= 1;
                    continue;
                }
                let before = self.chunks_done;
                self.buffer_doc(doc.words);
                if self.chunks_done > before {
                    unsaved += 1;
                    if persist_every > 0 && unsaved >= persist_every {
                        persist(&self.moments, self.chunks_done)?;
                        unsaved = 0;
                    }
                }
            }
        }
        if skip > 0 {
            return Err(LsspcaError::cache(format!(
                "append resume: job state covers {skip} more docs than the segment provides"
            )));
        }
        Ok((docs, nnz))
    }

    /// Finalize the merged per-feature variances without disturbing the
    /// running state: the tail is folded as one last (partial) fresh
    /// chunk — exactly what the resumable pass does with a final short
    /// chunk — into a clone of the master.
    pub fn finalize_variances(&self) -> FeatureVariances {
        let mut master = self.moments.clone();
        if !self.tail.is_empty() {
            let mut fresh = FeatureMoments::new(self.num_features());
            for words in &self.tail {
                fresh.push_doc(words);
            }
            master.merge(&fresh);
        }
        master.finalize()
    }

    /// Record a completed fit's per-component λs and clear the drift flag.
    pub(crate) fn record_fit(&mut self, lambdas: Vec<f64>) {
        self.last_lambdas = lambdas;
        self.drift_since_fit = false;
    }

    /// Mark that elimination was invalidated by drift.
    pub(crate) fn mark_drift(&mut self) {
        self.drift_since_fit = true;
        self.csr = None;
        self.last_shard_key = None;
    }
}

// ---------------------------------------------------------------------------
// Segment source adapters
// ---------------------------------------------------------------------------

/// Replay appended documents out of the in-memory store, with their
/// global document ids (`start_id + ordinal`).
pub struct ReplaySource<'a> {
    docs: &'a [Vec<(u32, f64)>],
    start_id: u64,
    pos: usize,
    num_features: usize,
}

impl<'a> ReplaySource<'a> {
    /// Replay `docs`, assigning ids `start_id..start_id + docs.len()`.
    pub fn new(
        docs: &'a [Vec<(u32, f64)>],
        start_id: u64,
        num_features: usize,
    ) -> ReplaySource<'a> {
        ReplaySource { docs, start_id, pos: 0, num_features }
    }
}

impl ChunkSource for ReplaySource<'_> {
    fn num_features(&self) -> usize {
        self.num_features
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        if self.pos >= self.docs.len() {
            return Ok(None);
        }
        let end = (self.pos + max_docs).min(self.docs.len());
        let docs = (self.pos..end)
            .map(|i| Doc { id: (self.start_id as usize) + i, words: self.docs[i].clone() })
            .collect();
        self.pos = end;
        Ok(Some(DocChunk { docs }))
    }
}

/// Concatenate two chunk sources: all of `a`, then all of `b`.
///
/// Chunk boundaries at the seam may be partial; that is fine for every
/// consumer the incremental path feeds (the reduce pass canonicalizes
/// by document id, the dense fold is order-only), and the Welford fold
/// never uses this adapter — it re-buffers documents itself.
pub struct ChainSource<A: ChunkSource, B: ChunkSource> {
    a: A,
    b: B,
    on_second: bool,
}

impl<A: ChunkSource, B: ChunkSource> ChainSource<A, B> {
    /// Chain `a` then `b`; errors if their feature counts differ.
    pub fn new(a: A, b: B) -> Result<ChainSource<A, B>, LsspcaError> {
        if a.num_features() != b.num_features() {
            return Err(LsspcaError::config(format!(
                "chained sources disagree on features: {} vs {}",
                a.num_features(),
                b.num_features()
            )));
        }
        Ok(ChainSource { a, b, on_second: false })
    }
}

impl<A: ChunkSource, B: ChunkSource> ChunkSource for ChainSource<A, B> {
    fn num_features(&self) -> usize {
        self.a.num_features()
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        if !self.on_second {
            if let Some(chunk) = self.a.next_chunk(max_docs)? {
                return Ok(Some(chunk));
            }
            self.on_second = true;
        }
        self.b.next_chunk(max_docs)
    }
}

/// Drop the first `skip` documents of a source, pass the rest through.
///
/// The watch daemon uses this to slice the appended suffix out of a
/// docword file that grew in place (the reader has no seek-to-doc).
pub struct SkipSource<S: ChunkSource> {
    inner: S,
    remaining: u64,
}

impl<S: ChunkSource> SkipSource<S> {
    /// Skip the first `skip` documents of `inner`.
    pub fn new(inner: S, skip: u64) -> SkipSource<S> {
        SkipSource { inner, remaining: skip }
    }
}

impl<S: ChunkSource> ChunkSource for SkipSource<S> {
    fn num_features(&self) -> usize {
        self.inner.num_features()
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        loop {
            let Some(mut chunk) = self.inner.next_chunk(max_docs)? else {
                return Ok(None);
            };
            if self.remaining == 0 {
                return Ok(Some(chunk));
            }
            let drop = (self.remaining as usize).min(chunk.docs.len());
            chunk.docs.drain(..drop);
            self.remaining -= drop as u64;
            if !chunk.docs.is_empty() {
                return Ok(Some(chunk));
            }
        }
    }
}

/// Cap a source at its first `limit` documents.
///
/// In watch mode the input docword file grows *in place*, so a plain
/// re-open of the base corpus would also stream the appended suffix and
/// double-count it against the replay store. Wrapping the base stream
/// in a `LimitSource` at `base_docs` restores the original prefix.
pub struct LimitSource<S: ChunkSource> {
    inner: S,
    remaining: u64,
}

impl<S: ChunkSource> LimitSource<S> {
    /// Pass through at most the first `limit` documents of `inner`.
    pub fn new(inner: S, limit: u64) -> LimitSource<S> {
        LimitSource { inner, remaining: limit }
    }
}

impl<S: ChunkSource> ChunkSource for LimitSource<S> {
    fn num_features(&self) -> usize {
        self.inner.num_features()
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let want = (self.remaining as usize).min(max_docs);
        let Some(mut chunk) = self.inner.next_chunk(want)? else {
            self.remaining = 0;
            return Ok(None);
        };
        if chunk.docs.len() as u64 > self.remaining {
            chunk.docs.truncate(self.remaining as usize);
        }
        self.remaining -= chunk.docs.len() as u64;
        if chunk.docs.is_empty() {
            return Ok(None);
        }
        Ok(Some(chunk))
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusSpec, SynthCorpus};
    use crate::stream::{resumable_variance_pass, StreamOptions, SynthSource};

    fn corpus(docs: usize) -> SynthCorpus {
        SynthCorpus::new(CorpusSpec::nytimes().scaled(docs, 400), 7)
    }

    /// The tentpole invariant: bootstrap(base) + append(suffix) merges
    /// bitwise-identically to the resumable pass over the grown corpus,
    /// at a chunk size that leaves a partial tail on both sides.
    #[test]
    fn append_fold_matches_cold_resumable_pass() {
        let base = corpus(230);
        let grown = corpus(300);
        let opts = StreamOptions { workers: 2, chunk_docs: 64, queue_depth: 4 };

        let mut cold_src = SynthSource::new(&grown);
        let (cold, cold_stats) =
            resumable_variance_pass(&mut cold_src, opts, None, 1_000_000, |_, _| Ok(())).unwrap();

        let (mut st, boot_stats) =
            IncrState::bootstrap(&mut SynthSource::new(&base), 64, 1).unwrap();
        assert_eq!(boot_stats.docs, 230);
        assert_eq!(st.base_docs, 230);
        assert_eq!(st.chunks_done, 3); // 230 = 3*64 + 38
        assert_eq!(st.tail.len(), 38);

        let mut seg = SynthSource::starting_at(&grown, 230);
        let (docs, nnz) = st.append_docs(&mut seg, 1_000_000, |_, _| Ok(()), 0).unwrap();
        assert_eq!(docs, 70);
        assert!(nnz > 0);
        assert_eq!(st.total_docs(), 300);
        assert_eq!(st.appended.len(), 70);

        let merged = st.finalize_variances();
        assert_eq!(cold_stats.docs, 300);
        assert_eq!(merged.docs, cold.docs);
        for j in 0..merged.variance.len() {
            assert_eq!(merged.variance[j].to_bits(), cold.variance[j].to_bits(), "var {j}");
            assert_eq!(merged.mean[j].to_bits(), cold.mean[j].to_bits(), "mean {j}");
            assert_eq!(
                merged.second_moment[j].to_bits(),
                cold.second_moment[j].to_bits(),
                "m2 {j}"
            );
        }
        // nnz bookkeeping matches the cold pass too.
        assert_eq!(st.total_nnz(), cold_stats.nnz);
    }

    /// Resume parity: fold a prefix of the segment, persist, then start
    /// over from the persisted moments with `skip_folded` — bitwise
    /// identical to the uninterrupted fold, and the replay store is
    /// complete either way.
    #[test]
    fn append_resume_from_persisted_moments_is_bitwise() {
        let base = corpus(128); // exactly 2 chunks of 64: empty tail
        let grown = corpus(320);

        // Uninterrupted reference.
        let (mut full, _) = IncrState::bootstrap(&mut SynthSource::new(&base), 64, 9).unwrap();
        full.append_docs(&mut SynthSource::starting_at(&grown, 128), 1_000_000, |_, _| Ok(()), 0)
            .unwrap();

        // Interrupted: persist after every merge, fail after the first.
        let (mut st, _) = IncrState::bootstrap(&mut SynthSource::new(&base), 64, 9).unwrap();
        let saved: std::cell::RefCell<Option<(FeatureMoments, u64)>> =
            std::cell::RefCell::new(None);
        let err = st
            .append_docs(
                &mut SynthSource::starting_at(&grown, 128),
                1,
                |m, done| {
                    if saved.borrow().is_some() {
                        return Err(LsspcaError::io("simulated kill"));
                    }
                    *saved.borrow_mut() = Some((m.clone(), done));
                    Ok(())
                },
                0,
            )
            .unwrap_err();
        assert!(format!("{err}").contains("simulated kill"));

        // Fresh state resumes from the persisted accumulator: skip the
        // segment docs already covered by the saved chunk count.
        let (moments, done) = saved.into_inner().unwrap();
        let (mut res, _) = IncrState::bootstrap(&mut SynthSource::new(&base), 64, 9).unwrap();
        let covered = done * 64; // total docs in complete chunks
        let skip = covered - res.total_docs();
        res.moments = moments;
        res.chunks_done = done;
        res.tail.clear();
        res.append_docs(&mut SynthSource::starting_at(&grown, 128), 1_000_000, |_, _| Ok(()), skip)
            .unwrap();

        let a = full.finalize_variances();
        let b = res.finalize_variances();
        for j in 0..a.variance.len() {
            assert_eq!(a.variance[j].to_bits(), b.variance[j].to_bits());
        }
        assert_eq!(full.appended.len(), res.appended.len());
        for (x, y) in full.appended.iter().zip(&res.appended) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn chain_digest_is_deterministic_and_order_sensitive() {
        let a = checkpoint::corpus_key("segment-a");
        let b = checkpoint::corpus_key("segment-b");
        assert_eq!(chain_digest(a, b), chain_digest(a, b));
        assert_ne!(chain_digest(a, b), chain_digest(b, a));
        assert_ne!(chain_digest(a, b), a);
        assert_ne!(chain_digest(a, b), b);
        // Chaining twice differs from chaining once (no fixed point).
        let ab = chain_digest(a, b);
        assert_ne!(chain_digest(ab, b), ab);
        // Cross-language pins shared with python/tests/test_incr_mirror.py:
        // the canonical encoding zero-pads to 16 hex chars.
        assert_eq!(chain_digest(0, 0), 0x26D9201420613A5A);
        assert_eq!(
            chain_digest(
                checkpoint::corpus_key("synth:nytimes-synth:300:800:20111212"),
                checkpoint::corpus_key("parity-segment"),
            ),
            0xA67C6AEE4B56EE10
        );
    }

    #[test]
    fn drift_gate_mandatory_and_quality_paths() {
        // Features 0,1 kept; 2,3 eliminated at λ = 1.0.
        let elim = SafeElimination::apply(&[4.0, 2.0, 0.5, 0.2], 1.0, None);
        assert_eq!(elim.kept, vec![0, 1]);

        let fv = |v: Vec<f64>| FeatureVariances {
            variance: v,
            mean: vec![0.0; 4],
            second_moment: vec![0.0; 4],
            docs: 10,
        };

        // No movement: quiet at any tolerance.
        let r = drift_gate(&elim, &fv(vec![4.0, 2.0, 0.5, 0.2]), 0.01);
        assert!(!r.fired && !r.mandatory);

        // Kept variance shifts 10%: fires at tol 0.05, not at tol 0.5.
        let r = drift_gate(&elim, &fv(vec![4.4, 2.0, 0.5, 0.2]), 0.05);
        assert!(r.fired && !r.mandatory);
        assert!((r.max_shift - 0.1).abs() < 1e-12);
        let r = drift_gate(&elim, &fv(vec![4.4, 2.0, 0.5, 0.2]), 0.5);
        assert!(!r.fired);

        // Eliminated feature rises above λ: mandatory even at huge tol.
        let r = drift_gate(&elim, &fv(vec![4.0, 2.0, 1.5, 0.2]), 1e9);
        assert!(r.fired && r.mandatory);
    }

    #[test]
    fn replay_chain_skip_sources_compose() {
        let grown = corpus(50);
        // Materialize docs 30..50 as a replay store.
        let mut suffix = Vec::new();
        for d in 30..50 {
            suffix.push(grown.generate_doc(d));
        }
        let replay = ReplaySource::new(&suffix, 30, 400);
        let base = SynthSource::new(&corpus(30));
        // ChainSource over (base corpus, replay) == full grown stream.
        let mut chain = ChainSource::new(base, replay).unwrap();
        let mut ids = Vec::new();
        while let Some(chunk) = chain.next_chunk(16).unwrap() {
            for doc in &chunk.docs {
                assert_eq!(doc.words, grown.generate_doc(doc.id));
                ids.push(doc.id);
            }
        }
        assert_eq!(ids, (0..50).collect::<Vec<_>>());

        // SkipSource drops exactly the first k docs, across chunk seams.
        let mut skip = SkipSource::new(SynthSource::new(&grown), 37);
        let mut ids = Vec::new();
        while let Some(chunk) = skip.next_chunk(16).unwrap() {
            for doc in &chunk.docs {
                ids.push(doc.id);
            }
        }
        assert_eq!(ids, (37..50).collect::<Vec<_>>());

        // LimitSource caps at the first k docs, across chunk seams.
        let mut lim = LimitSource::new(SynthSource::new(&grown), 37);
        let mut ids = Vec::new();
        while let Some(chunk) = lim.next_chunk(16).unwrap() {
            for doc in &chunk.docs {
                ids.push(doc.id);
            }
        }
        assert_eq!(ids, (0..37).collect::<Vec<_>>());
        // Limit past the end is harmless; limit 0 yields nothing.
        let mut lim = LimitSource::new(SynthSource::new(&grown), 99);
        let mut n = 0;
        while let Some(chunk) = lim.next_chunk(16).unwrap() {
            n += chunk.docs.len();
        }
        assert_eq!(n, 50);
        let mut lim = LimitSource::new(SynthSource::new(&grown), 0);
        assert!(lim.next_chunk(16).unwrap().is_none());

        // LimitSource(base) ++ Replay(suffix) reproduces the grown stream
        // even when the underlying file already contains the suffix —
        // the watch-mode double-count guard.
        let grown_src = SynthSource::new(&grown);
        let replay = ReplaySource::new(&suffix, 30, 400);
        let mut chain = ChainSource::new(LimitSource::new(grown_src, 30), replay).unwrap();
        let mut ids = Vec::new();
        while let Some(chunk) = chain.next_chunk(16).unwrap() {
            for doc in &chunk.docs {
                assert_eq!(doc.words, grown.generate_doc(doc.id));
                ids.push(doc.id);
            }
        }
        assert_eq!(ids, (0..50).collect::<Vec<_>>());

        // Feature-count mismatch is a config error.
        let narrow = SynthCorpus::new(CorpusSpec::nytimes().scaled(10, 300), 7);
        let err =
            ChainSource::new(SynthSource::new(&grown), SynthSource::new(&narrow)).unwrap_err();
        assert!(format!("{err}").contains("features"));
    }

    #[test]
    fn append_rejects_feature_mismatch_and_short_resume() {
        let (mut st, _) = IncrState::bootstrap(&mut SynthSource::new(&corpus(64)), 64, 1).unwrap();
        let narrow = SynthCorpus::new(CorpusSpec::nytimes().scaled(10, 300), 7);
        let err = st
            .append_docs(&mut SynthSource::new(&narrow), 0, |_, _| Ok(()), 0)
            .unwrap_err();
        assert!(format!("{err}").contains("features"));

        // skip_folded beyond the segment length is a corrupt-resume error.
        let tiny = corpus(70); // segment = docs 64..70
        let err = st
            .append_docs(&mut SynthSource::starting_at(&tiny, 64), 0, |_, _| Ok(()), 99)
            .unwrap_err();
        assert!(format!("{err}").contains("resume"));
    }
}
