//! The `lsspca watch` daemon: keep a model artifact fresh as its
//! docword corpus grows in place.
//!
//! The daemon polls the input file's `(len, mtime)` signature — the
//! same change detector the serving layer's hot-reload watcher uses
//! ([`crate::serve::reload::stat_sig`]) — and, when the corpus has
//! grown, runs the incremental cycle: slice the appended suffix out of
//! the grown file ([`SkipSource`]), fold it with [`Session::append`]
//! (chained digest, drift gate, resumable job state), warm-refit with
//! [`Session::refit_incremental`], and atomically rewrite the LSPM
//! artifact ([`crate::model::Model::save`] renames a fully-fsynced file
//! into place). Point `lsspca serve --model-path` at the same artifact
//! and the reload watcher hot-swaps each refresh with zero dropped
//! requests — the end-to-end pinned by `rust/tests/incremental.rs`.
//!
//! Failures are contained: `Session::append` commits by clone-swap, so
//! a corrupt or half-written segment leaves the session, its chained
//! digest, and the served artifact untouched; the daemon logs the error
//! and retries on the next poll.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::config::PipelineConfig;
use crate::data::docword::DocwordReader;
use crate::error::LsspcaError;
use crate::incr::SkipSource;
use crate::serve::reload::{stat_sig, ArtifactSig};
use crate::session::Session;
use crate::stream::FileSource;

/// Knobs for one [`watch_corpus`] run.
#[derive(Clone, Debug)]
pub struct WatchOptions {
    /// Poll interval between corpus signature checks
    /// (`[incremental] watch_poll_ms`).
    pub poll: Duration,
    /// Stop after this many successful refits, counting the initial fit
    /// (0 = run until `shutdown`).
    pub max_refits: u64,
    /// Where the LSPM artifact is atomically rewritten after every
    /// refit — point the serving watcher at the same path.
    pub model_out: PathBuf,
}

/// What a [`watch_corpus`] run did.
#[derive(Clone, Copy, Debug, Default)]
pub struct WatchReport {
    /// Appended segments successfully folded.
    pub appends: u64,
    /// Successful refits — each one rewrote the artifact.
    pub refits: u64,
    /// Appends on which the drift gate fired (re-elimination ran).
    pub drifts: u64,
}

/// Run the watch daemon: fit the current corpus once and write the
/// artifact, then poll for growth until `shutdown` (or `max_refits`).
///
/// Requires a file corpus (`[data] input`) — a synthetic corpus cannot
/// grow. The session is built fresh from `cfg`, so every `[robustness]`
/// knob (retry schedule, job state, dead-letter quarantine, fault
/// injection) applies to the daemon's folds exactly as it would to a
/// one-shot run.
pub fn watch_corpus(
    cfg: &PipelineConfig,
    opts: &WatchOptions,
    shutdown: &AtomicBool,
) -> Result<WatchReport, LsspcaError> {
    if cfg.input.is_empty() {
        return Err(LsspcaError::config(
            "watch: requires a docword input file (a synthetic corpus cannot grow)",
        ));
    }
    let input = PathBuf::from(&cfg.input);
    let mut session = Session::from_config(cfg.clone())?;
    let mut report = WatchReport::default();

    // Capture the signature *before* the initial fit: if the corpus
    // grows while the fit streams it, the next poll still sees a change
    // and folds whatever the bootstrap did not cover.
    let mut last_sig: Option<ArtifactSig> = stat_sig(&input);
    let fit = session.refit_incremental()?;
    fit.model.save(&opts.model_out)?;
    report.refits += 1;
    crate::info!("watch: initial model written to {}", opts.model_out.display());
    if opts.max_refits > 0 && report.refits >= opts.max_refits {
        return Ok(report);
    }

    while !shutdown.load(Ordering::SeqCst) {
        stepped_sleep(opts.poll, shutdown);
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let sig = stat_sig(&input);
        if sig.is_none() || sig == last_sig {
            continue; // unchanged, or mid-rename / gone: next poll
        }
        match append_growth(cfg, &input, &mut session, opts, &mut report) {
            Ok(()) => last_sig = sig,
            // The clone-commit in `Session::append` left the session and
            // its chained digest untouched; the old artifact keeps
            // serving and the next poll retries.
            Err(e) => crate::warn_!("watch: append failed, will retry: {e}"),
        }
        if opts.max_refits > 0 && report.refits >= opts.max_refits {
            break;
        }
    }
    Ok(report)
}

/// One detected change: fold any appended documents, refit, rewrite the
/// artifact. A change without growth (e.g. an in-place rewrite of the
/// same documents) is a no-op.
fn append_growth(
    cfg: &PipelineConfig,
    input: &Path,
    session: &mut Session,
    opts: &WatchOptions,
    report: &mut WatchReport,
) -> Result<(), LsspcaError> {
    let header_docs = DocwordReader::open(input)?.header().num_docs as u64;
    let folded = session.stats().map(|s| s.docs).unwrap_or(0);
    if header_docs <= folded {
        return Ok(());
    }
    let len = std::fs::metadata(input).map(|m| m.len()).unwrap_or(0);
    let identity = format!("file:{}:{len}", input.display());
    let seg_digest = crate::checkpoint::corpus_key(&identity);
    let policy = crate::session::record_policy(cfg, input, seg_digest)?;
    let mut src = SkipSource::new(FileSource::open_with_policy(input, policy)?, folded);
    let ar = session.append(&mut src, &identity)?;
    report.appends += 1;
    report.drifts += ar.drift as u64;
    crate::info!(
        "watch: appended {} docs, {} nnz (drift={}, digest {:016x})",
        ar.docs,
        ar.nnz,
        ar.drift,
        ar.digest
    );
    let fit = session.refit_incremental()?;
    fit.model.save(&opts.model_out)?;
    report.refits += 1;
    crate::info!("watch: artifact refreshed at {}", opts.model_out.display());
    Ok(())
}

/// Sleep `poll` in short steps so `shutdown` is honored promptly even
/// with a long poll interval (mirrors the reload watcher's loop).
fn stepped_sleep(poll: Duration, shutdown: &AtomicBool) {
    let mut left = poll;
    while !left.is_zero() && !shutdown.load(Ordering::SeqCst) {
        let step = left.min(Duration::from_millis(25));
        std::thread::sleep(step);
        left -= step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusSpec, SynthCorpus};

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lsspca_watch_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn small_cfg(input: &Path) -> PipelineConfig {
        PipelineConfig {
            input: input.display().to_string(),
            workers: 1,
            chunk_docs: 64,
            target_card: 5,
            card_slack: 2,
            max_reduced: 32,
            bca_sweeps: 4,
            num_pcs: 1,
            ..Default::default()
        }
    }

    #[test]
    fn watch_requires_a_file_corpus() {
        let opts = WatchOptions {
            poll: Duration::from_millis(10),
            max_refits: 1,
            model_out: std::env::temp_dir().join("lsspca_watch_never.lspm"),
        };
        let err =
            watch_corpus(&PipelineConfig::default(), &opts, &AtomicBool::new(false)).unwrap_err();
        assert!(format!("{err}").contains("input"));
    }

    #[test]
    fn initial_fit_writes_artifact_and_growth_triggers_refresh() {
        let dir = tmpdir("grow");
        let input = dir.join("corpus.docword.txt");
        let model_out = dir.join("model.lspm");
        let base = SynthCorpus::new(CorpusSpec::nytimes().scaled(200, 400), 7);
        base.write_docword(&input).unwrap();

        let cfg = small_cfg(&input);
        let opts = WatchOptions {
            poll: Duration::from_millis(10),
            max_refits: 2, // initial fit + one growth refresh, then exit
            model_out: model_out.clone(),
        };
        let shutdown = std::sync::Arc::new(AtomicBool::new(false));
        let handle = {
            let (cfg, opts, shutdown) =
                (cfg.clone(), opts.clone(), std::sync::Arc::clone(&shutdown));
            std::thread::spawn(move || watch_corpus(&cfg, &opts, &shutdown))
        };

        // Wait for the initial artifact (fit of the 200-doc base).
        let t0 = std::time::Instant::now();
        loop {
            if let Ok(m) = crate::model::Model::load(&model_out) {
                assert_eq!(m.num_docs, 200);
                break;
            }
            assert!(t0.elapsed().as_secs() < 60, "initial artifact never appeared");
            std::thread::sleep(Duration::from_millis(10));
        }

        // Grow the corpus in place; the daemon appends, refits, exits.
        let grown = SynthCorpus::new(CorpusSpec::nytimes().scaled(260, 400), 7);
        grown.write_docword(&input).unwrap();
        let report = handle.join().unwrap().unwrap();
        shutdown.store(true, Ordering::SeqCst);
        assert_eq!(report.refits, 2);
        assert_eq!(report.appends, 1);
        let m2 = crate::model::Model::load(&model_out).unwrap();
        assert_eq!(m2.num_docs, 260);

        std::fs::remove_dir_all(&dir).ok();
    }
}
