//! Typed pipeline configuration plus a TOML-subset parser (offline
//! substitute for `serde` + `toml`, see DESIGN.md §3).
//!
//! The subset covers what config files in this repo need: `[section]`
//! headers, `key = value` with string / integer / float / boolean values,
//! inline comments with `#`, and blank lines. Arrays of scalars are
//! supported with `[a, b, c]` syntax.
//!
//! [`PipelineConfig`] is *one* way to configure the system — the file
//! format behind `lsspca run --config`. Library callers should prefer
//! the typed [`crate::session::SessionBuilder`], which produces the same
//! validated configuration programmatically. Unknown `[section]`s and
//! keys in a parsed document are reported as warnings with
//! nearest-known-spelling suggestions (typo detection, e.g. `[memry]` →
//! `[memory]`), so a misspelled knob never silently becomes a no-op.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::error::LsspcaError;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `[a, b, c]` array of scalars.
    Array(Vec<Value>),
}

impl Value {
    /// String view, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric view (floats and ints both coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Integer view, if this is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Non-negative integer view.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    /// Boolean view, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: `section.key -> value` (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Document {
    entries: BTreeMap<(String, String), Value>,
}

impl Document {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Document, LsspcaError> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = inner.trim().to_string();
                if section.is_empty() {
                    return Err(LsspcaError::config(format!(
                        "line {}: empty section name",
                        lineno + 1
                    )));
                }
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| {
                LsspcaError::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(LsspcaError::config(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(val.trim())
                .map_err(|e| LsspcaError::config(format!("line {}: {e}", lineno + 1)))?;
            doc.entries.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &Path) -> Result<Document, LsspcaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| LsspcaError::io_at(path, format!("reading config: {e}")))?;
        Document::parse(&text)
    }

    /// Look up `[section] key` (top-level keys use section `""`).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// Iterate every parsed entry as `(section, key, value)`, in sorted
    /// order (the unknown-key detector walks this).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries.iter().map(|((s, k), v)| (s.as_str(), k.as_str(), v))
    }

    fn typed<T>(
        &self,
        section: &str,
        key: &str,
        default: T,
        conv: impl Fn(&Value) -> Option<T>,
    ) -> Result<T, LsspcaError> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => conv(v).ok_or_else(|| {
                LsspcaError::config(format!("[{section}] {key}: unexpected type ({v})"))
            }),
        }
    }

    /// `f64` at `[section] key`, or `default` when absent.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64, LsspcaError> {
        self.typed(section, key, default, |v| v.as_f64())
    }
    /// `usize` at `[section] key`, or `default` when absent.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize, LsspcaError> {
        self.typed(section, key, default, |v| v.as_usize())
    }
    /// `u64` at `[section] key`, or `default` when absent.
    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> Result<u64, LsspcaError> {
        self.typed(section, key, default, |v| v.as_i64().and_then(|i| u64::try_from(i).ok()))
    }
    /// `bool` at `[section] key`, or `default` when absent.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool, LsspcaError> {
        self.typed(section, key, default, |v| v.as_bool())
    }
    /// `String` at `[section] key`, or `default` when absent.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String, LsspcaError> {
        self.typed(section, key, default.to_string(), |v| v.as_str().map(|s| s.to_string()))
    }
    /// `Vec<String>` at `[section] key` (an array of strings), or
    /// `default` when absent.
    pub fn strs_or(
        &self,
        section: &str,
        key: &str,
        default: &[String],
    ) -> Result<Vec<String>, LsspcaError> {
        self.typed(section, key, default.to_vec(), |v| match v {
            Value::Array(xs) => xs.iter().map(|x| x.as_str().map(str::to_string)).collect(),
            _ => None,
        })
    }
}

/// Every `[section] key` the pipeline configuration consumes — the
/// whitelist behind [`unknown_key_warnings`]. Keep in sync with
/// [`PipelineConfig::from_document`].
const KNOWN_KEYS: &[(&str, &str)] = &[
    ("corpus", "input"),
    ("corpus", "preset"),
    ("corpus", "docs"),
    ("corpus", "vocab"),
    ("corpus", "seed"),
    ("corpus", "cache_dir"),
    ("stream", "workers"),
    ("stream", "chunk_docs"),
    ("stream", "queue_depth"),
    ("solver", "threads"),
    ("solver", "lambda_probes"),
    ("solver", "num_pcs"),
    ("solver", "target_card"),
    ("solver", "card_slack"),
    ("solver", "max_reduced"),
    ("solver", "row_cache_mb"),
    ("solver", "bca_sweeps"),
    ("solver", "epsilon"),
    ("solver", "engine"),
    ("solver", "artifacts_dir"),
    ("solver", "deflation"),
    ("solver", "certify"),
    ("cov", "backend"),
    ("compute", "kernels"),
    ("compute", "fast_math"),
    ("memory", "budget_mb"),
    ("memory", "shard_mb"),
    ("model", "save_path"),
    ("model", "center"),
    ("model", "normalize"),
    ("serve", "addr"),
    ("serve", "pool"),
    ("serve", "timeout_secs"),
    ("serve", "queue_depth"),
    ("serve", "max_conns"),
    ("serve", "reload_poll_ms"),
    ("serve", "models"),
    ("robustness", "max_bad_records"),
    ("robustness", "dead_letter_path"),
    ("robustness", "retry_attempts"),
    ("robustness", "retry_base_ms"),
    ("robustness", "job_state"),
    ("robustness", "job_state_chunks"),
    ("robustness", "faults"),
    ("dist", "workers"),
    ("dist", "shard_docs"),
    ("incremental", "drift_tol"),
    ("incremental", "watch_poll_ms"),
];

/// Levenshtein edit distance (the strings involved are tiny).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Nearest candidate within edit distance 2, if any.
fn suggest<'a>(got: &str, candidates: impl Iterator<Item = &'a str>) -> Option<&'a str> {
    candidates
        .map(|c| (edit_distance(got, c), c))
        .min_by_key(|&(d, _)| d)
        .filter(|&(d, _)| d <= 2)
        .map(|(_, c)| c)
}

/// Warnings for entries a [`Document`] holds but [`PipelineConfig`]
/// never reads — silent typos like `[memry] budget_mb` or
/// `target_cards`. Each warning names the offending `[section] key` and
/// suggests the nearest known spelling when one is close.
/// [`PipelineConfig::from_document`] logs these; callers that want to
/// treat them as hard errors can check the returned list directly.
pub fn unknown_key_warnings(doc: &Document) -> Vec<String> {
    let mut out = Vec::new();
    for (section, key, _) in doc.entries() {
        if KNOWN_KEYS.iter().any(|&(s, k)| s == section && k == key) {
            continue;
        }
        let known_section = KNOWN_KEYS.iter().any(|&(s, _)| s == section);
        let msg = if known_section {
            let keys = KNOWN_KEYS.iter().filter(|&&(s, _)| s == section).map(|&(_, k)| k);
            match suggest(key, keys) {
                Some(near) => {
                    format!("[{section}] {key}: unknown key (did you mean '{near}'?)")
                }
                None => format!("[{section}] {key}: unknown key"),
            }
        } else {
            let mut sections: Vec<&str> = KNOWN_KEYS.iter().map(|&(s, _)| s).collect();
            sections.dedup();
            match suggest(section, sections.into_iter()) {
                Some(near) => format!(
                    "[{section}] {key}: unknown section '[{section}]' (did you mean '[{near}]'?)"
                ),
                None => format!("[{section}] {key}: unknown section '[{section}]'"),
            }
        };
        out.push(msg);
    }
    out
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// End-to-end pipeline configuration (see `coordinator::Pipeline`).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Path to a docword file (UCI bag-of-words format, optionally .gz);
    /// empty = generate a synthetic corpus instead.
    pub input: String,
    /// Synthetic corpus preset when `input` is empty: "nytimes" | "pubmed".
    pub synth_preset: String,
    /// Synthetic corpus document-count override (0 = preset default).
    pub synth_docs: usize,
    /// Synthetic corpus vocabulary-size override (0 = preset default).
    pub synth_vocab: usize,
    /// Corpus / generator seed.
    pub seed: u64,
    /// Directory for variance-pass checkpoints (empty = disabled). At
    /// PubMed scale the pass dominates wall time and is λ-independent, so
    /// re-runs reuse it (see `checkpoint`).
    pub cache_dir: String,
    /// Number of moment-pass worker threads.
    pub workers: usize,
    /// Worker threads for the solver-side parallel kernels (λ-search
    /// probes, path grids, Gram shards, deflation row blocks). 0 = use
    /// every available core; 1 = serial.
    pub threads: usize,
    /// Independent λ probes per bracketing round of the cardinality
    /// search. 1 = classic bisection (best per-eval bracketing; the
    /// serial default); raise toward `threads` to trade eval-efficiency
    /// for wall-clock parallelism. Part of the numerical schedule: fixed
    /// by config, never derived from the thread count, so results are
    /// machine-independent.
    pub lambda_probes: usize,
    /// Documents per streamed chunk.
    pub chunk_docs: usize,
    /// Bounded queue depth between reader and workers (backpressure).
    pub queue_depth: usize,
    /// Number of sparse PCs to extract.
    pub num_pcs: usize,
    /// Target cardinality per PC (paper: 5).
    pub target_card: usize,
    /// Accept solutions with cardinality within ±slack of target (paper
    /// accepts "close, but not necessarily equal").
    pub card_slack: usize,
    /// Hard cap on the reduced problem size n̂ after elimination.
    pub max_reduced: usize,
    /// Covariance backend (`[cov] backend`): "dense" materializes the
    /// reduced n̂ × n̂ matrix (solves bitwise the historical pipeline); "gram"
    /// keeps Σ implicit as a centered Gram operator over the reduced
    /// sparse term matrix — O(nnz) memory, so n̂ can reach tens of
    /// thousands; "disk" streams the reduced matrix from the on-disk
    /// shard cache under the `[memory] budget_mb` cap (bitwise-identical
    /// solves to "gram"); "auto" lets the memory-budget planner pick from
    /// the variance-pass footprint estimates.
    pub cov_backend: String,
    /// SIMD kernel dispatch (`[compute] kernels`): "auto" detects the
    /// best available tier at startup (AVX2 on x86-64, NEON on aarch64,
    /// scalar otherwise); "scalar" | "avx2" | "neon" force a tier
    /// (forcing an unavailable tier is a config error). All tiers are
    /// bitwise-identical, so this knob is purely about speed — see
    /// [`crate::kernels`].
    pub kernels: String,
    /// Allow reassociating FMA kernel variants (`[compute] fast_math`).
    /// Off by default: results then match the scalar reference bitwise.
    /// When on, dot reductions may use fused multiply-add (validated to
    /// agree within 1e-12 relative, but not bitwise).
    pub fast_math: bool,
    /// Resident-memory budget in MiB for the covariance stage
    /// (`[memory] budget_mb`; 0 = unlimited). Drives the `auto` backend
    /// decision and sizes the disk backend's Σ-row cache.
    pub memory_budget_mb: usize,
    /// Byte budget per on-disk shard, in MiB (`[memory] shard_mb`) — the
    /// streaming granularity of the disk backend.
    pub shard_mb: usize,
    /// Row-cache budget in MiB for the "gram" backend's lazily gathered
    /// Σ rows (solver.row_cache_mb; 0 disables caching).
    pub row_cache_mb: usize,
    /// BCA sweeps (paper: K typically 5).
    pub bca_sweeps: usize,
    /// ε for the barrier parameter β = ε/n.
    pub epsilon: f64,
    /// Solver engine: "native" | "xla".
    pub engine: String,
    /// Directory holding AOT artifacts (for engine = "xla").
    pub artifacts_dir: String,
    /// Deflation scheme: "projection" | "hotelling".
    pub deflation: String,
    /// Compute a dual optimality certificate per component (extra
    /// eigendecompositions; off by default).
    pub certify: bool,
    /// Path to write the trained model artifact to (`[model] save_path`;
    /// empty = don't save). `lsspca export --model-out` overrides.
    pub save_model: String,
    /// Scoring default: subtract training means (`[model] center`).
    pub score_center: bool,
    /// Scoring default: divide loadings by training standard deviations
    /// (`[model] normalize`).
    pub score_normalize: bool,
    /// Bind address for `lsspca serve` (`[serve] addr`).
    pub serve_addr: String,
    /// Connection-handler threads for `lsspca serve` (`[serve] pool`).
    pub serve_pool: usize,
    /// Per-connection socket read/write timeout in seconds for
    /// `lsspca serve` (`[serve] timeout_secs`; 0 = no timeout).
    pub serve_timeout_secs: u64,
    /// Accept-queue capacity for `lsspca serve` (`[serve] queue_depth`);
    /// a full queue sheds new connections with 503.
    pub serve_queue_depth: usize,
    /// Open-connection cap for `lsspca serve` (`[serve] max_conns`);
    /// beyond it new connections shed with 503.
    pub serve_max_conns: usize,
    /// Model-artifact watch interval in ms for hot reload
    /// (`[serve] reload_poll_ms`; 0 = reload off).
    pub serve_reload_poll_ms: u64,
    /// Registry rows for `lsspca serve` as `"name=path"` strings
    /// (`[serve] models`); empty = serve the `--model` flag only. The
    /// first entry is the default model.
    pub serve_models: Vec<String>,
    /// Tolerated count of malformed corpus records (`[robustness]
    /// max_bad_records`). 0 (default) keeps the strict behavior: the
    /// first bad record aborts the run. > 0 quarantines bad records to
    /// the dead-letter queue and aborts only past this budget.
    pub robust_max_bad_records: u64,
    /// Dead-letter queue path (`[robustness] dead_letter_path`; empty =
    /// derived: `<cache_dir>/deadletter_<digest>.jsonl`, or
    /// `<input>.deadletter.jsonl` without a cache dir).
    pub robust_dead_letter_path: String,
    /// Attempts per transient-I/O operation (`[robustness]
    /// retry_attempts`, >= 1; 1 = no retry).
    pub robust_retry_attempts: usize,
    /// Base backoff delay in ms for transient-I/O retries
    /// (`[robustness] retry_base_ms`; doubles per retry, capped).
    pub robust_retry_base_ms: u64,
    /// Persist resumable job state during the variance pass
    /// (`[robustness] job_state`; needs `corpus.cache_dir`).
    pub robust_job_state: bool,
    /// Chunks between job-state snapshots (`[robustness]
    /// job_state_chunks`, >= 1).
    pub robust_job_state_chunks: usize,
    /// Deterministic fault-injection plan (`[robustness] faults`,
    /// `op:tag@offset;...` — see `util::faultinject`; empty = off; test
    /// harness only).
    pub robust_faults: String,
    /// Worker processes for the distributed corpus pass (`[dist]
    /// workers`; 0 = disabled, run the passes in-process). > 0 shards
    /// the docword stream across re-exec'd worker processes — see
    /// [`crate::dist`]. Requires `corpus.cache_dir` (shard results and
    /// the job manifest live there).
    pub dist_workers: usize,
    /// Target documents per shard for the distributed pass (`[dist]
    /// shard_docs`; 0 = auto: 8 × `stream.chunk_docs`). Rounded up to a
    /// chunk multiple so shard boundaries never split a chunk.
    pub dist_shard_docs: u64,
    /// Drift tolerance for incremental appends (`[incremental]
    /// drift_tol`): the largest relative per-feature variance shift an
    /// appended segment may cause among *kept* features before the
    /// Thm-2.1 elimination is re-run from scratch. Below it the cached
    /// kept-feature set is provably still valid and reused (see
    /// [`crate::incr::drift_gate`]); 0.0 forces re-elimination on every
    /// append (the bitwise-parity setting).
    pub incr_drift_tol: f64,
    /// Poll interval in ms for the `lsspca watch` corpus daemon
    /// (`[incremental] watch_poll_ms`) — how often the input file's
    /// `(len, mtime)` signature is checked for growth.
    pub incr_watch_poll_ms: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            input: String::new(),
            synth_preset: "nytimes".into(),
            synth_docs: 0,
            synth_vocab: 0,
            seed: 20111212,
            cache_dir: String::new(),
            workers: 2,
            threads: 1,
            lambda_probes: 1,
            chunk_docs: 2048,
            queue_depth: 4,
            num_pcs: 5,
            target_card: 5,
            card_slack: 2,
            max_reduced: 512,
            cov_backend: "dense".into(),
            kernels: "auto".into(),
            fast_math: false,
            memory_budget_mb: 0,
            shard_mb: 32,
            row_cache_mb: 64,
            bca_sweeps: 5,
            epsilon: 1e-3,
            engine: "native".into(),
            artifacts_dir: "artifacts".into(),
            deflation: "projection".into(),
            certify: false,
            save_model: String::new(),
            score_center: true,
            score_normalize: false,
            serve_addr: "127.0.0.1:7878".into(),
            serve_pool: 4,
            serve_timeout_secs: 10,
            serve_queue_depth: 64,
            serve_max_conns: 1024,
            serve_reload_poll_ms: 1000,
            serve_models: Vec::new(),
            robust_max_bad_records: 0,
            robust_dead_letter_path: String::new(),
            robust_retry_attempts: 3,
            robust_retry_base_ms: 10,
            robust_job_state: true,
            robust_job_state_chunks: 64,
            robust_faults: String::new(),
            dist_workers: 0,
            dist_shard_docs: 0,
            incr_drift_tol: 0.05,
            incr_watch_poll_ms: 1000,
        }
    }
}

impl PipelineConfig {
    /// Build from a parsed TOML-subset document (missing keys =
    /// defaults). Unknown sections/keys are logged as warnings with a
    /// nearest-spelling suggestion — see [`unknown_key_warnings`].
    pub fn from_document(doc: &Document) -> Result<PipelineConfig, LsspcaError> {
        for w in unknown_key_warnings(doc) {
            crate::warn_!("config: {w}");
        }
        let d = PipelineConfig::default();
        let cfg = PipelineConfig {
            input: doc.str_or("corpus", "input", &d.input)?,
            synth_preset: doc.str_or("corpus", "preset", &d.synth_preset)?,
            synth_docs: doc.usize_or("corpus", "docs", d.synth_docs)?,
            synth_vocab: doc.usize_or("corpus", "vocab", d.synth_vocab)?,
            seed: doc.u64_or("corpus", "seed", d.seed)?,
            cache_dir: doc.str_or("corpus", "cache_dir", &d.cache_dir)?,
            workers: doc.usize_or("stream", "workers", d.workers)?,
            threads: doc.usize_or("solver", "threads", d.threads)?,
            lambda_probes: doc.usize_or("solver", "lambda_probes", d.lambda_probes)?,
            chunk_docs: doc.usize_or("stream", "chunk_docs", d.chunk_docs)?,
            queue_depth: doc.usize_or("stream", "queue_depth", d.queue_depth)?,
            num_pcs: doc.usize_or("solver", "num_pcs", d.num_pcs)?,
            target_card: doc.usize_or("solver", "target_card", d.target_card)?,
            card_slack: doc.usize_or("solver", "card_slack", d.card_slack)?,
            max_reduced: doc.usize_or("solver", "max_reduced", d.max_reduced)?,
            cov_backend: doc.str_or("cov", "backend", &d.cov_backend)?,
            kernels: doc.str_or("compute", "kernels", &d.kernels)?,
            fast_math: doc.bool_or("compute", "fast_math", d.fast_math)?,
            memory_budget_mb: doc.usize_or("memory", "budget_mb", d.memory_budget_mb)?,
            shard_mb: doc.usize_or("memory", "shard_mb", d.shard_mb)?,
            row_cache_mb: doc.usize_or("solver", "row_cache_mb", d.row_cache_mb)?,
            bca_sweeps: doc.usize_or("solver", "bca_sweeps", d.bca_sweeps)?,
            epsilon: doc.f64_or("solver", "epsilon", d.epsilon)?,
            engine: doc.str_or("solver", "engine", &d.engine)?,
            artifacts_dir: doc.str_or("solver", "artifacts_dir", &d.artifacts_dir)?,
            deflation: doc.str_or("solver", "deflation", &d.deflation)?,
            certify: doc.bool_or("solver", "certify", d.certify)?,
            save_model: doc.str_or("model", "save_path", &d.save_model)?,
            score_center: doc.bool_or("model", "center", d.score_center)?,
            score_normalize: doc.bool_or("model", "normalize", d.score_normalize)?,
            serve_addr: doc.str_or("serve", "addr", &d.serve_addr)?,
            serve_pool: doc.usize_or("serve", "pool", d.serve_pool)?,
            serve_timeout_secs: doc.u64_or("serve", "timeout_secs", d.serve_timeout_secs)?,
            serve_queue_depth: doc.usize_or("serve", "queue_depth", d.serve_queue_depth)?,
            serve_max_conns: doc.usize_or("serve", "max_conns", d.serve_max_conns)?,
            serve_reload_poll_ms: doc.u64_or("serve", "reload_poll_ms", d.serve_reload_poll_ms)?,
            serve_models: doc.strs_or("serve", "models", &d.serve_models)?,
            robust_max_bad_records: doc.u64_or(
                "robustness",
                "max_bad_records",
                d.robust_max_bad_records,
            )?,
            robust_dead_letter_path: doc.str_or(
                "robustness",
                "dead_letter_path",
                &d.robust_dead_letter_path,
            )?,
            robust_retry_attempts: doc.usize_or(
                "robustness",
                "retry_attempts",
                d.robust_retry_attempts,
            )?,
            robust_retry_base_ms: doc.u64_or("robustness", "retry_base_ms", d.robust_retry_base_ms)?,
            robust_job_state: doc.bool_or("robustness", "job_state", d.robust_job_state)?,
            robust_job_state_chunks: doc.usize_or(
                "robustness",
                "job_state_chunks",
                d.robust_job_state_chunks,
            )?,
            robust_faults: doc.str_or("robustness", "faults", &d.robust_faults)?,
            dist_workers: doc.usize_or("dist", "workers", d.dist_workers)?,
            dist_shard_docs: doc.u64_or("dist", "shard_docs", d.dist_shard_docs)?,
            incr_drift_tol: doc.f64_or("incremental", "drift_tol", d.incr_drift_tol)?,
            incr_watch_poll_ms: doc.u64_or(
                "incremental",
                "watch_poll_ms",
                d.incr_watch_poll_ms,
            )?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<PipelineConfig, LsspcaError> {
        Self::from_document(&Document::load(path)?)
    }

    /// Sanity-check field values.
    pub fn validate(&self) -> Result<(), LsspcaError> {
        let bad = |msg: String| Err(LsspcaError::config(msg));
        if self.workers == 0 {
            return bad("stream.workers must be >= 1".into());
        }
        if self.chunk_docs == 0 {
            return bad("stream.chunk_docs must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return bad("stream.queue_depth must be >= 1".into());
        }
        if self.num_pcs == 0 {
            return bad("solver.num_pcs must be >= 1".into());
        }
        if self.target_card == 0 {
            return bad("solver.target_card must be >= 1".into());
        }
        if self.lambda_probes == 0 {
            return bad("solver.lambda_probes must be >= 1".into());
        }
        if self.max_reduced < self.target_card {
            return bad("solver.max_reduced must be >= target_card".into());
        }
        if !(self.epsilon > 0.0) {
            return bad("solver.epsilon must be > 0".into());
        }
        match self.engine.as_str() {
            "native" | "xla" => {}
            other => return bad(format!("solver.engine '{other}' (want native|xla)")),
        }
        match self.cov_backend.as_str() {
            "dense" | "gram" | "disk" | "auto" => {}
            other => return bad(format!("cov.backend '{other}' (want dense|gram|disk|auto)")),
        }
        if crate::kernels::KernelMode::parse(&self.kernels).is_none() {
            return bad(format!(
                "compute.kernels '{}' (want auto|scalar|avx2|neon)",
                self.kernels
            ));
        }
        if self.shard_mb == 0 {
            return bad("memory.shard_mb must be >= 1".into());
        }
        if self.engine == "xla" && matches!(self.cov_backend.as_str(), "gram" | "disk") {
            // The XLA engine ships an explicit Σ to shape-static
            // artifacts; combined with an implicit backend it would
            // silently materialize the full n̂ × n̂ matrix once per
            // λ-probe — defeating the implicit backends' memory
            // contract at exactly the scales they exist for. ("auto"
            // is fine: the planner pins itself to dense under xla.)
            return bad(format!(
                "solver.engine = \"xla\" requires cov.backend = \"dense\" (the XLA \
                 artifacts need an explicit covariance matrix; \"{}\" would re-densify \
                 Σ per λ-probe)",
                self.cov_backend
            ));
        }
        match self.deflation.as_str() {
            "projection" | "hotelling" => {}
            other => return bad(format!("solver.deflation '{other}' (want projection|hotelling)")),
        }
        match self.synth_preset.as_str() {
            "nytimes" | "pubmed" => {}
            other => return bad(format!("corpus.preset '{other}' (want nytimes|pubmed)")),
        }
        if self.serve_pool == 0 {
            return bad("serve.pool must be >= 1".into());
        }
        if self.serve_addr.is_empty() {
            return bad("serve.addr must not be empty".into());
        }
        if self.serve_queue_depth == 0 {
            return bad("serve.queue_depth must be >= 1".into());
        }
        if self.serve_max_conns == 0 {
            return bad("serve.max_conns must be >= 1".into());
        }
        for entry in &self.serve_models {
            if !entry.contains('=') || entry.starts_with('=') || entry.ends_with('=') {
                return bad(format!("serve.models entry '{entry}' must be 'name=path'"));
            }
        }
        if self.robust_retry_attempts == 0 {
            return bad("robustness.retry_attempts must be >= 1".into());
        }
        if self.robust_job_state_chunks == 0 {
            return bad("robustness.job_state_chunks must be >= 1".into());
        }
        if !self.robust_faults.is_empty() {
            if let Err(e) = crate::util::faultinject::FaultPlan::parse(&self.robust_faults) {
                return bad(format!("robustness.faults: {e}"));
            }
        }
        if !(self.incr_drift_tol >= 0.0) {
            return bad("incremental.drift_tol must be >= 0".into());
        }
        if self.dist_workers > 0 && self.cache_dir.is_empty() {
            return bad(
                "dist.workers > 0 requires corpus.cache_dir (shard results and the \
                 dist manifest are cache files)"
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# pipeline config
[corpus]
preset = "pubmed"   # larger preset
docs = 10000
seed = 7

[stream]
workers = 3

[solver]
target_card = 5
epsilon = 0.01
engine = "native"
lambdas = [0.1, 0.2, 0.5]
"#;

    #[test]
    fn parse_sections_and_types() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("corpus", "preset"), Some(&Value::Str("pubmed".into())));
        assert_eq!(doc.get("corpus", "docs"), Some(&Value::Int(10000)));
        assert_eq!(doc.get("solver", "epsilon"), Some(&Value::Float(0.01)));
        match doc.get("solver", "lambdas") {
            Some(Value::Array(xs)) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comments_stripped_even_inline() {
        let doc = Document::parse("a = 1 # one\nb = \"x # not a comment\"").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&Value::Str("x # not a comment".into())));
    }

    #[test]
    fn config_from_document() {
        let doc = Document::parse(SAMPLE).unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.synth_preset, "pubmed");
        assert_eq!(cfg.synth_docs, 10000);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.epsilon, 0.01);
        // defaults fill in
        assert_eq!(cfg.num_pcs, 5);
    }

    #[test]
    fn validation_rejects_bad_engine() {
        let doc = Document::parse("[solver]\nengine = \"gpu\"").unwrap();
        assert!(PipelineConfig::from_document(&doc).is_err());
    }

    #[test]
    fn robustness_section_parses_and_validates() {
        let doc = Document::parse(
            "[robustness]\nmax_bad_records = 25\ndead_letter_path = \"dlq.jsonl\"\n\
             retry_attempts = 5\nretry_base_ms = 20\njob_state = false\n\
             job_state_chunks = 8\nfaults = \"rinterrupt:checkpoint@4\"",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.robust_max_bad_records, 25);
        assert_eq!(cfg.robust_dead_letter_path, "dlq.jsonl");
        assert_eq!(cfg.robust_retry_attempts, 5);
        assert_eq!(cfg.robust_retry_base_ms, 20);
        assert!(!cfg.robust_job_state);
        assert_eq!(cfg.robust_job_state_chunks, 8);
        assert_eq!(cfg.robust_faults, "rinterrupt:checkpoint@4");
        // defaults: strict reader, job state on, 3 retry attempts
        let d = PipelineConfig::default();
        assert_eq!(d.robust_max_bad_records, 0);
        assert!(d.robust_job_state);
        assert_eq!(d.robust_retry_attempts, 3);

        // zero retries / zero cadence / unparsable fault plans are
        // config errors, not silent surprises at hour three
        for bad in [
            "[robustness]\nretry_attempts = 0",
            "[robustness]\njob_state_chunks = 0",
            "[robustness]\nfaults = \"explode:everything@now\"",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(PipelineConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn dist_section_parses_and_validates() {
        let doc = Document::parse(
            "[corpus]\ncache_dir = \"cache\"\n[dist]\nworkers = 4\nshard_docs = 5000",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.dist_workers, 4);
        assert_eq!(cfg.dist_shard_docs, 5000);
        // defaults: disabled, auto shard size
        let d = PipelineConfig::default();
        assert_eq!(d.dist_workers, 0);
        assert_eq!(d.dist_shard_docs, 0);
        // shard results live in the cache: no cache dir, no dist pass
        let bad = Document::parse("[dist]\nworkers = 2").unwrap();
        let e = PipelineConfig::from_document(&bad).unwrap_err().to_string();
        assert!(e.contains("cache_dir"), "{e}");
    }

    #[test]
    fn incremental_section_parses_and_validates() {
        let doc =
            Document::parse("[incremental]\ndrift_tol = 0.1\nwatch_poll_ms = 50").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.incr_drift_tol, 0.1);
        assert_eq!(cfg.incr_watch_poll_ms, 50);
        // defaults: 5% drift tolerance, 1 s poll
        let d = PipelineConfig::default();
        assert_eq!(d.incr_drift_tol, 0.05);
        assert_eq!(d.incr_watch_poll_ms, 1000);
        // drift_tol = 0.0 is the bitwise-parity setting, not an error
        let zero = Document::parse("[incremental]\ndrift_tol = 0.0").unwrap();
        assert_eq!(PipelineConfig::from_document(&zero).unwrap().incr_drift_tol, 0.0);
        // negative (or NaN) tolerances are config errors
        let bad = Document::parse("[incremental]\ndrift_tol = -0.5").unwrap();
        let e = PipelineConfig::from_document(&bad).unwrap_err().to_string();
        assert!(e.contains("drift_tol"), "{e}");
    }

    #[test]
    fn serve_timeout_parses() {
        let doc = Document::parse("[serve]\ntimeout_secs = 0").unwrap();
        assert_eq!(PipelineConfig::from_document(&doc).unwrap().serve_timeout_secs, 0);
        assert_eq!(PipelineConfig::default().serve_timeout_secs, 10);
    }

    #[test]
    fn cov_backend_parses_and_validates() {
        let doc =
            Document::parse("[cov]\nbackend = \"gram\"\n[solver]\nrow_cache_mb = 16").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.cov_backend, "gram");
        assert_eq!(cfg.row_cache_mb, 16);
        // default backend is the bitwise-historical dense path
        assert_eq!(PipelineConfig::default().cov_backend, "dense");
        let bad = Document::parse("[cov]\nbackend = \"sparse\"").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
        // xla + gram would re-densify Σ per λ-probe; rejected up front
        let clash =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"gram\"").unwrap();
        let e = PipelineConfig::from_document(&clash).unwrap_err().to_string();
        assert!(e.contains("xla") && e.contains("gram"), "{e}");
    }

    #[test]
    fn memory_section_and_oocore_backends() {
        let doc = Document::parse(
            "[cov]\nbackend = \"auto\"\n[memory]\nbudget_mb = 256\nshard_mb = 8",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.cov_backend, "auto");
        assert_eq!(cfg.memory_budget_mb, 256);
        assert_eq!(cfg.shard_mb, 8);
        // defaults: unlimited budget, 32 MiB shards
        let d = PipelineConfig::default();
        assert_eq!(d.memory_budget_mb, 0);
        assert_eq!(d.shard_mb, 32);
        let disk = Document::parse("[cov]\nbackend = \"disk\"").unwrap();
        assert!(PipelineConfig::from_document(&disk).is_ok());
        let bad = Document::parse("[memory]\nshard_mb = 0").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
        // xla still incompatible with the implicit backends...
        let clash =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"disk\"").unwrap();
        assert!(PipelineConfig::from_document(&clash).is_err());
        // ...but auto is allowed (the planner pins itself to dense)
        let autoxla =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"auto\"").unwrap();
        assert!(PipelineConfig::from_document(&autoxla).is_ok());
    }

    #[test]
    fn compute_section_parses_and_validates() {
        let doc = Document::parse("[compute]\nkernels = \"scalar\"\nfast_math = true").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.kernels, "scalar");
        assert!(cfg.fast_math);
        // defaults: auto-detect, exact (bitwise) math
        let d = PipelineConfig::default();
        assert_eq!(d.kernels, "auto");
        assert!(!d.fast_math);
        // unknown tier names are config errors, not silent fallbacks
        let bad = Document::parse("[compute]\nkernels = \"sse9\"").unwrap();
        let e = PipelineConfig::from_document(&bad).unwrap_err().to_string();
        assert!(e.contains("compute.kernels"), "{e}");
        // forcing a tier this arch lacks is *not* a file-validation
        // error (configs stay portable); it fails at apply time.
        let forced = Document::parse("[compute]\nkernels = \"neon\"").unwrap();
        assert!(PipelineConfig::from_document(&forced).is_ok());
    }

    #[test]
    fn model_and_serve_sections_parse_and_validate() {
        let doc = Document::parse(
            "[model]\nsave_path = \"out/m.lspm\"\nnormalize = true\n\
             [serve]\naddr = \"0.0.0.0:9000\"\npool = 8",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.save_model, "out/m.lspm");
        assert!(cfg.score_normalize);
        assert!(cfg.score_center); // default stays on
        assert_eq!(cfg.serve_addr, "0.0.0.0:9000");
        assert_eq!(cfg.serve_pool, 8);
        let bad = Document::parse("[serve]\npool = 0").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
    }

    #[test]
    fn serve_registry_keys_parse_and_validate() {
        let doc = Document::parse(
            "[serve]\nqueue_depth = 16\nmax_conns = 99\nreload_poll_ms = 250\n\
             models = [\"nytimes=runs/nyt.lspm\", \"pubmed=runs/pm.lspm\"]",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.serve_queue_depth, 16);
        assert_eq!(cfg.serve_max_conns, 99);
        assert_eq!(cfg.serve_reload_poll_ms, 250);
        assert_eq!(cfg.serve_models, vec!["nytimes=runs/nyt.lspm", "pubmed=runs/pm.lspm"]);
        // defaults
        let d = PipelineConfig::default();
        assert_eq!(d.serve_queue_depth, 64);
        assert_eq!(d.serve_max_conns, 1024);
        assert_eq!(d.serve_reload_poll_ms, 1000);
        assert!(d.serve_models.is_empty());
        // malformed rows and zero knobs are rejected
        for bad in [
            "[serve]\nmodels = [\"no-equals-sign\"]",
            "[serve]\nmodels = [\"=path\"]",
            "[serve]\nmodels = [\"name=\"]",
            "[serve]\nmodels = [7]",
            "[serve]\nqueue_depth = 0",
            "[serve]\nmax_conns = 0",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(PipelineConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let e = Document::parse("ok = 1\nnot a kv line").unwrap_err();
        assert!(matches!(e, crate::error::LsspcaError::Config { .. }));
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn bad_value_type_reports_key() {
        let doc = Document::parse("[stream]\nworkers = \"three\"").unwrap();
        let e = PipelineConfig::from_document(&doc).unwrap_err();
        assert!(e.to_string().contains("workers"), "{e}");
    }

    #[test]
    fn validation_errors_are_config_variants() {
        let doc = Document::parse("[solver]\nengine = \"gpu\"").unwrap();
        let e = PipelineConfig::from_document(&doc).unwrap_err();
        assert!(matches!(e, crate::error::LsspcaError::Config { .. }), "{e}");
        assert_eq!(e.exit_code(), 2);
    }

    #[test]
    fn default_validates() {
        PipelineConfig::default().validate().unwrap();
    }

    #[test]
    fn unknown_section_warns_with_suggestion() {
        // the classic typo: [memry] instead of [memory]
        let doc = Document::parse("[memry]\nbudget_mb = 256").unwrap();
        let warnings = unknown_key_warnings(&doc);
        assert_eq!(warnings.len(), 1, "{warnings:?}");
        assert!(warnings[0].contains("memry"), "{warnings:?}");
        assert!(warnings[0].contains("did you mean '[memory]'"), "{warnings:?}");
        // the misspelled section must not silently apply: defaults hold
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.memory_budget_mb, 0);
    }

    #[test]
    fn unknown_key_warns_with_suggestion() {
        let doc = Document::parse("[solver]\ntarget_cards = 7\nnum_pcs = 2").unwrap();
        let warnings = unknown_key_warnings(&doc);
        assert_eq!(warnings.len(), 1, "{warnings:?}");
        assert!(warnings[0].contains("target_cards"), "{warnings:?}");
        assert!(warnings[0].contains("did you mean 'target_card'"), "{warnings:?}");
        // the known key in the same document still applies
        assert_eq!(PipelineConfig::from_document(&doc).unwrap().num_pcs, 2);
    }

    #[test]
    fn unrelated_unknown_key_warns_without_suggestion() {
        let doc = Document::parse("[solver]\ncompletely_unrelated_knob = 1").unwrap();
        let warnings = unknown_key_warnings(&doc);
        assert_eq!(warnings.len(), 1, "{warnings:?}");
        assert!(warnings[0].contains("unknown key"), "{warnings:?}");
        assert!(!warnings[0].contains("did you mean"), "{warnings:?}");
    }

    #[test]
    fn known_keys_produce_no_warnings() {
        let doc = Document::parse(
            "[corpus]\npreset = \"nytimes\"\n[memory]\nbudget_mb = 64\nshard_mb = 4",
        )
        .unwrap();
        assert!(unknown_key_warnings(&doc).is_empty());
        // a document exercising one key from every known section is quiet
        let full = Document::parse(
            "[corpus]\nseed = 1\n[stream]\nworkers = 2\n[solver]\nengine = \"native\"\n\
             [cov]\nbackend = \"dense\"\n[compute]\nkernels = \"auto\"\n\
             [memory]\nshard_mb = 8\n\
             [model]\ncenter = true\n[serve]\npool = 2",
        )
        .unwrap();
        assert!(unknown_key_warnings(&full).is_empty());
    }
}
