//! Typed pipeline configuration plus a TOML-subset parser (offline
//! substitute for `serde` + `toml`, see DESIGN.md §3).
//!
//! The subset covers what config files in this repo need: `[section]`
//! headers, `key = value` with string / integer / float / boolean values,
//! inline comments with `#`, and blank lines. Arrays of scalars are
//! supported with `[a, b, c]` syntax.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `[a, b, c]` array of scalars.
    Array(Vec<Value>),
}

impl Value {
    /// String view, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric view (floats and ints both coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Integer view, if this is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Non-negative integer view.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    /// Boolean view, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: `section.key -> value` (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Document {
    entries: BTreeMap<(String, String), Value>,
}

impl Document {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Document, String> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = inner.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.entries.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &Path) -> Result<Document, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Document::parse(&text)
    }

    /// Look up `[section] key` (top-level keys use section `""`).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    fn typed<T>(
        &self,
        section: &str,
        key: &str,
        default: T,
        conv: impl Fn(&Value) -> Option<T>,
    ) -> Result<T, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => {
                conv(v).ok_or_else(|| format!("[{section}] {key}: unexpected type ({v})"))
            }
        }
    }

    /// `f64` at `[section] key`, or `default` when absent.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64, String> {
        self.typed(section, key, default, |v| v.as_f64())
    }
    /// `usize` at `[section] key`, or `default` when absent.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize, String> {
        self.typed(section, key, default, |v| v.as_usize())
    }
    /// `u64` at `[section] key`, or `default` when absent.
    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> Result<u64, String> {
        self.typed(section, key, default, |v| v.as_i64().and_then(|i| u64::try_from(i).ok()))
    }
    /// `bool` at `[section] key`, or `default` when absent.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool, String> {
        self.typed(section, key, default, |v| v.as_bool())
    }
    /// `String` at `[section] key`, or `default` when absent.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String, String> {
        self.typed(section, key, default.to_string(), |v| v.as_str().map(|s| s.to_string()))
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// End-to-end pipeline configuration (see `coordinator::Pipeline`).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Path to a docword file (UCI bag-of-words format, optionally .gz);
    /// empty = generate a synthetic corpus instead.
    pub input: String,
    /// Synthetic corpus preset when `input` is empty: "nytimes" | "pubmed".
    pub synth_preset: String,
    /// Synthetic corpus document-count override (0 = preset default).
    pub synth_docs: usize,
    /// Synthetic corpus vocabulary-size override (0 = preset default).
    pub synth_vocab: usize,
    /// Corpus / generator seed.
    pub seed: u64,
    /// Directory for variance-pass checkpoints (empty = disabled). At
    /// PubMed scale the pass dominates wall time and is λ-independent, so
    /// re-runs reuse it (see `checkpoint`).
    pub cache_dir: String,
    /// Number of moment-pass worker threads.
    pub workers: usize,
    /// Worker threads for the solver-side parallel kernels (λ-search
    /// probes, path grids, Gram shards, deflation row blocks). 0 = use
    /// every available core; 1 = serial.
    pub threads: usize,
    /// Independent λ probes per bracketing round of the cardinality
    /// search. 1 = classic bisection (best per-eval bracketing; the
    /// serial default); raise toward `threads` to trade eval-efficiency
    /// for wall-clock parallelism. Part of the numerical schedule: fixed
    /// by config, never derived from the thread count, so results are
    /// machine-independent.
    pub lambda_probes: usize,
    /// Documents per streamed chunk.
    pub chunk_docs: usize,
    /// Bounded queue depth between reader and workers (backpressure).
    pub queue_depth: usize,
    /// Number of sparse PCs to extract.
    pub num_pcs: usize,
    /// Target cardinality per PC (paper: 5).
    pub target_card: usize,
    /// Accept solutions with cardinality within ±slack of target (paper
    /// accepts "close, but not necessarily equal").
    pub card_slack: usize,
    /// Hard cap on the reduced problem size n̂ after elimination.
    pub max_reduced: usize,
    /// Covariance backend (`[cov] backend`): "dense" materializes the
    /// reduced n̂ × n̂ matrix (solves bitwise the historical pipeline); "gram"
    /// keeps Σ implicit as a centered Gram operator over the reduced
    /// sparse term matrix — O(nnz) memory, so n̂ can reach tens of
    /// thousands; "disk" streams the reduced matrix from the on-disk
    /// shard cache under the `[memory] budget_mb` cap (bitwise-identical
    /// solves to "gram"); "auto" lets the memory-budget planner pick from
    /// the variance-pass footprint estimates.
    pub cov_backend: String,
    /// Resident-memory budget in MiB for the covariance stage
    /// (`[memory] budget_mb`; 0 = unlimited). Drives the `auto` backend
    /// decision and sizes the disk backend's Σ-row cache.
    pub memory_budget_mb: usize,
    /// Byte budget per on-disk shard, in MiB (`[memory] shard_mb`) — the
    /// streaming granularity of the disk backend.
    pub shard_mb: usize,
    /// Row-cache budget in MiB for the "gram" backend's lazily gathered
    /// Σ rows (solver.row_cache_mb; 0 disables caching).
    pub row_cache_mb: usize,
    /// BCA sweeps (paper: K typically 5).
    pub bca_sweeps: usize,
    /// ε for the barrier parameter β = ε/n.
    pub epsilon: f64,
    /// Solver engine: "native" | "xla".
    pub engine: String,
    /// Directory holding AOT artifacts (for engine = "xla").
    pub artifacts_dir: String,
    /// Deflation scheme: "projection" | "hotelling".
    pub deflation: String,
    /// Compute a dual optimality certificate per component (extra
    /// eigendecompositions; off by default).
    pub certify: bool,
    /// Path to write the trained model artifact to (`[model] save_path`;
    /// empty = don't save). `lsspca export --model-out` overrides.
    pub save_model: String,
    /// Scoring default: subtract training means (`[model] center`).
    pub score_center: bool,
    /// Scoring default: divide loadings by training standard deviations
    /// (`[model] normalize`).
    pub score_normalize: bool,
    /// Bind address for `lsspca serve` (`[serve] addr`).
    pub serve_addr: String,
    /// Connection-handler threads for `lsspca serve` (`[serve] pool`).
    pub serve_pool: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            input: String::new(),
            synth_preset: "nytimes".into(),
            synth_docs: 0,
            synth_vocab: 0,
            seed: 20111212,
            cache_dir: String::new(),
            workers: 2,
            threads: 1,
            lambda_probes: 1,
            chunk_docs: 2048,
            queue_depth: 4,
            num_pcs: 5,
            target_card: 5,
            card_slack: 2,
            max_reduced: 512,
            cov_backend: "dense".into(),
            memory_budget_mb: 0,
            shard_mb: 32,
            row_cache_mb: 64,
            bca_sweeps: 5,
            epsilon: 1e-3,
            engine: "native".into(),
            artifacts_dir: "artifacts".into(),
            deflation: "projection".into(),
            certify: false,
            save_model: String::new(),
            score_center: true,
            score_normalize: false,
            serve_addr: "127.0.0.1:7878".into(),
            serve_pool: 4,
        }
    }
}

impl PipelineConfig {
    /// Build from a parsed TOML-subset document (missing keys = defaults).
    pub fn from_document(doc: &Document) -> Result<PipelineConfig, String> {
        let d = PipelineConfig::default();
        let cfg = PipelineConfig {
            input: doc.str_or("corpus", "input", &d.input)?,
            synth_preset: doc.str_or("corpus", "preset", &d.synth_preset)?,
            synth_docs: doc.usize_or("corpus", "docs", d.synth_docs)?,
            synth_vocab: doc.usize_or("corpus", "vocab", d.synth_vocab)?,
            seed: doc.u64_or("corpus", "seed", d.seed)?,
            cache_dir: doc.str_or("corpus", "cache_dir", &d.cache_dir)?,
            workers: doc.usize_or("stream", "workers", d.workers)?,
            threads: doc.usize_or("solver", "threads", d.threads)?,
            lambda_probes: doc.usize_or("solver", "lambda_probes", d.lambda_probes)?,
            chunk_docs: doc.usize_or("stream", "chunk_docs", d.chunk_docs)?,
            queue_depth: doc.usize_or("stream", "queue_depth", d.queue_depth)?,
            num_pcs: doc.usize_or("solver", "num_pcs", d.num_pcs)?,
            target_card: doc.usize_or("solver", "target_card", d.target_card)?,
            card_slack: doc.usize_or("solver", "card_slack", d.card_slack)?,
            max_reduced: doc.usize_or("solver", "max_reduced", d.max_reduced)?,
            cov_backend: doc.str_or("cov", "backend", &d.cov_backend)?,
            memory_budget_mb: doc.usize_or("memory", "budget_mb", d.memory_budget_mb)?,
            shard_mb: doc.usize_or("memory", "shard_mb", d.shard_mb)?,
            row_cache_mb: doc.usize_or("solver", "row_cache_mb", d.row_cache_mb)?,
            bca_sweeps: doc.usize_or("solver", "bca_sweeps", d.bca_sweeps)?,
            epsilon: doc.f64_or("solver", "epsilon", d.epsilon)?,
            engine: doc.str_or("solver", "engine", &d.engine)?,
            artifacts_dir: doc.str_or("solver", "artifacts_dir", &d.artifacts_dir)?,
            deflation: doc.str_or("solver", "deflation", &d.deflation)?,
            certify: doc.bool_or("solver", "certify", d.certify)?,
            save_model: doc.str_or("model", "save_path", &d.save_model)?,
            score_center: doc.bool_or("model", "center", d.score_center)?,
            score_normalize: doc.bool_or("model", "normalize", d.score_normalize)?,
            serve_addr: doc.str_or("serve", "addr", &d.serve_addr)?,
            serve_pool: doc.usize_or("serve", "pool", d.serve_pool)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<PipelineConfig, String> {
        Self::from_document(&Document::load(path)?)
    }

    /// Sanity-check field values.
    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("stream.workers must be >= 1".into());
        }
        if self.chunk_docs == 0 {
            return Err("stream.chunk_docs must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("stream.queue_depth must be >= 1".into());
        }
        if self.num_pcs == 0 {
            return Err("solver.num_pcs must be >= 1".into());
        }
        if self.target_card == 0 {
            return Err("solver.target_card must be >= 1".into());
        }
        if self.lambda_probes == 0 {
            return Err("solver.lambda_probes must be >= 1".into());
        }
        if self.max_reduced < self.target_card {
            return Err("solver.max_reduced must be >= target_card".into());
        }
        if !(self.epsilon > 0.0) {
            return Err("solver.epsilon must be > 0".into());
        }
        match self.engine.as_str() {
            "native" | "xla" => {}
            other => return Err(format!("solver.engine '{other}' (want native|xla)")),
        }
        match self.cov_backend.as_str() {
            "dense" | "gram" | "disk" | "auto" => {}
            other => return Err(format!("cov.backend '{other}' (want dense|gram|disk|auto)")),
        }
        if self.shard_mb == 0 {
            return Err("memory.shard_mb must be >= 1".into());
        }
        if self.engine == "xla" && matches!(self.cov_backend.as_str(), "gram" | "disk") {
            // The XLA engine ships an explicit Σ to shape-static
            // artifacts; combined with an implicit backend it would
            // silently materialize the full n̂ × n̂ matrix once per
            // λ-probe — defeating the implicit backends' memory
            // contract at exactly the scales they exist for. ("auto"
            // is fine: the planner pins itself to dense under xla.)
            return Err(format!(
                "solver.engine = \"xla\" requires cov.backend = \"dense\" (the XLA \
                 artifacts need an explicit covariance matrix; \"{}\" would re-densify \
                 Σ per λ-probe)",
                self.cov_backend
            ));
        }
        match self.deflation.as_str() {
            "projection" | "hotelling" => {}
            other => return Err(format!("solver.deflation '{other}' (want projection|hotelling)")),
        }
        match self.synth_preset.as_str() {
            "nytimes" | "pubmed" => {}
            other => return Err(format!("corpus.preset '{other}' (want nytimes|pubmed)")),
        }
        if self.serve_pool == 0 {
            return Err("serve.pool must be >= 1".into());
        }
        if self.serve_addr.is_empty() {
            return Err("serve.addr must not be empty".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# pipeline config
[corpus]
preset = "pubmed"   # larger preset
docs = 10000
seed = 7

[stream]
workers = 3

[solver]
target_card = 5
epsilon = 0.01
engine = "native"
lambdas = [0.1, 0.2, 0.5]
"#;

    #[test]
    fn parse_sections_and_types() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("corpus", "preset"), Some(&Value::Str("pubmed".into())));
        assert_eq!(doc.get("corpus", "docs"), Some(&Value::Int(10000)));
        assert_eq!(doc.get("solver", "epsilon"), Some(&Value::Float(0.01)));
        match doc.get("solver", "lambdas") {
            Some(Value::Array(xs)) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comments_stripped_even_inline() {
        let doc = Document::parse("a = 1 # one\nb = \"x # not a comment\"").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&Value::Str("x # not a comment".into())));
    }

    #[test]
    fn config_from_document() {
        let doc = Document::parse(SAMPLE).unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.synth_preset, "pubmed");
        assert_eq!(cfg.synth_docs, 10000);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.epsilon, 0.01);
        // defaults fill in
        assert_eq!(cfg.num_pcs, 5);
    }

    #[test]
    fn validation_rejects_bad_engine() {
        let doc = Document::parse("[solver]\nengine = \"gpu\"").unwrap();
        assert!(PipelineConfig::from_document(&doc).is_err());
    }

    #[test]
    fn cov_backend_parses_and_validates() {
        let doc =
            Document::parse("[cov]\nbackend = \"gram\"\n[solver]\nrow_cache_mb = 16").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.cov_backend, "gram");
        assert_eq!(cfg.row_cache_mb, 16);
        // default backend is the bitwise-historical dense path
        assert_eq!(PipelineConfig::default().cov_backend, "dense");
        let bad = Document::parse("[cov]\nbackend = \"sparse\"").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
        // xla + gram would re-densify Σ per λ-probe; rejected up front
        let clash =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"gram\"").unwrap();
        let e = PipelineConfig::from_document(&clash).unwrap_err();
        assert!(e.contains("xla") && e.contains("gram"), "{e}");
    }

    #[test]
    fn memory_section_and_oocore_backends() {
        let doc = Document::parse(
            "[cov]\nbackend = \"auto\"\n[memory]\nbudget_mb = 256\nshard_mb = 8",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.cov_backend, "auto");
        assert_eq!(cfg.memory_budget_mb, 256);
        assert_eq!(cfg.shard_mb, 8);
        // defaults: unlimited budget, 32 MiB shards
        let d = PipelineConfig::default();
        assert_eq!(d.memory_budget_mb, 0);
        assert_eq!(d.shard_mb, 32);
        let disk = Document::parse("[cov]\nbackend = \"disk\"").unwrap();
        assert!(PipelineConfig::from_document(&disk).is_ok());
        let bad = Document::parse("[memory]\nshard_mb = 0").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
        // xla still incompatible with the implicit backends...
        let clash =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"disk\"").unwrap();
        assert!(PipelineConfig::from_document(&clash).is_err());
        // ...but auto is allowed (the planner pins itself to dense)
        let autoxla =
            Document::parse("[solver]\nengine = \"xla\"\n[cov]\nbackend = \"auto\"").unwrap();
        assert!(PipelineConfig::from_document(&autoxla).is_ok());
    }

    #[test]
    fn model_and_serve_sections_parse_and_validate() {
        let doc = Document::parse(
            "[model]\nsave_path = \"out/m.lspm\"\nnormalize = true\n\
             [serve]\naddr = \"0.0.0.0:9000\"\npool = 8",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.save_model, "out/m.lspm");
        assert!(cfg.score_normalize);
        assert!(cfg.score_center); // default stays on
        assert_eq!(cfg.serve_addr, "0.0.0.0:9000");
        assert_eq!(cfg.serve_pool, 8);
        let bad = Document::parse("[serve]\npool = 0").unwrap();
        assert!(PipelineConfig::from_document(&bad).is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let e = Document::parse("ok = 1\nnot a kv line").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn bad_value_type_reports_key() {
        let doc = Document::parse("[stream]\nworkers = \"three\"").unwrap();
        let e = PipelineConfig::from_document(&doc).unwrap_err();
        assert!(e.contains("workers"), "{e}");
    }

    #[test]
    fn default_validates() {
        PipelineConfig::default().validate().unwrap();
    }
}
