//! The production serving layer: an event-driven HTTP/1.1 scoring
//! server with keep-alive and pipelining, a named multi-model registry,
//! checksum-validated hot reload, load shedding, and Prometheus
//! `/metrics` — all on `std::net`, zero dependencies.
//!
//! # Architecture
//!
//! ```text
//!  acceptor thread          bounded queue            event-loop workers
//!  ───────────────          (queue_depth)            (serve.pool)
//!  accept() ──try_send──► [ sock | sock | … ] ──try_recv──► worker 0: tick conns
//!     │                                                      worker 1: tick conns
//!     └─ queue full or max_conns reached:                    …
//!        write 503 + Retry-After: 1, close
//!
//!  reload watcher (one thread, reload_poll_ms)
//!  stat artifacts ─changed?→ read + checksum-validate ─ok?→ Registry::swap
//!                                                       └err?→ keep old model
//! ```
//!
//! Each worker multiplexes many non-blocking connections through the
//! [`conn`] state machine (read → parse pipelined requests → route →
//! write), so slow clients cost a buffer, not a thread. Models live in
//! the [`registry`] behind `RwLock<Arc<_>>` slots: handlers snapshot an
//! `Arc`, the [`reload`] watcher swaps slots atomically, and in-flight
//! requests always finish on the model they started with.
//!
//! # API
//!
//! Configure with [`ServerBuilder`] (the typed path, mirroring
//! `SessionBuilder`):
//!
//! ```no_run
//! use lsspca::serve::ServerBuilder;
//! # fn f(model: lsspca::model::Model) -> Result<(), lsspca::error::LsspcaError> {
//! ServerBuilder::new()
//!     .addr("127.0.0.1:7878")
//!     .register("nytimes", "runs/nytimes.lspm") // hot-reloaded on rewrite
//!     .register_model("inline", model)          // in-memory, never reloaded
//!     .workers(4)
//!     .build()?
//!     .run()
//! # }
//! ```
//!
//! The HTTP surface is versioned under `/v1` ([`conn::V1_ROUTES`]); the
//! pre-registry routes (`/score`, `/topics`, `/healthz`) remain as
//! deprecated shims onto the default model with byte-identical bodies.
//! [`ServeOptions`] and [`serve`] are the equally deprecated library
//! mirror of those shims. Failures are [`LsspcaError::Serve`] (CLI exit
//! code 7).

pub(crate) mod conn;
pub mod http;
pub(crate) mod listener;
pub mod metrics;
pub mod registry;
pub mod reload;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::PipelineConfig;
use crate::error::LsspcaError;
use crate::model::Model;
use crate::score::scorer::{ScoreOptions, Scorer};
use crate::serve::metrics::Metrics;
use crate::serve::registry::{Registry, ServingModel};

/// Everything the acceptor, workers, and watcher share (one `Arc`).
pub(crate) struct Shared {
    /// The model registry (slots swap under it on reload).
    pub registry: Registry,
    /// Process-wide serving counters.
    pub metrics: Metrics,
    /// Request-body cap in bytes (413 beyond).
    pub max_body: usize,
    /// Idle/stuck connection timeout (zero = none).
    pub timeout: Duration,
    /// Raised by [`ServerHandle::shutdown`].
    pub shutdown: AtomicBool,
    /// Bound address (shutdown wake-up connects here).
    pub addr: SocketAddr,
}

#[cfg(test)]
impl Shared {
    /// A `Shared` for route-level unit tests (no sockets involved).
    pub(crate) fn for_tests(registry: Registry) -> Shared {
        Shared {
            registry,
            metrics: Metrics::default(),
            max_body: 1 << 20,
            timeout: Duration::from_secs(10),
            shutdown: AtomicBool::new(false),
            addr: "127.0.0.1:0".parse().unwrap(),
        }
    }
}

/// How one registered name obtains its model at [`ServerBuilder::build`].
enum RowSource {
    /// In-memory model, compiled with the builder's score options.
    Memory(Model),
    /// Artifact path: loaded at build, watched for hot reload.
    Path(PathBuf),
    /// Pre-compiled (the deprecated `Server::bind` hands a scorer in).
    Compiled(Box<ServingModel>, ScoreOptions),
}

/// Typed, chainable server configuration — the serving counterpart of
/// [`crate::session::SessionBuilder`]. Every knob has the `[serve]`
/// config default; [`ServerBuilder::build`] validates, loads and
/// compiles every registered model, and binds the listener.
pub struct ServerBuilder {
    addr: String,
    workers: usize,
    queue_depth: usize,
    max_conns: usize,
    max_body_bytes: usize,
    timeout_secs: u64,
    reload_poll_ms: u64,
    score_opts: ScoreOptions,
    default_model: Option<String>,
    rows: Vec<(String, RowSource)>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    /// Start from the `[serve]` defaults (no models registered yet).
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            addr: "127.0.0.1:7878".into(),
            workers: 4,
            queue_depth: 64,
            max_conns: 1024,
            max_body_bytes: 1 << 20,
            timeout_secs: 10,
            reload_poll_ms: 1000,
            score_opts: ScoreOptions::default(),
            default_model: None,
            rows: Vec::new(),
        }
    }

    /// Seed every shared knob from a parsed `[serve]` config section,
    /// including its `models = ["name=path", ...]` registry rows.
    pub fn from_config(cfg: &PipelineConfig) -> Result<ServerBuilder, LsspcaError> {
        let mut b = ServerBuilder::new()
            .addr(cfg.serve_addr.clone())
            .workers(cfg.serve_pool)
            .queue_depth(cfg.serve_queue_depth)
            .max_conns(cfg.serve_max_conns)
            .timeout_secs(cfg.serve_timeout_secs)
            .reload_poll_ms(cfg.serve_reload_poll_ms);
        for entry in &cfg.serve_models {
            let Some((name, path)) = entry.split_once('=') else {
                return Err(LsspcaError::config(format!(
                    "[serve] models entry '{entry}' must be 'name=path'"
                )));
            };
            b = b.register(name, path);
        }
        Ok(b)
    }

    /// Seed from the deprecated [`ServeOptions`] (migration path: the
    /// old option-struct knobs map onto the builder; then chain
    /// registrations and the new knobs).
    #[allow(deprecated)]
    pub fn from_options(opts: ServeOptions) -> ServerBuilder {
        ServerBuilder::new()
            .addr(opts.addr)
            .workers(opts.pool)
            .max_body_bytes(opts.max_body_bytes)
            .timeout_secs(opts.timeout_secs)
    }

    /// Bind address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub fn addr(mut self, addr: impl Into<String>) -> ServerBuilder {
        self.addr = addr.into();
        self
    }

    /// Event-loop worker threads (`[serve] pool`, ≥ 1).
    pub fn workers(mut self, n: usize) -> ServerBuilder {
        self.workers = n;
        self
    }

    /// Accept-queue capacity; a full queue sheds with 503
    /// (`[serve] queue_depth`).
    pub fn queue_depth(mut self, n: usize) -> ServerBuilder {
        self.queue_depth = n;
        self
    }

    /// Open-connection cap across all workers; beyond it new
    /// connections shed with 503 (`[serve] max_conns`).
    pub fn max_conns(mut self, n: usize) -> ServerBuilder {
        self.max_conns = n;
        self
    }

    /// Request-body cap in bytes (413 beyond).
    pub fn max_body_bytes(mut self, n: usize) -> ServerBuilder {
        self.max_body_bytes = n;
        self
    }

    /// Idle/stuck connection timeout in seconds, 0 = none
    /// (`[serve] timeout_secs`).
    pub fn timeout_secs(mut self, secs: u64) -> ServerBuilder {
        self.timeout_secs = secs;
        self
    }

    /// Artifact-watch poll interval in milliseconds, 0 = hot reload off
    /// (`[serve] reload_poll_ms`).
    pub fn reload_poll_ms(mut self, ms: u64) -> ServerBuilder {
        self.reload_poll_ms = ms;
        self
    }

    /// Scoring options applied when compiling registered models (and
    /// re-applied on every hot reload).
    pub fn score_options(mut self, opts: ScoreOptions) -> ServerBuilder {
        self.score_opts = opts;
        self
    }

    /// Which registered name the legacy shims and `/v1/healthz` use
    /// (default: the first registration).
    pub fn default_model(mut self, name: impl Into<String>) -> ServerBuilder {
        self.default_model = Some(name.into());
        self
    }

    /// Register a path-backed model: loaded (and checksum-validated) at
    /// build, then watched for hot reload.
    pub fn register(
        mut self,
        name: impl Into<String>,
        path: impl Into<PathBuf>,
    ) -> ServerBuilder {
        self.rows.push((name.into(), RowSource::Path(path.into())));
        self
    }

    /// Register an in-memory model (never hot-reloaded).
    pub fn register_model(mut self, name: impl Into<String>, model: Model) -> ServerBuilder {
        self.rows.push((name.into(), RowSource::Memory(model)));
        self
    }

    /// Register an in-memory model under the name `default` — the
    /// one-model convenience the old `serve(model, scorer, opts)` had.
    pub fn model(self, model: Model) -> ServerBuilder {
        self.register_model("default", model)
    }

    fn register_compiled(
        mut self,
        name: impl Into<String>,
        sm: ServingModel,
        opts: ScoreOptions,
    ) -> ServerBuilder {
        self.rows.push((name.into(), RowSource::Compiled(Box::new(sm), opts)));
        self
    }

    /// Validate, load + compile every registered model, and bind the
    /// listener. Knob and registry failures are [`LsspcaError::Serve`];
    /// artifact-load failures keep their I/O class.
    pub fn build(self) -> Result<Server, LsspcaError> {
        if self.workers == 0 {
            return Err(LsspcaError::serve("serve.pool must be >= 1"));
        }
        if self.queue_depth == 0 {
            return Err(LsspcaError::serve("serve.queue_depth must be >= 1"));
        }
        if self.max_conns == 0 {
            return Err(LsspcaError::serve("serve.max_conns must be >= 1"));
        }
        let mut rows = Vec::with_capacity(self.rows.len());
        for (name, source) in self.rows {
            let (path, sm, opts) = match source {
                RowSource::Memory(m) => {
                    (None, ServingModel::compile(m, self.score_opts)?, self.score_opts)
                }
                RowSource::Path(p) => {
                    let m = Model::load(&p)?;
                    (Some(p), ServingModel::compile(m, self.score_opts)?, self.score_opts)
                }
                RowSource::Compiled(sm, opts) => (None, *sm, opts),
            };
            rows.push((name, path, sm, opts));
        }
        let registry = Registry::new(rows, self.default_model.as_deref())?;
        let listener = TcpListener::bind(&self.addr)
            .map_err(|e| LsspcaError::serve(format!("bind {}: {e}", self.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| LsspcaError::serve(format!("local_addr: {e}")))?;
        let shared = Arc::new(Shared {
            registry,
            metrics: Metrics::default(),
            max_body: self.max_body_bytes,
            timeout: Duration::from_secs(self.timeout_secs),
            shutdown: AtomicBool::new(false),
            addr,
        });
        Ok(Server {
            listener,
            shared,
            workers: self.workers,
            queue_depth: self.queue_depth,
            max_conns: self.max_conns,
            reload_poll_ms: self.reload_poll_ms,
        })
    }
}

/// A bound (not yet running) server, produced by
/// [`ServerBuilder::build`].
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    workers: usize,
    queue_depth: usize,
    max_conns: usize,
    reload_poll_ms: u64,
}

impl Server {
    /// Bind a single in-memory model with a pre-built scorer — the old
    /// entrypoint, kept working verbatim.
    #[deprecated(note = "use `ServerBuilder` (see `serve` module docs)")]
    #[allow(deprecated)]
    pub fn bind(model: Model, scorer: Scorer, opts: ServeOptions) -> Result<Server, LsspcaError> {
        let digest = crate::util::xor_fold_checksum(&model.to_bytes());
        let score_opts = scorer.options();
        let sm = ServingModel::from_parts(model, scorer, digest);
        ServerBuilder::from_options(opts).register_compiled("default", sm, score_opts).build()
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// A cloneable shutdown handle.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Serve until [`ServerHandle::shutdown`]. Blocks the calling
    /// thread (it becomes the acceptor); spawns the event-loop workers
    /// and, when any registered model is path-backed and
    /// `reload_poll_ms > 0`, the hot-reload watcher.
    pub fn run(self) -> Result<(), LsspcaError> {
        let Server { listener, shared, workers, queue_depth, max_conns, reload_poll_ms } = self;
        crate::info!(
            "serving {} model(s) [{}] on http://{} with {workers} workers (default '{}')",
            shared.registry.slots().len(),
            shared.registry.names().join(", "),
            shared.addr,
            shared.registry.default_slot().name,
        );
        let watch = reload_poll_ms > 0 && shared.registry.slots().iter().any(|s| s.path.is_some());
        let watcher = if watch {
            let sh = Arc::clone(&shared);
            Some(
                std::thread::Builder::new()
                    .name("lsspca-reload".into())
                    .spawn(move || {
                        reload::watch_loop(
                            &sh.registry,
                            &sh.metrics,
                            &sh.shutdown,
                            Duration::from_millis(reload_poll_ms),
                        );
                    })
                    .expect("spawn reload watcher"),
            )
        } else {
            None
        };
        listener::run(listener, &shared, workers, queue_depth, max_conns);
        // listener::run returns only on shutdown, but make it explicit
        // for the watcher before joining it.
        shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(w) = watcher {
            let _ = w.join();
        }
        Ok(())
    }
}

/// Cloneable handle to stop a running server (tests, signal handlers;
/// `shutdown` is idempotent).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Request shutdown and unblock the acceptor with a dummy
    /// connection.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept(); a failed connect is fine (the
        // listener may already be gone).
        let _ = TcpStream::connect(self.shared.addr);
    }
}

// ---------------------------------------------------------------------------
// Deprecated pre-registry surface
// ---------------------------------------------------------------------------

/// Flat server configuration for the old one-model API.
#[deprecated(note = "use `ServerBuilder` (seed it with `ServerBuilder::from_options`)")]
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads (now event-loop workers, not one per connection).
    pub pool: usize,
    /// Maximum accepted request-body size in bytes.
    pub max_body_bytes: usize,
    /// Connection idle timeout in seconds (0 = none).
    pub timeout_secs: u64,
}

#[allow(deprecated)]
impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".into(),
            pool: 4,
            max_body_bytes: 1 << 20,
            timeout_secs: 10,
        }
    }
}

/// Bind and run a single-model server in one call — the old `lsspca
/// serve` entrypoint, kept working verbatim.
#[deprecated(note = "use `ServerBuilder` (see `serve` module docs)")]
#[allow(deprecated)]
pub fn serve(model: Model, scorer: Scorer, opts: ServeOptions) -> Result<(), LsspcaError> {
    Server::bind(model, scorer, opts)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::tests::test_model;

    #[test]
    fn builder_validates_knobs_and_registry() {
        let m = || test_model("m");
        let err = |b: ServerBuilder| b.build().unwrap_err().to_string();
        assert!(err(ServerBuilder::new().model(m()).workers(0)).contains("pool"));
        assert!(err(ServerBuilder::new().model(m()).queue_depth(0)).contains("queue_depth"));
        assert!(err(ServerBuilder::new().model(m()).max_conns(0)).contains("max_conns"));
        assert!(err(ServerBuilder::new()).contains("at least one model"));
        assert!(err(ServerBuilder::new().model(m()).default_model("nosuch"))
            .contains("not registered"));
        assert!(matches!(
            ServerBuilder::new().model(m()).workers(0).build(),
            Err(LsspcaError::Serve { .. })
        ));
    }

    #[test]
    fn builder_binds_ephemeral_port_and_registers_models() {
        let srv = ServerBuilder::new()
            .addr("127.0.0.1:0")
            .register_model("a", test_model("corpus-a"))
            .register_model("b", test_model("corpus-b"))
            .default_model("b")
            .build()
            .unwrap();
        assert_ne!(srv.local_addr().port(), 0);
        assert_eq!(srv.shared.registry.names(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(srv.shared.registry.default_slot().name, "b");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_options_seed_the_builder() {
        let opts = ServeOptions { pool: 7, timeout_secs: 3, ..Default::default() };
        let b = ServerBuilder::from_options(opts);
        assert_eq!(b.workers, 7);
        assert_eq!(b.timeout_secs, 3);
        assert_eq!(b.queue_depth, ServerBuilder::new().queue_depth); // new knobs keep defaults
    }
}
