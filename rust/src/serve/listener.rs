//! Accept loop and event-loop workers.
//!
//! One blocking acceptor thread (the caller of [`run`]) feeds accepted
//! sockets through a bounded [`crate::stream`] channel to `workers`
//! event-loop threads. Each worker owns a set of non-blocking
//! [`Conn`] state machines and multiplexes them with [`Conn::tick`]:
//! drain newly queued sockets with `try_recv`, tick every connection,
//! park briefly only when nothing moved. A slow or idle client costs a
//! buffer in one worker's set — never a blocked thread.
//!
//! Load shedding happens at the accept boundary, before any request
//! bytes are read, on two conditions:
//!
//! 1. the accept queue is full ([`crate::stream::BoundedSender::try_send`]
//!    returns `Full` — every worker is busy and the backlog is at
//!    `queue_depth`), or
//! 2. `max_conns` connections are already open (counted across queued
//!    and live connections).
//!
//! Either way the acceptor writes `503` + `Retry-After: 1` and closes —
//! the same contract the old thread-pool server had, now also visible
//! as `lsspca_sheds_total` in `/metrics`.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::serve::conn::{Conn, Tick};
use crate::serve::http::Response;
use crate::serve::Shared;
use crate::stream::{self, BoundedReceiver, TryRecvError, TrySendError};
use crate::util::json::{obj, Json};

/// How long a worker with no connections parks on the accept queue
/// before re-checking shutdown.
const PARK: Duration = Duration::from_millis(50);
/// How long a worker with idle connections sleeps between tick sweeps.
const IDLE_SPIN: Duration = Duration::from_micros(500);

/// Serve until `shared.shutdown` is raised. Runs the accept loop on the
/// calling thread and spawns `workers` event-loop threads; returns after
/// every worker has exited.
pub fn run(
    listener: TcpListener,
    shared: &Arc<Shared>,
    workers: usize,
    queue_depth: usize,
    max_conns: usize,
) {
    let workers = workers.max(1);
    let (tx, rx) = stream::bounded::<TcpStream>(queue_depth.max(1));
    let handles: Vec<_> = (0..workers)
        .map(|i| {
            let rx = rx.clone();
            let shared = Arc::clone(shared);
            std::thread::Builder::new()
                .name(format!("lsspca-serve-{i}"))
                .spawn(move || worker_loop(&rx, &shared))
                .expect("spawn serve worker")
        })
        .collect();
    drop(rx); // workers hold the only receivers

    while !shared.shutdown.load(Ordering::SeqCst) {
        let sock = match listener.accept() {
            Ok((sock, _)) => sock,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                crate::warn_!("serve: accept: {e}");
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // the shutdown wake-up connection itself
        }
        shared.metrics.connections_accepted.fetch_add(1, Ordering::Relaxed);
        // `connections_active` counts queued + live; it is the admission
        // gauge for the max_conns cap.
        let open = shared.metrics.connections_active.fetch_add(1, Ordering::Relaxed);
        if open as usize >= max_conns {
            shed(sock, &shared.metrics);
            shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
            continue;
        }
        match tx.try_send(sock) {
            Ok(()) => {
                shared.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(sock)) | Err(TrySendError::Closed(sock)) => {
                shed(sock, &shared.metrics);
                shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    tx.close(); // workers drain the queue, then observe Closed and exit
    for h in handles {
        let _ = h.join();
    }
}

/// Write the shed response (503 + `Retry-After: 1`) and drop the socket.
/// Body wording matches the old server byte-for-byte.
fn shed(mut sock: TcpStream, metrics: &crate::serve::metrics::Metrics) {
    metrics.sheds.fetch_add(1, Ordering::Relaxed);
    metrics.count_response(503);
    let body =
        obj(vec![("error", Json::Str("server overloaded; retry shortly".into()))]).to_string();
    let mut out = Vec::new();
    Response::json(503, body)
        .with_header("Retry-After", "1".to_string())
        .render(false, &mut out);
    let _ = sock.write_all(&out);
    let _ = sock.shutdown(std::net::Shutdown::Write);
}

/// One event-loop worker: adopt queued sockets, tick every live
/// connection, park only when there is nothing to do.
fn worker_loop(rx: &BoundedReceiver<TcpStream>, shared: &Shared) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut queue_closed = false;
    loop {
        // Adopt everything already queued without blocking.
        loop {
            match rx.try_recv() {
                Ok(sock) => {
                    shared.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    match Conn::adopt(sock) {
                        Ok(c) => conns.push(c),
                        Err(_) => {
                            shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Closed) => {
                    queue_closed = true;
                    break;
                }
            }
        }

        if shared.shutdown.load(Ordering::SeqCst) || (queue_closed && conns.is_empty()) {
            // Shutdown: flushed responses are already on the wire; drop
            // the rest. (Ticks are synchronous, so no request is ever
            // abandoned mid-handler.)
            for _ in &conns {
                shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
            }
            return;
        }

        if conns.is_empty() {
            // Nothing to tick: park on the queue instead of spinning.
            match rx.recv_timeout(PARK) {
                Ok(sock) => {
                    shared.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    match Conn::adopt(sock) {
                        Ok(c) => conns.push(c),
                        Err(_) => {
                            shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Closed) => queue_closed = true,
            }
            continue;
        }

        // Tick sweep over every connection this worker owns.
        let mut progressed = false;
        conns.retain_mut(|c| match c.tick(shared) {
            Tick::Progress => {
                progressed = true;
                true
            }
            Tick::Idle => true,
            Tick::Close => {
                shared.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
                false
            }
        });
        if !progressed {
            // All sockets would block: yield briefly rather than burn a
            // core. New sockets are picked up at the top of the loop.
            std::thread::sleep(IDLE_SPIN);
        }
    }
}
