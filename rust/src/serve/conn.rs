//! Per-connection state machine and the route table — the replacement
//! for the old thread-per-socket handler.
//!
//! A [`Conn`] owns one non-blocking socket plus an input and an output
//! buffer. Each [`Conn::tick`] from the event loop drives the machine:
//!
//! ```text
//!        ┌────────────────────────────────────────────────┐
//!        ▼                                                │
//!   READ bytes ──► PARSE next request ──► ROUTE ──► WRITE response
//!   (until         (incremental; loops     │        (until WouldBlock;
//!    WouldBlock)    over pipelined         │         keep-alive → back
//!                   requests)              │         to READ)
//!                                          ▼
//!                             parse error / Connection: close
//!                                → flush, drain, then CLOSE
//! ```
//!
//! Reads, parses, and writes all happen on whichever event-loop worker
//! owns the connection; a slow client costs a buffer, not a thread. The
//! route table serves both API generations: `/v1/...` routes and the
//! legacy `/score` / `/topics` / `/healthz` shims, which render through
//! the same [`crate::serve::registry`] JSON views (bitwise-identical
//! bodies) and add a `Deprecation` header.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::serve::http::{self, Request, Response};
use crate::serve::Shared;
use crate::util::json::{obj, Json};

/// The v1 route table — returned verbatim in the structured 404 for
/// unknown `/v1/...` paths (and cross-checked against the router by the
/// Python mirror suite).
pub const V1_ROUTES: [&str; 5] = [
    "GET /v1/models",
    "GET /v1/models/{name}/topics",
    "POST /v1/models/{name}/score",
    "GET /v1/healthz",
    "GET /v1/metrics",
];

/// What one [`Conn::tick`] accomplished, driving the event loop's
/// park-or-spin decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tick {
    /// Bytes moved or a request was served.
    Progress,
    /// Nothing to do right now (socket would block).
    Idle,
    /// Connection finished (flushed + close, EOF, timeout, or error) —
    /// the worker drops it.
    Close,
}

/// How long a closing connection lingers after its final response is
/// flushed, draining (and discarding) whatever the client is still
/// sending. Dropping a socket with unread bytes in the receive buffer
/// makes the kernel send RST, which can destroy the response still in
/// flight to the client — exactly the 4xx the client most needs to see.
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// One live connection owned by an event-loop worker.
pub struct Conn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    last_active: Instant,
    /// A response demanded close (client asked, or framing is unknown
    /// after a parse error): stop parsing, flush, half-close, drain
    /// briefly, then close.
    close_after_flush: bool,
    /// Deadline of the lingering-close drain, set when the final
    /// response has been flushed and the write side shut down.
    drain_until: Option<Instant>,
    eof: bool,
}

impl Conn {
    /// Take ownership of an accepted socket: non-blocking (the event
    /// loop must never park inside a syscall on one connection) and
    /// Nagle off (responses are single small writes; delaying them only
    /// adds p99).
    pub fn adopt(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        Ok(Conn {
            stream,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            last_active: Instant::now(),
            close_after_flush: false,
            drain_until: None,
            eof: false,
        })
    }

    /// Drive the machine one step: read what's available, serve every
    /// complete pipelined request, write what the socket will take.
    pub fn tick(&mut self, shared: &Shared) -> Tick {
        let mut progressed = false;

        // READ — drain the socket into the input buffer. A closing
        // connection keeps reading but discards the bytes (see
        // [`DRAIN_GRACE`]).
        if !self.eof {
            let mut tmp = [0u8; 4096];
            loop {
                match self.stream.read(&mut tmp) {
                    Ok(0) => {
                        self.eof = true;
                        break;
                    }
                    Ok(n) => {
                        if !self.close_after_flush {
                            self.inbuf.extend_from_slice(&tmp[..n]);
                        }
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return Tick::Close,
                }
            }
        }

        // PARSE + ROUTE — loop over every complete request already
        // buffered (HTTP/1.1 pipelining: responses go out in order).
        while !self.close_after_flush {
            match http::next_request(&mut self.inbuf, shared.max_body) {
                Ok(Some(req)) => {
                    progressed = true;
                    let t0 = Instant::now();
                    let resp = route(&req, shared);
                    shared.metrics.count_response(resp.status);
                    let keep_alive = !req.close;
                    resp.render(keep_alive, &mut self.outbuf);
                    shared.metrics.request_seconds.observe(t0.elapsed());
                    if req.close {
                        self.close_after_flush = true;
                    }
                }
                Ok(None) => break, // valid prefix; need more bytes
                Err(e) => {
                    // Framing is unknown past a malformed head: answer
                    // and close.
                    progressed = true;
                    let body = obj(vec![("error", Json::Str(e.message))]).to_string();
                    shared.metrics.count_response(e.status);
                    Response::json(e.status, body).render(false, &mut self.outbuf);
                    self.close_after_flush = true;
                }
            }
        }

        // WRITE — push the output buffer until the socket would block.
        while !self.outbuf.is_empty() {
            match self.stream.write(&self.outbuf) {
                Ok(0) => return Tick::Close,
                Ok(n) => {
                    self.outbuf.drain(..n);
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Tick::Close,
            }
        }

        if progressed {
            self.last_active = Instant::now();
        }
        let flushed = self.outbuf.is_empty();
        if flushed && self.eof {
            return Tick::Close;
        }
        if flushed && self.close_after_flush {
            // Lingering close: half-close (FIN) so the client sees end-
            // of-response, then keep draining until it closes its side
            // or the grace period runs out.
            match self.drain_until {
                None => {
                    let _ = self.stream.shutdown(std::net::Shutdown::Write);
                    self.drain_until = Some(Instant::now() + DRAIN_GRACE);
                }
                Some(t) if Instant::now() >= t => return Tick::Close,
                Some(_) => {}
            }
        }
        // Idle keep-alive / stuck-client timeout (0 = none).
        if !shared.timeout.is_zero() && self.last_active.elapsed() > shared.timeout {
            return Tick::Close;
        }
        if progressed {
            Tick::Progress
        } else {
            Tick::Idle
        }
    }
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

fn json_resp(code: u16, v: Json) -> Response {
    Response::json(code, v.to_string())
}

fn method_not_allowed(allow: &'static str) -> Response {
    json_resp(
        405,
        obj(vec![("error", Json::Str(format!("method not allowed; use {allow}")))]),
    )
    .with_header("Allow", allow)
}

/// Structured 404 for unknown `/v1/...` paths: the error plus the full
/// route table, so a typo'd client sees what exists.
fn unknown_v1(path: &str) -> Response {
    let routes: Vec<Json> = V1_ROUTES.iter().map(|r| Json::Str(r.to_string())).collect();
    json_resp(
        404,
        obj(vec![
            ("error", Json::Str(format!("no route for {path}"))),
            ("routes", Json::Arr(routes)),
        ]),
    )
}

fn unknown_model(name: &str, shared: &Shared) -> Response {
    let models: Vec<Json> =
        shared.registry.names().into_iter().map(Json::Str).collect();
    json_resp(
        404,
        obj(vec![
            ("error", Json::Str(format!("no model named '{name}'"))),
            ("models", Json::Arr(models)),
        ]),
    )
}

/// Mark a legacy-shim response: `Deprecation` plus a pointer at the v1
/// successor route. Headers only — the body stays bitwise-identical to
/// the v1 route's.
fn deprecated(resp: Response, successor: &str) -> Response {
    resp.with_header("Deprecation", "true".to_string())
        .with_header("Link", format!("<{successor}>; rel=\"successor-version\""))
}

fn metrics_resp(shared: &Shared) -> Response {
    Response::text(200, shared.metrics.render(&shared.registry.model_stats()))
}

fn score_resp(slot: &crate::serve::registry::Slot, body: &[u8]) -> Response {
    let sm = slot.current();
    slot.requests.fetch_add(1, Ordering::Relaxed);
    let (code, v) = crate::serve::registry::score_json(&sm, body);
    json_resp(code, v)
}

/// The route table. Every 405 carries `Allow`; unknown `/v1` paths get
/// the structured 404; legacy shims hit the default model and add
/// `Deprecation`.
pub fn route(req: &Request, shared: &Shared) -> Response {
    use crate::serve::registry::{healthz_json, models_json, topics_json};
    let method = req.method.as_str();
    let path = req.path.as_str();
    match (method, path) {
        // --- v1 API ---------------------------------------------------
        ("GET", "/v1/healthz") => {
            json_resp(200, healthz_json(&shared.registry.default_slot().current().model))
        }
        ("GET", "/v1/models") => json_resp(200, models_json(&shared.registry)),
        ("GET", "/v1/metrics") | ("GET", "/metrics") => metrics_resp(shared),
        (_, "/v1/healthz") | (_, "/v1/models") | (_, "/v1/metrics") | (_, "/metrics") => {
            method_not_allowed("GET")
        }
        _ if path.starts_with("/v1/models/") => {
            let rest = &path["/v1/models/".len()..];
            match rest.split_once('/') {
                Some((name, "topics")) => match (method, shared.registry.get(name)) {
                    ("GET", Some(slot)) => json_resp(200, topics_json(&slot.current().model)),
                    ("GET", None) => unknown_model(name, shared),
                    _ => method_not_allowed("GET"),
                },
                Some((name, "score")) => match (method, shared.registry.get(name)) {
                    ("POST", Some(slot)) => score_resp(slot, &req.body),
                    ("POST", None) => unknown_model(name, shared),
                    _ => method_not_allowed("POST"),
                },
                _ => unknown_v1(path),
            }
        }
        _ if path.starts_with("/v1/") || path == "/v1" => unknown_v1(path),
        // --- legacy shims (default model + Deprecation header) --------
        ("GET", "/healthz") => deprecated(
            json_resp(200, healthz_json(&shared.registry.default_slot().current().model)),
            "/v1/healthz",
        ),
        ("GET", "/topics") => {
            let slot = shared.registry.default_slot();
            let successor = format!("/v1/models/{}/topics", slot.name);
            deprecated(json_resp(200, topics_json(&slot.current().model)), &successor)
        }
        ("POST", "/score") => {
            let slot = shared.registry.default_slot();
            let successor = format!("/v1/models/{}/score", slot.name);
            deprecated(score_resp(slot, &req.body), &successor)
        }
        // the old server answered `GET /score` 405 with no Allow header;
        // every 405 now says what would have worked
        (_, "/score") => method_not_allowed("POST"),
        (_, "/healthz") | (_, "/topics") => method_not_allowed("GET"),
        _ => json_resp(404, obj(vec![("error", Json::Str(format!("no route for {path}")))])),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::tests::test_registry;
    use crate::serve::Shared;

    fn shared() -> Shared {
        Shared::for_tests(test_registry())
    }

    fn call(shared: &Shared, method: &str, path: &str, body: &str) -> Response {
        route(
            &Request {
                method: method.into(),
                path: path.into(),
                body: body.as_bytes().to_vec(),
                close: false,
            },
            shared,
        )
    }

    fn header<'a>(resp: &'a Response, name: &str) -> Option<&'a str> {
        resp.extra.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    #[test]
    fn v1_and_legacy_bodies_are_bitwise_identical() {
        let sh = shared();
        let doc = r#"{"words": [[3, 2], [15, 1]], "top": 2}"#;
        for (legacy, v1, method, body) in [
            ("/healthz", "/v1/healthz", "GET", ""),
            ("/topics", "/v1/models/default/topics", "GET", ""),
            ("/score", "/v1/models/default/score", "POST", doc),
        ] {
            let l = call(&sh, method, legacy, body);
            let v = call(&sh, method, v1, body);
            assert_eq!(l.status, 200, "{legacy}");
            assert_eq!(v.status, 200, "{v1}");
            assert_eq!(l.body, v.body, "{legacy} vs {v1} must be byte-identical");
            assert_eq!(header(&l, "Deprecation"), Some("true"), "{legacy}");
            assert!(header(&l, "Link").unwrap().contains(v1), "{legacy} Link → {v1}");
            assert_eq!(header(&v, "Deprecation"), None, "{v1} is not deprecated");
        }
    }

    #[test]
    fn every_405_names_the_allowed_method() {
        let sh = shared();
        for (method, path, want_allow) in [
            ("GET", "/score", "POST"), // the old server's missing-Allow bug
            ("DELETE", "/score", "POST"),
            ("POST", "/topics", "GET"),
            ("POST", "/healthz", "GET"),
            ("POST", "/v1/models", "GET"),
            ("POST", "/v1/metrics", "GET"),
            ("POST", "/metrics", "GET"),
            ("POST", "/v1/models/default/topics", "GET"),
            ("GET", "/v1/models/default/score", "POST"),
        ] {
            let r = call(&sh, method, path, "");
            assert_eq!(r.status, 405, "{method} {path}");
            assert_eq!(header(&r, "Allow"), Some(want_allow), "{method} {path}");
        }
    }

    #[test]
    fn unknown_v1_paths_return_structured_404_with_routes() {
        let sh = shared();
        for path in ["/v1/nope", "/v1", "/v1/models/default", "/v1/models/default/wat"] {
            let r = call(&sh, "GET", path, "");
            assert_eq!(r.status, 404, "{path}");
            let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
            let routes = v.get("routes").unwrap().as_array().unwrap();
            assert_eq!(routes.len(), V1_ROUTES.len(), "{path}");
            assert_eq!(routes[0].as_str(), Some(V1_ROUTES[0]));
        }
        // non-v1 unknown paths keep the legacy terse 404
        let r = call(&sh, "GET", "/nope", "");
        assert_eq!(r.status, 404);
        let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(v.get("routes").is_none());
    }

    #[test]
    fn unknown_model_404_lists_registered_names() {
        let sh = shared();
        let r = call(&sh, "POST", "/v1/models/nosuch/score", r#"{"words": [[3, 1]]}"#);
        assert_eq!(r.status, 404);
        let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let models = v.get("models").unwrap().as_array().unwrap();
        assert_eq!(models[0].as_str(), Some("default"));
    }

    #[test]
    fn metrics_routes_render_prometheus_text() {
        let sh = shared();
        call(&sh, "POST", "/v1/models/default/score", r#"{"words": [[3, 1]]}"#);
        for path in ["/metrics", "/v1/metrics"] {
            let r = call(&sh, "GET", path, "");
            assert_eq!(r.status, 200);
            assert!(r.content_type.starts_with("text/plain"), "{path}");
            let text = String::from_utf8(r.body).unwrap();
            assert!(text.contains("lsspca_model_requests_total{model=\"default\"}"), "{text}");
            assert!(text.contains("lsspca_request_duration_seconds_bucket"), "{text}");
        }
    }
}
