//! Serving observability: lock-free counters and latency histograms
//! rendered in the Prometheus text exposition format (`GET /metrics`,
//! `GET /v1/metrics`).
//!
//! Every value is an [`AtomicU64`] updated with `Relaxed` ordering —
//! metrics are monotone tallies, not synchronization points, and the
//! render pass tolerates (bounded) skew between counters scraped
//! mid-update. The request-latency histogram uses fixed bucket bounds
//! ([`BUCKETS`], seconds) with per-bucket counts made cumulative only at
//! render time, the shape Prometheus' `histogram_quantile` expects.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in seconds, ascending. Spans 100 µs
/// (an in-memory score of a short document) to 2.5 s (a stalled client
/// about to hit the idle timeout); `+Inf` is implicit.
pub const BUCKETS: [f64; 12] =
    [0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.5, 2.5];

/// Status codes the server can emit — the label set of
/// `lsspca_http_requests_total`. Codes outside this list cannot be
/// produced by the router; debug builds assert that.
pub const CODES: [u16; 8] = [200, 400, 404, 405, 413, 431, 501, 503];

/// A fixed-bucket latency histogram (counts + sum, Prometheus style).
#[derive(Debug, Default)]
pub struct Histogram {
    /// Per-bucket (non-cumulative) observation counts.
    counts: [AtomicU64; BUCKETS.len()],
    /// Observations above the last bound (the `+Inf` bucket).
    overflow: AtomicU64,
    /// Total observed duration in nanoseconds.
    sum_nanos: AtomicU64,
}

impl Histogram {
    /// Record one observation.
    pub fn observe(&self, d: Duration) {
        let secs = d.as_secs_f64();
        match BUCKETS.iter().position(|&b| secs <= b) {
            Some(i) => self.counts[i].fetch_add(1, Ordering::Relaxed),
            None => self.overflow.fetch_add(1, Ordering::Relaxed),
        };
        self.sum_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        let mut n = self.overflow.load(Ordering::Relaxed);
        for c in &self.counts {
            n += c.load(Ordering::Relaxed);
        }
        n
    }

    /// Render as `name_bucket{le=...}` lines plus `_sum` / `_count`.
    fn render(&self, name: &str, out: &mut String) {
        use std::fmt::Write as _;
        let mut cumulative = 0u64;
        for (i, bound) in BUCKETS.iter().enumerate() {
            cumulative += self.counts[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cumulative}");
        }
        cumulative += self.overflow.load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cumulative}");
        let sum = self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let _ = writeln!(out, "{name}_sum {sum}");
        let _ = writeln!(out, "{name}_count {cumulative}");
    }
}

/// Process-wide serving counters, shared (one `Arc`) by the acceptor,
/// every event-loop worker, and the reload watcher.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Completed HTTP responses, indexed parallel to [`CODES`].
    requests_by_code: [AtomicU64; CODES.len()],
    /// Wall time from request fully parsed to response queued.
    pub request_seconds: Histogram,
    /// Connections handed to the event loop.
    pub connections_accepted: AtomicU64,
    /// Connections currently owned by event-loop workers (gauge).
    pub connections_active: AtomicU64,
    /// Accepted sockets sitting in the accept queue, not yet adopted by
    /// a worker (gauge).
    pub queue_depth: AtomicU64,
    /// Connections shed with `503 Retry-After` (queue full or the
    /// connection cap reached).
    pub sheds: AtomicU64,
    /// Successful hot reloads (model swaps) across all registry slots.
    pub reloads: AtomicU64,
    /// Failed reload attempts (unreadable / checksum-invalid artifact);
    /// the previous model keeps serving.
    pub reload_errors: AtomicU64,
}

/// One registry slot's contribution to `/metrics`, snapshotted by
/// [`crate::serve::registry::Registry::model_stats`].
#[derive(Clone, Debug)]
pub struct ModelStat {
    /// Registry name of the model.
    pub name: String,
    /// Scoring requests answered by this slot.
    pub requests: u64,
    /// Hot reloads applied to this slot.
    pub reloads: u64,
    /// Kept vocabulary terms (the scorer's inverted-index width).
    pub scorer_terms: u64,
    /// Scorer inverted-index postings (word→PC weight entries) held in
    /// memory — the "cache" the scorer answers from.
    pub scorer_entries: u64,
}

impl Metrics {
    /// Count one response with `code` (must be in [`CODES`]).
    pub fn count_response(&self, code: u16) {
        debug_assert!(CODES.contains(&code), "unregistered status code {code}");
        if let Some(i) = CODES.iter().position(|&c| c == code) {
            self.requests_by_code[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Total responses with `code`.
    pub fn responses(&self, code: u16) -> u64 {
        CODES
            .iter()
            .position(|&c| c == code)
            .map(|i| self.requests_by_code[i].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Render the Prometheus text exposition, folding in the per-model
    /// stats snapshotted from the registry.
    pub fn render(&self, models: &[ModelStat]) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(2048);
        let gauge = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };

        let _ = writeln!(out, "# HELP lsspca_http_requests_total HTTP responses, by status code.");
        let _ = writeln!(out, "# TYPE lsspca_http_requests_total counter");
        for (i, code) in CODES.iter().enumerate() {
            let n = self.requests_by_code[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "lsspca_http_requests_total{{code=\"{code}\"}} {n}");
        }

        let _ = writeln!(
            out,
            "# HELP lsspca_request_duration_seconds Request latency, parse-complete to \
             response-queued."
        );
        let _ = writeln!(out, "# TYPE lsspca_request_duration_seconds histogram");
        self.request_seconds.render("lsspca_request_duration_seconds", &mut out);

        counter(
            &mut out,
            "lsspca_connections_accepted_total",
            "Connections handed to the event loop.",
            self.connections_accepted.load(Ordering::Relaxed),
        );
        gauge(
            &mut out,
            "lsspca_connections_active",
            "Connections currently owned by event-loop workers.",
            self.connections_active.load(Ordering::Relaxed),
        );
        gauge(
            &mut out,
            "lsspca_accept_queue_depth",
            "Accepted sockets waiting for a worker.",
            self.queue_depth.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "lsspca_sheds_total",
            "Connections shed with 503 under overload.",
            self.sheds.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "lsspca_reloads_total",
            "Successful hot model reloads.",
            self.reloads.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "lsspca_reload_errors_total",
            "Failed reload attempts (previous model kept serving).",
            self.reload_errors.load(Ordering::Relaxed),
        );

        gauge(&mut out, "lsspca_models", "Models in the serving registry.", models.len() as u64);
        let _ = writeln!(out, "# HELP lsspca_model_requests_total Scoring requests, by model.");
        let _ = writeln!(out, "# TYPE lsspca_model_requests_total counter");
        for m in models {
            let _ =
                writeln!(out, "lsspca_model_requests_total{{model=\"{}\"}} {}", m.name, m.requests);
        }
        let _ = writeln!(out, "# HELP lsspca_model_reloads_total Hot reloads applied, by model.");
        let _ = writeln!(out, "# TYPE lsspca_model_reloads_total counter");
        for m in models {
            let _ =
                writeln!(out, "lsspca_model_reloads_total{{model=\"{}\"}} {}", m.name, m.reloads);
        }
        let _ = writeln!(
            out,
            "# HELP lsspca_scorer_index_terms Kept vocabulary terms in the scorer index, by model."
        );
        let _ = writeln!(out, "# TYPE lsspca_scorer_index_terms gauge");
        let terms = "lsspca_scorer_index_terms";
        for m in models {
            let _ = writeln!(out, "{terms}{{model=\"{}\"}} {}", m.name, m.scorer_terms);
        }
        let _ = writeln!(
            out,
            "# HELP lsspca_scorer_index_entries Word-to-PC postings held by the scorer, by model."
        );
        let _ = writeln!(out, "# TYPE lsspca_scorer_index_entries gauge");
        let entries = "lsspca_scorer_index_entries";
        for m in models {
            let _ = writeln!(out, "{entries}{{model=\"{}\"}} {}", m.name, m.scorer_entries);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_sorted_and_positive() {
        assert!(BUCKETS.windows(2).all(|w| w[0] < w[1]));
        assert!(BUCKETS[0] > 0.0);
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_render() {
        let h = Histogram::default();
        h.observe(Duration::from_micros(50)); // ≤ 0.0001
        h.observe(Duration::from_micros(50));
        h.observe(Duration::from_millis(3)); // ≤ 0.005
        h.observe(Duration::from_secs(10)); // +Inf
        assert_eq!(h.count(), 4);
        let mut s = String::new();
        h.render("x", &mut s);
        assert!(s.contains("x_bucket{le=\"0.0001\"} 2"), "{s}");
        assert!(s.contains("x_bucket{le=\"0.005\"} 3"), "{s}");
        assert!(s.contains("x_bucket{le=\"2.5\"} 3"), "{s}");
        assert!(s.contains("x_bucket{le=\"+Inf\"} 4"), "{s}");
        assert!(s.contains("x_count 4"), "{s}");
    }

    #[test]
    fn render_shape_is_prometheus_text() {
        let m = Metrics::default();
        m.count_response(200);
        m.count_response(200);
        m.count_response(503);
        m.sheds.fetch_add(1, Ordering::Relaxed);
        let models = vec![ModelStat {
            name: "default".into(),
            requests: 2,
            reloads: 1,
            scorer_terms: 3,
            scorer_entries: 5,
        }];
        let text = m.render(&models);
        assert!(text.contains("lsspca_http_requests_total{code=\"200\"} 2"), "{text}");
        assert!(text.contains("lsspca_http_requests_total{code=\"503\"} 1"), "{text}");
        assert!(text.contains("lsspca_sheds_total 1"), "{text}");
        assert!(text.contains("lsspca_models 1"), "{text}");
        assert!(text.contains("lsspca_model_requests_total{model=\"default\"} 2"), "{text}");
        assert!(text.contains("lsspca_scorer_index_entries{model=\"default\"} 5"), "{text}");
        // every non-comment line is `name{labels} value` with a numeric value
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, v) = line.rsplit_once(' ').expect("metric line");
            assert!(v.parse::<f64>().is_ok(), "non-numeric value in {line:?}");
        }
    }
}
