//! Hot model reload: a polling watcher that picks up rewritten LSPM
//! artifacts and swaps them into the registry without dropping a single
//! in-flight request.
//!
//! The watcher polls each path-backed registry slot (`[serve]
//! reload_poll_ms`): when an artifact's `(len, mtime)` signature
//! changes, it re-reads the file (through the transient-I/O retry
//! policy and the fault-injection layer, tag [`FAULT_TAG`]) and
//! revalidates it with [`Model::from_bytes`] — magic, version, and the
//! xor-fold checksum, so a corrupt or truncated file can never be
//! swapped in. Writers that use [`crate::util::atomic_write`] (which
//! [`Model::save`] does) rename a fully-fsynced file into place, so the
//! watcher always reads either the old artifact or the complete new one.
//!
//! Swap mechanics are [`Registry::swap`]'s: a momentary write lock
//! replaces the slot's `Arc`; requests already holding the old `Arc`
//! finish on the old model. If the rewritten bytes hash to the digest
//! already being served, the swap is skipped (a no-op rewrite is not a
//! "reload"). Any failure leaves the previous model serving and counts
//! in `lsspca_reload_errors_total`; the next poll retries.

use std::io::Read as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, SystemTime};

use crate::model::Model;
use crate::serve::metrics::Metrics;
use crate::serve::registry::{Registry, ServingModel};
use crate::util::{faultinject, retry};

/// Fault-injection tag for artifact reads — test plans like
/// `rinterrupt:model@4` target the watcher's re-read path.
pub const FAULT_TAG: &str = "model";

/// Last artifact state seen on disk for one slot (`None` until the
/// first poll).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactSig {
    len: u64,
    mtime: Option<SystemTime>,
}

/// The `(len, mtime)` signature of a file, or `None` while it is
/// missing or mid-rename. This is the change detector shared by the
/// reload watcher and the `lsspca watch` corpus daemon
/// ([`crate::incr::watch`]).
pub fn stat_sig(path: &Path) -> Option<ArtifactSig> {
    let meta = std::fs::metadata(path).ok()?;
    Some(ArtifactSig { len: meta.len(), mtime: meta.modified().ok() })
}

/// Read an artifact through the retry policy and fault-injection layer.
fn read_artifact(path: &Path) -> std::io::Result<Vec<u8>> {
    retry::with_retry(&retry::policy(), || {
        let file = std::fs::File::open(path)?;
        let mut reader = faultinject::wrap_read(FAULT_TAG, file);
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        Ok(buf)
    })
    .map_err(|e| e.error)
}

/// One watcher pass over every path-backed slot. `sigs` carries the
/// per-slot signatures between polls (parallel to `registry.slots()`).
/// Returns the number of models swapped (tests poll synchronously).
pub fn poll_once(
    registry: &Registry,
    metrics: &Metrics,
    sigs: &mut Vec<Option<ArtifactSig>>,
) -> usize {
    sigs.resize(registry.slots().len(), None);
    let mut swapped = 0;
    for (slot, seen) in registry.slots().iter().zip(sigs.iter_mut()) {
        let Some(path) = &slot.path else { continue };
        let Some(sig) = stat_sig(path) else { continue }; // mid-rename or gone: next poll
        if *seen == Some(sig) {
            continue;
        }
        let bytes = match read_artifact(path) {
            Ok(b) => b,
            Err(e) => {
                metrics.reload_errors.fetch_add(1, Ordering::Relaxed);
                crate::warn_!("reload {}: read {}: {e}", slot.name, path.display());
                continue; // signature not stored → retried next poll
            }
        };
        let digest = crate::util::xor_fold_checksum(&bytes);
        if digest == slot.current().digest {
            *seen = Some(sig); // touched but identical: no swap
            continue;
        }
        let next = Model::from_bytes(&bytes)
            .and_then(|m| ServingModel::compile(m, slot.score_opts));
        match next {
            Ok(sm) => {
                let name = sm.model.corpus_name.clone();
                if registry.swap(&slot.name, sm).is_ok() {
                    metrics.reloads.fetch_add(1, Ordering::Relaxed);
                    *seen = Some(sig);
                    swapped += 1;
                    crate::info!("reloaded model '{}' from {} ({name})", slot.name, path.display());
                }
            }
            Err(e) => {
                metrics.reload_errors.fetch_add(1, Ordering::Relaxed);
                crate::warn_!("reload {}: invalid artifact: {e}", slot.name);
                // signature not stored → retried next poll
            }
        }
    }
    swapped
}

/// Watcher thread body: poll until `shutdown`, sleeping in short steps
/// so shutdown is honored promptly even with a long poll interval.
pub fn watch_loop(
    registry: &Registry,
    metrics: &Metrics,
    shutdown: &AtomicBool,
    poll: Duration,
) {
    let mut sigs: Vec<Option<ArtifactSig>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        poll_once(registry, metrics, &mut sigs);
        let mut left = poll;
        while !left.is_zero() && !shutdown.load(Ordering::SeqCst) {
            let step = left.min(Duration::from_millis(25));
            std::thread::sleep(step);
            left -= step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::scorer::ScoreOptions;
    use crate::serve::registry::tests::test_model;

    fn path_registry(path: &Path) -> Registry {
        let opts = ScoreOptions { center: false, normalize: false };
        let sm = ServingModel::compile(test_model("v1"), opts).unwrap();
        Registry::new(
            vec![("default".into(), Some(path.to_path_buf()), sm, opts)],
            None,
        )
        .unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_reload_{}_{name}.lspm", std::process::id()));
        p
    }

    #[test]
    fn rewrite_swaps_and_noop_rewrite_does_not() {
        let p = tmp("swap");
        test_model("v1").save(&p).unwrap();
        let reg = path_registry(&p);
        let metrics = Metrics::default();
        let mut sigs = Vec::new();
        // first poll: file matches the served digest → signature learned, no swap
        assert_eq!(poll_once(&reg, &metrics, &mut sigs), 0);
        assert_eq!(metrics.reloads.load(Ordering::Relaxed), 0);
        // rewrite with different content → swap
        let mut m2 = test_model("v2");
        m2.pcs[0].loadings = vec![(3, 9.0)];
        m2.save(&p).unwrap();
        assert_eq!(poll_once(&reg, &metrics, &mut sigs), 1);
        assert_eq!(reg.default_slot().current().model.corpus_name, "v2");
        assert_eq!(metrics.reloads.load(Ordering::Relaxed), 1);
        // rewrite the same bytes → signature moves, no second swap
        m2.save(&p).unwrap();
        assert_eq!(poll_once(&reg, &metrics, &mut sigs), 0);
        assert_eq!(metrics.reloads.load(Ordering::Relaxed), 1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_artifact_keeps_old_model_and_counts_error() {
        let p = tmp("corrupt");
        test_model("v1").save(&p).unwrap();
        let reg = path_registry(&p);
        let metrics = Metrics::default();
        let mut sigs = Vec::new();
        poll_once(&reg, &metrics, &mut sigs);
        // corrupt the artifact in place (checksum now invalid)
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(poll_once(&reg, &metrics, &mut sigs), 0);
        assert_eq!(reg.default_slot().current().model.corpus_name, "v1", "old model serves on");
        assert_eq!(metrics.reload_errors.load(Ordering::Relaxed), 1);
        // fixing the file recovers on the next poll
        let mut m2 = test_model("v2");
        m2.num_docs = 11;
        m2.save(&p).unwrap();
        assert_eq!(poll_once(&reg, &metrics, &mut sigs), 1);
        assert_eq!(reg.default_slot().current().model.corpus_name, "v2");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn transient_read_fault_is_retried_within_one_poll() {
        let _guard = faultinject::test_guard();
        let p = tmp("fault");
        test_model("v1").save(&p).unwrap();
        let reg = path_registry(&p);
        let metrics = Metrics::default();
        let mut sigs = Vec::new();
        poll_once(&reg, &metrics, &mut sigs);
        let mut m2 = test_model("v2");
        m2.seed = 99;
        m2.save(&p).unwrap();
        // one injected Interrupted on the first artifact read: the retry
        // policy absorbs it inside the same poll
        let plan = faultinject::FaultPlan::parse(&format!("rinterrupt:{FAULT_TAG}@4")).unwrap();
        let swapped = faultinject::scoped(plan, || poll_once(&reg, &metrics, &mut sigs));
        assert_eq!(swapped, 1, "transient fault must not block the reload");
        assert_eq!(reg.default_slot().current().model.corpus_name, "v2");
        assert_eq!(metrics.reload_errors.load(Ordering::Relaxed), 0);
        std::fs::remove_file(&p).ok();
    }
}
