//! The named multi-model registry: one process serves several
//! topic-sets, each behind an atomically swappable slot.
//!
//! A slot holds `RwLock<Arc<ServingModel>>`. Request handlers clone the
//! `Arc` under a momentary read lock and then score entirely on their
//! clone, so a hot reload ([`Registry::swap`], a momentary write lock)
//! never blocks behind an in-flight request and never invalidates one:
//! requests that grabbed the old `Arc` finish on the old model, requests
//! that arrive after the swap see the new one. Nothing is ever dropped
//! mid-score — the last `Arc` owner frees the old model.
//!
//! This module also owns the JSON views (`healthz`, `topics`, `score`)
//! so the legacy routes and the `/v1` routes render through the *same*
//! functions — the bitwise-identical-response contract between them is
//! structural, not maintained by hand.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::error::LsspcaError;
use crate::model::Model;
use crate::score::scorer::{ScoreOptions, Scorer};
use crate::serve::metrics::ModelStat;
use crate::util::json::{arr_f64, obj, Json};

/// One immutable, ready-to-serve compilation of a model: the artifact
/// plus its scorer and term lookup. Swapped wholesale on reload.
pub struct ServingModel {
    /// The model artifact.
    pub model: Model,
    /// Compiled inverted-index scorer.
    pub scorer: Scorer,
    /// word string → original feature index, for `terms` payloads.
    pub term_index: HashMap<String, usize>,
    /// [`crate::util::xor_fold_checksum`] of the artifact bytes — the
    /// reload watcher skips swaps when a rewrite produced identical
    /// bytes.
    pub digest: u64,
}

impl ServingModel {
    /// Compile `model` for serving (index + term lookup + digest).
    pub fn compile(model: Model, opts: ScoreOptions) -> Result<ServingModel, LsspcaError> {
        let digest = crate::util::xor_fold_checksum(&model.to_bytes());
        let scorer = Scorer::new(&model, opts)?;
        Ok(ServingModel::from_parts(model, scorer, digest))
    }

    /// Wrap an already-built scorer (the deprecated `serve(model,
    /// scorer, opts)` entrypoint hands one in).
    pub fn from_parts(model: Model, scorer: Scorer, digest: u64) -> ServingModel {
        let term_index = model
            .kept
            .iter()
            .zip(&model.kept_words)
            .map(|(&orig, w)| (w.clone(), orig))
            .collect();
        ServingModel { model, scorer, term_index, digest }
    }
}

/// One registry entry: the swappable model plus its reload bookkeeping.
pub struct Slot {
    /// Registry name (path segment in `/v1/models/{name}/…`).
    pub name: String,
    /// Artifact path watched for hot reload (`None` = in-memory model,
    /// never reloaded).
    pub path: Option<PathBuf>,
    /// Scorer options reapplied on every reload compile.
    pub score_opts: ScoreOptions,
    current: RwLock<Arc<ServingModel>>,
    /// Scoring requests answered by this slot.
    pub requests: AtomicU64,
    /// Hot reloads applied to this slot.
    pub reloads: AtomicU64,
}

impl Slot {
    /// Snapshot the current model (cheap: one `Arc` clone under a read
    /// lock). The caller scores on the snapshot; a concurrent swap does
    /// not affect it.
    pub fn current(&self) -> Arc<ServingModel> {
        Arc::clone(&self.current.read().expect("slot lock poisoned"))
    }
}

/// Ordered name → [`Slot`] map. The first registered model is the
/// default (what the legacy `/score`, `/topics`, `/healthz` shims hit).
pub struct Registry {
    slots: Vec<Arc<Slot>>,
    default: usize,
}

impl Registry {
    /// Build from `(name, path, compiled model, score options)` rows;
    /// `default_name = None` defaults to the first row.
    pub fn new(
        rows: Vec<(String, Option<PathBuf>, ServingModel, ScoreOptions)>,
        default_name: Option<&str>,
    ) -> Result<Registry, LsspcaError> {
        if rows.is_empty() {
            return Err(LsspcaError::serve("registry needs at least one model"));
        }
        let mut slots: Vec<Arc<Slot>> = Vec::with_capacity(rows.len());
        for (name, path, sm, score_opts) in rows {
            let name_ok = |c: char| c.is_ascii_alphanumeric() || c == '-' || c == '_';
            if name.is_empty() || !name.chars().all(name_ok) {
                return Err(LsspcaError::serve(format!(
                    "model name '{name}' must be non-empty [A-Za-z0-9_-]"
                )));
            }
            if slots.iter().any(|s| s.name == name) {
                return Err(LsspcaError::serve(format!("duplicate model name '{name}'")));
            }
            slots.push(Arc::new(Slot {
                name,
                path,
                score_opts,
                current: RwLock::new(Arc::new(sm)),
                requests: AtomicU64::new(0),
                reloads: AtomicU64::new(0),
            }));
        }
        let default = match default_name {
            None => 0,
            Some(d) => slots.iter().position(|s| s.name == d).ok_or_else(|| {
                LsspcaError::serve(format!("default model '{d}' is not registered"))
            })?,
        };
        Ok(Registry { slots, default })
    }

    /// Slot by name.
    pub fn get(&self, name: &str) -> Option<&Arc<Slot>> {
        self.slots.iter().find(|s| s.name == name)
    }

    /// The default slot (legacy shims and `Session::serve` land here).
    pub fn default_slot(&self) -> &Arc<Slot> {
        &self.slots[self.default]
    }

    /// All slots in registration order.
    pub fn slots(&self) -> &[Arc<Slot>] {
        &self.slots
    }

    /// Registered model names in order (the structured 404 lists them).
    pub fn names(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.name.clone()).collect()
    }

    /// Atomically replace `name`'s model. In-flight requests keep the
    /// `Arc` they already cloned; new requests see `next`.
    pub fn swap(&self, name: &str, next: ServingModel) -> Result<(), LsspcaError> {
        let slot = self
            .get(name)
            .ok_or_else(|| LsspcaError::serve(format!("swap: no model named '{name}'")))?;
        *slot.current.write().expect("slot lock poisoned") = Arc::new(next);
        slot.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Per-model stats snapshot for `/metrics`.
    pub fn model_stats(&self) -> Vec<ModelStat> {
        self.slots
            .iter()
            .map(|s| {
                let sm = s.current();
                ModelStat {
                    name: s.name.clone(),
                    requests: s.requests.load(Ordering::Relaxed),
                    reloads: s.reloads.load(Ordering::Relaxed),
                    scorer_terms: sm.scorer.index_terms() as u64,
                    scorer_entries: sm.scorer.index_entries() as u64,
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// JSON views — shared verbatim by legacy and /v1 routes
// ---------------------------------------------------------------------------

/// `/healthz` and `/v1/healthz` body: liveness + default-model identity.
pub fn healthz_json(model: &Model) -> Json {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::Str(model.corpus_name.clone())),
        ("pcs", Json::Num(model.num_pcs() as f64)),
        ("kept", Json::Num(model.kept.len() as f64)),
        ("n_features", Json::Num(model.n_features as f64)),
    ])
}

/// `/topics` and `/v1/models/{name}/topics` body: the K sparse PCs with
/// words and loadings (the paper's topic tables, as an API).
pub fn topics_json(model: &Model) -> Json {
    let topics: Vec<Json> = model
        .pcs
        .iter()
        .enumerate()
        .map(|(k, pc)| {
            let words: Vec<Json> = pc
                .loadings
                .iter()
                .map(|&(idx, w)| {
                    obj(vec![
                        ("word", Json::Str(model.word_of(idx))),
                        ("index", Json::Num(idx as f64)),
                        ("loading", Json::Num(w)),
                    ])
                })
                .collect();
            obj(vec![
                ("pc", Json::Num((k + 1) as f64)),
                ("lambda", Json::Num(pc.lambda)),
                ("phi", Json::Num(pc.phi)),
                ("explained_variance", Json::Num(pc.explained_variance)),
                ("words", Json::Arr(words)),
            ])
        })
        .collect();
    obj(vec![("topics", Json::Arr(topics))])
}

/// `/v1/models` body: every registered model with identity + reload
/// bookkeeping.
pub fn models_json(registry: &Registry) -> Json {
    let models: Vec<Json> = registry
        .slots()
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let sm = s.current();
            let mut fields = vec![
                ("name", Json::Str(s.name.clone())),
                ("default", Json::Bool(i == registry.default)),
                ("corpus", Json::Str(sm.model.corpus_name.clone())),
                ("pcs", Json::Num(sm.model.num_pcs() as f64)),
                ("kept", Json::Num(sm.model.kept.len() as f64)),
                ("n_features", Json::Num(sm.model.n_features as f64)),
                ("reloads", Json::Num(s.reloads.load(Ordering::Relaxed) as f64)),
            ];
            if let Some(p) = &s.path {
                fields.push(("path", Json::Str(p.display().to_string())));
            }
            obj(fields)
        })
        .collect();
    obj(vec![("models", Json::Arr(models))])
}

/// `POST /score` / `POST /v1/models/{name}/score` body: parse the
/// document payload, project it, and render scores. Returns `(status,
/// body)`; any 4xx carries a JSON `error` field.
pub fn score_json(sm: &ServingModel, body: &[u8]) -> (u16, Json) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return (400, obj(vec![("error", Json::Str("body is not utf-8".into()))])),
    };
    let payload = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            let msg = format!("bad JSON: {}", e.message());
            return (400, obj(vec![("error", Json::Str(msg))]));
        }
    };
    let mut words: Vec<(u32, f64)> = Vec::new();
    let mut unknown_terms = 0u64;
    let mut saw_input = false;
    if let Some(ws) = payload.get("words") {
        saw_input = true;
        let Some(items) = ws.as_array() else {
            return (400, obj(vec![("error", Json::Str("\"words\" must be an array".into()))]));
        };
        for item in items {
            let pair = item.as_array().unwrap_or(&[]);
            let (Some(id), Some(count)) =
                (pair.first().and_then(Json::as_f64), pair.get(1).and_then(Json::as_f64))
            else {
                return (
                    400,
                    obj(vec![(
                        "error",
                        Json::Str("\"words\" entries must be [id, count] pairs".into()),
                    )]),
                );
            };
            if !(id.fract() == 0.0 && id >= 0.0 && id < u32::MAX as f64) || !count.is_finite() {
                return (
                    400,
                    obj(vec![(
                        "error",
                        Json::Str(format!("invalid word entry [{id}, {count}]")),
                    )]),
                );
            }
            words.push((id as u32, count));
        }
    }
    if let Some(terms) = payload.get("terms") {
        saw_input = true;
        let Json::Obj(pairs) = terms else {
            return (400, obj(vec![("error", Json::Str("\"terms\" must be an object".into()))]));
        };
        // Duplicate keys: last occurrence wins, matching `Json::get`'s
        // lookup semantics (scoring both would double-count the term).
        let mut last_at: HashMap<&str, usize> = HashMap::with_capacity(pairs.len());
        for (i, (term, _)) in pairs.iter().enumerate() {
            last_at.insert(term.as_str(), i);
        }
        for (i, (term, count)) in pairs.iter().enumerate() {
            if last_at[term.as_str()] != i {
                continue; // superseded by a later duplicate
            }
            let Some(c) = count.as_f64().filter(|c| c.is_finite()) else {
                return (
                    400,
                    obj(vec![("error", Json::Str(format!("bad count for term '{term}'")))]),
                );
            };
            match sm.term_index.get(term) {
                Some(&orig) => words.push((orig as u32, c)),
                // outside the kept set every PC weight is exactly 0, so
                // the score is unaffected; report instead of dropping
                None => unknown_terms += 1,
            }
        }
    }
    if !saw_input {
        return (
            400,
            obj(vec![(
                "error",
                Json::Str(
                    "provide \"words\": [[id, count], ...] and/or \"terms\": {word: count}".into(),
                ),
            )]),
        );
    }
    let top = payload
        .get("top")
        .and_then(Json::as_f64)
        .map(|t| t.max(1.0) as usize)
        .unwrap_or(1);
    // Canonicalize to sorted word order (stable, so equal ids keep their
    // payload order): f64 addition is order-sensitive, and the bitwise
    // agreement with batch/in-memory scoring assumes docword ordering.
    words.sort_by_key(|&(w, _)| w);
    match sm.scorer.score(&words) {
        Ok(scores) => {
            let tops: Vec<Json> = Scorer::top_pcs(&scores, top)
                .into_iter()
                .map(|p| Json::Num((p + 1) as f64))
                .collect();
            (
                200,
                obj(vec![
                    ("scores", arr_f64(&scores)),
                    ("top_pcs", Json::Arr(tops)),
                    ("unknown_terms", Json::Num(unknown_terms as f64)),
                ]),
            )
        }
        Err(e) => (400, obj(vec![("error", Json::Str(e.message().to_string()))])),
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::ModelPc;

    /// The model the old `score::server` unit suite pinned its scores
    /// against — kept verbatim so those pins carry over.
    pub(crate) fn test_model(name: &str) -> Model {
        Model {
            corpus_name: name.into(),
            num_docs: 10,
            n_features: 100,
            vocab_hash: 0,
            seed: 1,
            elim_lambda: 0.2,
            kept: vec![3, 8, 15],
            kept_means: vec![0.0, 0.0, 0.0],
            kept_stds: vec![1.0, 1.0, 1.0],
            kept_words: vec!["alpha".into(), "beta".into(), "gamma".into()],
            pcs: vec![
                ModelPc {
                    lambda: 0.5,
                    phi: 1.0,
                    explained_variance: 1.0,
                    loadings: vec![(3, 0.6), (8, 0.8)],
                },
                ModelPc {
                    lambda: 0.5,
                    phi: 0.7,
                    explained_variance: 0.7,
                    loadings: vec![(15, 1.0)],
                },
            ],
        }
    }

    pub(crate) fn test_registry() -> Registry {
        let opts = ScoreOptions { center: false, normalize: false };
        let sm = ServingModel::compile(test_model("srv-test"), opts).unwrap();
        Registry::new(vec![("default".into(), None, sm, opts)], None).unwrap()
    }

    fn post_score(body: &str) -> (u16, Json) {
        let reg = test_registry();
        let sm = reg.default_slot().current();
        score_json(&sm, body.as_bytes())
    }

    #[test]
    fn score_by_words() {
        let (code, v) = post_score(r#"{"words": [[3, 2], [15, 1]], "top": 2}"#);
        assert_eq!(code, 200, "{v:?}");
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 1.2).abs() < 1e-12);
        assert!((scores[1].as_f64().unwrap() - 1.0).abs() < 1e-12);
        let tops = v.get("top_pcs").unwrap().as_array().unwrap();
        assert_eq!(tops[0].as_f64(), Some(1.0));
        assert_eq!(tops[1].as_f64(), Some(2.0));
    }

    #[test]
    fn score_by_terms_counts_unknown() {
        let (code, v) = post_score(r#"{"terms": {"alpha": 1, "nosuchword": 3}}"#);
        assert_eq!(code, 200, "{v:?}");
        assert_eq!(v.get("unknown_terms").unwrap().as_f64(), Some(1.0));
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn duplicate_terms_last_occurrence_wins() {
        // must match Json::get's last-wins lookup, not double-count
        let (code, v) = post_score(r#"{"terms": {"alpha": 1, "alpha": 2}}"#);
        assert_eq!(code, 200, "{v:?}");
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 0.6 * 2.0).abs() < 1e-12, "{scores:?}");
    }

    #[test]
    fn bad_payloads_rejected() {
        for body in [
            "not json",
            "{}",
            r#"{"words": 5}"#,
            r#"{"words": [[1]]}"#,
            r#"{"words": [[-1, 2]]}"#,
            r#"{"words": [[1.5, 2]]}"#,
            r#"{"terms": [1]}"#,
            r#"{"words": [[999, 1]]}"#, // id ≥ n_features → scorer error
        ] {
            let (code, v) = post_score(body);
            assert_eq!(code, 400, "{body} -> {v:?}");
            assert!(v.get("error").is_some());
        }
    }

    #[test]
    fn registry_routes_by_name_and_rejects_bad_names() {
        let opts = ScoreOptions { center: false, normalize: false };
        let a = ServingModel::compile(test_model("corpus-a"), opts).unwrap();
        let b = ServingModel::compile(test_model("corpus-b"), opts).unwrap();
        let reg = Registry::new(
            vec![("nytimes".into(), None, a, opts), ("pubmed".into(), None, b, opts)],
            Some("pubmed"),
        )
        .unwrap();
        assert_eq!(reg.names(), vec!["nytimes".to_string(), "pubmed".to_string()]);
        assert_eq!(reg.default_slot().name, "pubmed");
        assert_eq!(reg.get("nytimes").unwrap().current().model.corpus_name, "corpus-a");
        assert!(reg.get("nosuch").is_none());

        let opts = ScoreOptions { center: false, normalize: false };
        let row = |n: &str| {
            (n.to_string(), None, ServingModel::compile(test_model("m"), opts).unwrap(), opts)
        };
        assert!(Registry::new(vec![row("x"), row("x")], None).is_err(), "duplicate name");
        assert!(Registry::new(vec![row("bad name")], None).is_err(), "space in name");
        assert!(Registry::new(vec![], None).is_err(), "empty registry");
        assert!(Registry::new(vec![row("x")], Some("y")).is_err(), "unknown default");
    }

    #[test]
    fn swap_changes_new_snapshots_not_old_ones() {
        let reg = test_registry();
        let before = reg.default_slot().current();
        let mut m2 = test_model("srv-test-v2");
        m2.pcs[0].loadings = vec![(3, 1.5)];
        let next =
            ServingModel::compile(m2, ScoreOptions { center: false, normalize: false }).unwrap();
        reg.swap("default", next).unwrap();
        let after = reg.default_slot().current();
        assert_eq!(before.model.corpus_name, "srv-test");
        assert_eq!(after.model.corpus_name, "srv-test-v2");
        assert_eq!(reg.default_slot().reloads.load(Ordering::Relaxed), 1);
        // the pre-swap snapshot still scores on the old weights
        let score0 =
            |v: &Json| v.get("scores").unwrap().as_array().unwrap()[0].as_f64().unwrap();
        let (_, v) = score_json(&before, br#"{"words": [[3, 1]]}"#);
        assert!((score0(&v) - 0.6).abs() < 1e-12);
        let (_, v) = score_json(&after, br#"{"words": [[3, 1]]}"#);
        assert!((score0(&v) - 1.5).abs() < 1e-12);
        let stray = ServingModel::compile(test_model("x"), ScoreOptions::default()).unwrap();
        assert!(reg.swap("nosuch", stray).is_err());
    }

    #[test]
    fn models_json_lists_identity_and_default_flag() {
        let reg = test_registry();
        let v = models_json(&reg);
        let models = v.get("models").unwrap().as_array().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("name").unwrap().as_str(), Some("default"));
        assert_eq!(models[0].get("default").unwrap().as_bool(), Some(true));
        assert_eq!(models[0].get("pcs").unwrap().as_f64(), Some(2.0));
        assert!(models[0].get("path").is_none());
    }
}
