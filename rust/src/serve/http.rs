//! HTTP/1.1 wire protocol: an incremental, allocation-light request
//! parser and a response renderer, shared by every connection state
//! machine in [`crate::serve::conn`].
//!
//! The parser is *incremental*: [`next_request`] inspects whatever bytes
//! have arrived so far and either produces one complete request (and
//! drains its bytes from the buffer), reports "need more bytes", or
//! fails with a status code. Because it consumes exactly one request's
//! bytes per call, a client that writes several requests back-to-back is
//! served with HTTP/1.1 pipelining for free — the connection loop just
//! calls [`next_request`] until the buffer runs dry.
//!
//! Framing rules (deliberately the subset the old thread-per-connection
//! server spoke, plus keep-alive):
//!
//! - head (request line + headers) terminated by `\r\n\r\n`, capped at
//!   [`MAX_HEAD_BYTES`] → `431` beyond that;
//! - bodies framed by `Content-Length` only; `Transfer-Encoding` is
//!   rejected with `501` (chunked bodies buy nothing for sub-megabyte
//!   JSON documents);
//! - `Content-Length` above the configured cap → `413`;
//! - HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close; a
//!   `Connection: close` / `keep-alive` header overrides either way.

/// Hard cap on one request's head (request line + headers). The body
/// has its own configurable cap; without this a client streaming header
/// bytes forever would grow the connection buffer without bound.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed request, bytes already drained from the connection buffer.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method, e.g. `GET`.
    pub method: String,
    /// Path component of the target (query string stripped).
    pub path: String,
    /// Raw body bytes (`Content-Length` framed).
    pub body: Vec<u8>,
    /// Client asked to close the connection after this response.
    pub close: bool,
}

/// A request that cannot be parsed; the connection answers with
/// `status` and closes (framing is unknown past a malformed head).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseError {
    /// Status code to answer with (400 / 413 / 431 / 501).
    pub status: u16,
    /// Human-readable cause, returned in the JSON error body.
    pub message: String,
}

impl ParseError {
    fn new(status: u16, message: impl Into<String>) -> ParseError {
        ParseError { status, message: message.into() }
    }
}

/// Try to parse one complete request from the front of `buf`.
///
/// - `Ok(Some(req))` — a full head + body was available; its bytes have
///   been drained from `buf` (call again: the next pipelined request may
///   already be buffered).
/// - `Ok(None)` — the buffered bytes are a valid prefix; read more.
/// - `Err(e)` — the head is malformed or over a cap; answer `e.status`
///   and close.
pub fn next_request(buf: &mut Vec<u8>, max_body: usize) -> Result<Option<Request>, ParseError> {
    let head_len = match find_head_end(buf) {
        Some(n) => n,
        None => {
            if buf.len() > MAX_HEAD_BYTES {
                return Err(ParseError::new(
                    431,
                    format!("request head too large (> {MAX_HEAD_BYTES} bytes)"),
                ));
            }
            return Ok(None);
        }
    };
    if head_len > MAX_HEAD_BYTES {
        return Err(ParseError::new(
            431,
            format!("request head too large (> {MAX_HEAD_BYTES} bytes)"),
        ));
    }
    let head = std::str::from_utf8(&buf[..head_len])
        .map_err(|_| ParseError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ParseError::new(400, "empty request line"))?
        .to_string();
    let target =
        parts.next().ok_or_else(|| ParseError::new(400, "missing request target"))?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    // route on the path only; ignore any query string
    let path = target.split('?').next().unwrap_or("").to_string();

    let mut content_length = 0usize;
    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close.
    let mut close = version.eq_ignore_ascii_case("HTTP/1.0");
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ParseError::new(400, format!("malformed header line {line:?}")));
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse()
                .map_err(|_| ParseError::new(400, format!("bad Content-Length '{value}'")))?;
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                close = true;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                close = false;
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err(ParseError::new(501, "Transfer-Encoding is not supported"));
        }
    }
    if content_length > max_body {
        return Err(ParseError::new(
            413,
            format!("request body too large ({content_length} > {max_body} bytes)"),
        ));
    }
    let total = head_len + content_length;
    if buf.len() < total {
        return Ok(None); // body still in flight
    }
    let body = buf[head_len..total].to_vec();
    buf.drain(..total);
    Ok(Some(Request { method, path, body, close }))
}

/// Byte offset just past the `\r\n\r\n` head terminator, if present.
/// Searches only the head budget (+3 bytes of terminator slack) so a
/// giant bufferful of garbage is not rescanned every call.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let limit = buf.len().min(MAX_HEAD_BYTES + 4);
    buf[..limit].windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Standard reason phrase for every status the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// One response, status + optional extra headers + body, rendered into
/// a connection's write buffer by [`Response::render`].
#[derive(Clone, Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Extra headers (name, value) — `Allow`, `Deprecation`,
    /// `Retry-After`, …
    pub extra: Vec<(&'static str, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response (the API's default content type).
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "application/json",
            extra: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// A plain-text response (`/metrics`).
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; version=0.0.4",
            extra: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// Attach an extra header.
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.extra.push((name, value.into()));
        self
    }

    /// Serialize head + body into `out`. `keep_alive` picks the
    /// `Connection` header; the connection loop closes after flushing
    /// when it is false.
    pub fn render(&self, keep_alive: bool, out: &mut Vec<u8>) {
        use std::io::Write as _;
        let conn = if keep_alive { "keep-alive" } else { "close" };
        let _ = write!(
            out,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
        );
        for (name, value) in &self.extra {
            let _ = write!(out, "{name}: {value}\r\n");
        }
        let _ = write!(out, "\r\n");
        out.extend_from_slice(&self.body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(raw: &[u8], max_body: usize) -> Result<Option<Request>, ParseError> {
        let mut buf = raw.to_vec();
        next_request(&mut buf, max_body)
    }

    #[test]
    fn parses_complete_request_and_drains() {
        let mut buf =
            b"POST /score HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcdGET ".to_vec();
        let r = next_request(&mut buf, 1024).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/score");
        assert_eq!(r.body, b"abcd");
        assert!(!r.close, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(buf, b"GET ", "next pipelined request's bytes stay buffered");
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let mut buf = b"GET /v1/healthz HTTP/1.1\r\n\r\nGET /v1/models HTTP/1.1\r\n\r\n".to_vec();
        let a = next_request(&mut buf, 1024).unwrap().unwrap();
        let b = next_request(&mut buf, 1024).unwrap().unwrap();
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/v1/healthz", "/v1/models"));
        assert!(buf.is_empty());
        assert!(next_request(&mut buf, 1024).unwrap().is_none());
    }

    #[test]
    fn partial_head_and_partial_body_wait() {
        assert!(req(b"GET /x HT", 1024).unwrap().is_none());
        assert!(req(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc", 1024)
            .unwrap()
            .is_none());
    }

    #[test]
    fn connection_header_and_version_drive_close() {
        let r = req(b"GET /x HTTP/1.1\r\nConnection: close\r\n\r\n", 64).unwrap().unwrap();
        assert!(r.close);
        let r = req(b"GET /x HTTP/1.0\r\n\r\n", 64).unwrap().unwrap();
        assert!(r.close, "HTTP/1.0 defaults to close");
        let r = req(b"GET /x HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", 64).unwrap().unwrap();
        assert!(!r.close);
    }

    #[test]
    fn query_string_is_stripped() {
        let r = req(b"GET /topics?pretty=1 HTTP/1.1\r\n\r\n", 64).unwrap().unwrap();
        assert_eq!(r.path, "/topics");
    }

    #[test]
    fn oversized_body_is_413_oversized_head_431() {
        let e = req(b"POST /x HTTP/1.1\r\nContent-Length: 999\r\n\r\n", 100).unwrap_err();
        assert_eq!(e.status, 413);
        let mut huge = b"GET /x HTTP/1.1\r\n".to_vec();
        huge.extend(vec![b'a'; MAX_HEAD_BYTES + 10]);
        let e = next_request(&mut huge, 100).unwrap_err();
        assert_eq!(e.status, 431);
    }

    #[test]
    fn malformed_heads_are_400_chunked_is_501() {
        assert_eq!(req(b"\r\n\r\n", 64).unwrap_err().status, 400);
        assert_eq!(req(b"GET\r\n\r\n", 64).unwrap_err().status, 400);
        assert_eq!(req(b"GET /x HTTP/1.1\r\nnocolonhere\r\n\r\n", 64).unwrap_err().status, 400);
        assert_eq!(
            req(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 64).unwrap_err().status,
            400
        );
        assert_eq!(
            req(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 64)
                .unwrap_err()
                .status,
            501
        );
        assert_eq!(req(b"GET /\xff\xfe HTTP/1.1\r\n\r\n", 64).unwrap_err().status, 400);
    }

    #[test]
    fn response_renders_with_length_connection_and_extras() {
        let mut out = Vec::new();
        Response::json(405, "{\"error\":\"x\"}")
            .with_header("Allow", "POST")
            .render(true, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{text}");
        assert!(text.contains("\r\nConnection: keep-alive\r\n"), "{text}");
        assert!(text.contains("\r\nAllow: POST\r\n"), "{text}");
        assert!(text.contains("\r\nContent-Length: 13\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"error\":\"x\"}"), "{text}");
        let mut out = Vec::new();
        Response::text(200, "m 1\n").render(false, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("\r\nConnection: close\r\n"), "{text}");
        assert!(text.contains("Content-Type: text/plain"), "{text}");
    }
}
