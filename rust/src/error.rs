//! The crate-wide structured error type.
//!
//! Every fallible operation on the public surface returns
//! [`LsspcaError`] instead of a bare `String`, so library callers can
//! *match* on failure classes (retry a cache rebuild, surface a config
//! typo to the user, alert on numeric trouble) and the CLI can map each
//! class to a distinct process exit code (see [`LsspcaError::exit_code`]).
//!
//! The variants mirror the system's layers:
//!
//! | variant    | layer                                        | exit code |
//! |------------|----------------------------------------------|-----------|
//! | `Config`   | TOML / builder / CLI-flag validation         | 2         |
//! | `Io`       | filesystem + model-artifact I/O              | 3         |
//! | `Cache`    | variance checkpoints + covariance shard cache| 4         |
//! | `Numeric`  | solver / engine failures                     | 5         |
//! | `Corpus`   | docword ingestion + streaming passes         | 6         |
//! | `Serve`    | the HTTP scoring server                      | 7         |
//!
//! `LsspcaError` implements [`std::error::Error`], so it composes with
//! `Box<dyn Error>`, `anyhow`-style consumers and `?` in `main`.

use std::fmt;
use std::path::{Path, PathBuf};

/// Structured error for every fallible operation in the crate.
///
/// Construct via the per-variant helpers ([`LsspcaError::config`],
/// [`LsspcaError::io`], …) rather than the variants directly — the
/// helpers take anything `Into<String>` and keep call sites short.
#[derive(Clone, Debug)]
pub enum LsspcaError {
    /// Invalid configuration: unparsable TOML, bad flag values, or knob
    /// combinations the pipeline rejects up front.
    Config {
        /// What was wrong, naming the offending `[section] key` or flag.
        message: String,
    },
    /// Filesystem failure or a malformed on-disk artifact (docword
    /// write, vocab file, model artifact, report output).
    Io {
        /// The file the operation touched, when known.
        path: Option<PathBuf>,
        /// The underlying failure.
        message: String,
        /// `true` when the failure was transient (`Interrupted`,
        /// `TimedOut`, `WouldBlock`) and every retry was exhausted —
        /// the caller may reasonably try the whole operation again.
        transient: bool,
    },
    /// Corpus ingestion problems: an unreadable or format-violating
    /// docword stream, or a streaming-pass worker failure.
    Corpus {
        /// What went wrong while streaming the corpus.
        message: String,
    },
    /// Cache-layer problems: a stale, corrupt or truncated variance
    /// checkpoint, covariance shard cache, or job-state file.
    Cache {
        /// Which cache object failed which integrity check.
        message: String,
        /// `true` when the failure was transient I/O with retries
        /// exhausted, rather than a corrupt or stale artifact.
        transient: bool,
    },
    /// Numerical / solver-layer failure: an engine that cannot run the
    /// requested problem, or a dimension mismatch reaching the solver.
    Numeric {
        /// What the solver layer rejected.
        message: String,
    },
    /// Scoring-server failure: bind/accept errors or invalid serve
    /// options.
    Serve {
        /// What the server could not do.
        message: String,
    },
}

impl LsspcaError {
    /// A [`LsspcaError::Config`] with the given message.
    pub fn config(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Config { message: message.into() }
    }

    /// A [`LsspcaError::Io`] with no path context (the message usually
    /// already embeds one).
    pub fn io(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Io { path: None, message: message.into(), transient: false }
    }

    /// A [`LsspcaError::Io`] carrying the file it concerns.
    pub fn io_at(path: impl AsRef<Path>, message: impl Into<String>) -> LsspcaError {
        LsspcaError::Io {
            path: Some(path.as_ref().to_path_buf()),
            message: message.into(),
            transient: false,
        }
    }

    /// A *transient* [`LsspcaError::Io`]: the operation failed with a
    /// retryable [`std::io::ErrorKind`] and the retry budget ran out
    /// (see [`crate::util::retry`]). [`LsspcaError::is_transient`]
    /// returns `true`.
    pub fn io_transient(path: impl AsRef<Path>, message: impl Into<String>) -> LsspcaError {
        LsspcaError::Io {
            path: Some(path.as_ref().to_path_buf()),
            message: message.into(),
            transient: true,
        }
    }

    /// A [`LsspcaError::Corpus`] with the given message.
    pub fn corpus(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Corpus { message: message.into() }
    }

    /// A [`LsspcaError::Cache`] with the given message.
    pub fn cache(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Cache { message: message.into(), transient: false }
    }

    /// A *transient* [`LsspcaError::Cache`]: retry-exhausted transient
    /// I/O against a checkpoint / shard-cache / job-state file, as
    /// opposed to a corrupt or stale artifact.
    pub fn cache_transient(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Cache { message: message.into(), transient: true }
    }

    /// A [`LsspcaError::Numeric`] with the given message.
    pub fn numeric(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Numeric { message: message.into() }
    }

    /// A [`LsspcaError::Serve`] with the given message.
    pub fn serve(message: impl Into<String>) -> LsspcaError {
        LsspcaError::Serve { message: message.into() }
    }

    /// The error class as a short lowercase label (the [`fmt::Display`]
    /// prefix).
    pub fn category(&self) -> &'static str {
        match self {
            LsspcaError::Config { .. } => "config",
            LsspcaError::Io { .. } => "io",
            LsspcaError::Corpus { .. } => "corpus",
            LsspcaError::Cache { .. } => "cache",
            LsspcaError::Numeric { .. } => "numeric",
            LsspcaError::Serve { .. } => "serve",
        }
    }

    /// The bare message, without the category prefix or path — what an
    /// API response or log line that supplies its own framing should
    /// show.
    pub fn message(&self) -> &str {
        match self {
            LsspcaError::Config { message }
            | LsspcaError::Io { message, .. }
            | LsspcaError::Corpus { message }
            | LsspcaError::Cache { message, .. }
            | LsspcaError::Numeric { message }
            | LsspcaError::Serve { message } => message,
        }
    }

    /// `true` when the underlying failure was transient I/O
    /// (`Interrupted` / `TimedOut` / `WouldBlock`) whose retry budget
    /// was exhausted: the operation may succeed if re-run, unlike a
    /// corrupt artifact or a config error. Only [`LsspcaError::Io`] and
    /// [`LsspcaError::Cache`] can carry the flag.
    pub fn is_transient(&self) -> bool {
        match self {
            LsspcaError::Io { transient, .. } | LsspcaError::Cache { transient, .. } => *transient,
            _ => false,
        }
    }

    /// Process exit code for the `lsspca` CLI: each error class maps to
    /// a distinct code so shell callers can branch on the failure kind
    /// (config=2, io=3, cache=4, numeric=5, corpus=6, serve=7).
    pub fn exit_code(&self) -> i32 {
        match self {
            LsspcaError::Config { .. } => 2,
            LsspcaError::Io { .. } => 3,
            LsspcaError::Cache { .. } => 4,
            LsspcaError::Numeric { .. } => 5,
            LsspcaError::Corpus { .. } => 6,
            LsspcaError::Serve { .. } => 7,
        }
    }
}

impl fmt::Display for LsspcaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LsspcaError::Io { path: Some(p), message, .. } => {
                write!(f, "io error [{}]: {message}", p.display())
            }
            other => write!(f, "{} error: {}", other.category(), other.message()),
        }
    }
}

impl std::error::Error for LsspcaError {}

/// Compatibility bridge for string-error contexts (the property-test
/// DSL's closures return `Result<(), String>`): `?` on an
/// [`LsspcaError`] inside them renders via [`fmt::Display`].
impl From<LsspcaError> for String {
    fn from(e: LsspcaError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_category_and_message() {
        let e = LsspcaError::config("solver.engine 'gpu' (want native|xla)");
        let s = e.to_string();
        assert!(s.starts_with("config error: "), "{s}");
        assert!(s.contains("gpu"), "{s}");
        let e = LsspcaError::io_at("/tmp/m.lspm", "checksum mismatch");
        let s = e.to_string();
        assert!(s.contains("/tmp/m.lspm") && s.contains("checksum"), "{s}");
    }

    #[test]
    fn exit_codes_are_distinct_and_match_the_contract() {
        let all = [
            LsspcaError::config("x"),
            LsspcaError::io("x"),
            LsspcaError::cache("x"),
            LsspcaError::numeric("x"),
            LsspcaError::corpus("x"),
            LsspcaError::serve("x"),
        ];
        // the documented CLI contract
        assert_eq!(LsspcaError::config("x").exit_code(), 2);
        assert_eq!(LsspcaError::io("x").exit_code(), 3);
        assert_eq!(LsspcaError::cache("x").exit_code(), 4);
        assert_eq!(LsspcaError::numeric("x").exit_code(), 5);
        let mut codes: Vec<i32> = all.iter().map(|e| e.exit_code()).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), all.len(), "exit codes must be distinct");
        // none may collide with the generic-failure code 1 or success 0
        assert!(codes.iter().all(|&c| c >= 2));
    }

    #[test]
    fn implements_std_error() {
        fn takes_error(_: &dyn std::error::Error) {}
        let e = LsspcaError::numeric("diverged");
        takes_error(&e);
        let boxed: Box<dyn std::error::Error> = Box::new(e);
        assert!(boxed.to_string().contains("diverged"));
    }

    #[test]
    fn string_bridge_renders_display() {
        let s: String = LsspcaError::cache("shard 3 checksum mismatch").into();
        assert_eq!(s, "cache error: shard 3 checksum mismatch");
    }

    #[test]
    fn matching_on_variants() {
        let e = LsspcaError::cache("corrupt");
        assert!(matches!(e, LsspcaError::Cache { .. }));
        assert_eq!(e.category(), "cache");
        assert_eq!(e.message(), "corrupt");
    }

    #[test]
    fn transient_flag_only_on_transient_constructors() {
        assert!(LsspcaError::io_transient("/tmp/x", "interrupted").is_transient());
        assert!(LsspcaError::cache_transient("interrupted").is_transient());
        for e in [
            LsspcaError::config("x"),
            LsspcaError::io("x"),
            LsspcaError::io_at("/tmp/x", "x"),
            LsspcaError::cache("x"),
            LsspcaError::numeric("x"),
            LsspcaError::corpus("x"),
            LsspcaError::serve("x"),
        ] {
            assert!(!e.is_transient(), "{e}");
        }
        // transient errors keep their class's exit code — transience is
        // an orthogonal axis, not a new category
        assert_eq!(LsspcaError::cache_transient("x").exit_code(), 4);
        assert_eq!(LsspcaError::io_transient("/t", "x").exit_code(), 3);
    }
}
