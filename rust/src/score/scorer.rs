//! Sparse projection of a bag-of-words document onto K sparse PCs.
//!
//! For component k with loadings `v_k` (supported on a handful of
//! original-space features), the topic score of a document with counts
//! `x` is
//!
//! ```text
//! s_k = Σ_j w_kj · x_j  −  offset_k        with w_kj = v_kj (raw)
//!                                         or  w_kj = v_kj / σ_j (normalized)
//! ```
//!
//! where `offset_k = Σ_j w_kj · μ_j` folds mean-centering into a single
//! precomputed constant (x − μ never materializes: the vocabulary is
//! large, documents are sparse, and only support features have nonzero
//! weight). The per-document cost is O(nnz(doc)) hash lookups — the
//! scoring engine never touches the vocabulary dimension.
//!
//! Determinism: the inverted index is built in (PC, loading-rank) order
//! and accumulation follows the document's word order, so for documents
//! presented in sorted word order (the docword convention; the HTTP
//! server sorts request payloads before scoring) batch scoring, serving,
//! and in-memory scoring produce bitwise-identical f64s.

use std::collections::HashMap;

use crate::error::LsspcaError;
use crate::model::Model;

/// Scoring-time options.
#[derive(Clone, Copy, Debug)]
pub struct ScoreOptions {
    /// Subtract the training means (fold `−Σ w·μ` into the score). The
    /// training covariance is centered, so this is the default.
    pub center: bool,
    /// Divide each loading by the feature's training standard deviation
    /// (correlation-style scoring). Zero-variance features score 0.
    pub normalize: bool,
}

impl Default for ScoreOptions {
    fn default() -> Self {
        ScoreOptions { center: true, normalize: false }
    }
}

/// A compiled scorer: inverted index from original feature index to the
/// components that load it.
///
/// # Example: project a document onto the sparse PCs
///
/// ```
/// use lsspca::model::{Model, ModelPc};
/// use lsspca::score::{ScoreOptions, Scorer};
///
/// let model = Model {
///     corpus_name: "doctest".into(),
///     num_docs: 10,
///     n_features: 6,
///     vocab_hash: 0,
///     seed: 1,
///     elim_lambda: 0.5,
///     kept: vec![4, 2],
///     kept_means: vec![0.0, 0.0],
///     kept_stds: vec![1.0, 1.0],
///     kept_words: vec!["alpha".into(), "beta".into()],
///     pcs: vec![ModelPc {
///         lambda: 0.5,
///         phi: 1.0,
///         explained_variance: 1.0,
///         loadings: vec![(4, 0.8), (2, 0.6)],
///     }],
/// };
/// let scorer = Scorer::new(&model, ScoreOptions::default()).unwrap();
/// // A document with count 1 of feature 2 and count 3 of feature 4
/// // projects to 1·0.6 + 3·0.8 = 3.0 (means are zero here).
/// let scores = scorer.score(&[(2, 1.0), (4, 3.0)]).unwrap();
/// assert!((scores[0] - 3.0).abs() < 1e-12);
/// ```
pub struct Scorer {
    k: usize,
    n_features: usize,
    /// orig feature → `(start, len)` span into [`entries`](Self::entries).
    ///
    /// The inverted index is stored as one contiguous arena instead of a
    /// `Vec` per key: scoring does a single hash probe per document word
    /// and then scans a cache-line-friendly slab, rather than chasing a
    /// separate heap allocation per feature. The per-feature entry order
    /// (PC order) is preserved by the flattening, so accumulation order —
    /// and hence every scored f64 — is bitwise unchanged.
    spans: HashMap<u32, (u32, u32)>,
    /// Flattened `(pc index, weight)` entries, grouped by feature in
    /// ascending feature order, PC order within a feature.
    entries: Vec<(u32, f64)>,
    /// Per-PC centering offset, stored already negated (`−Σ w·μ`, with
    /// a zero sum normalized to +0.0 so uncentered scores never render
    /// as `-0`); all zeros when `center` is off.
    neg_offsets: Vec<f64>,
    opts: ScoreOptions,
}

impl Scorer {
    /// Compile a scorer from a model. Fails on a model whose loadings
    /// reference features outside the kept set (validated shape).
    pub fn new(model: &Model, opts: ScoreOptions) -> Result<Scorer, LsspcaError> {
        model.validate()?;
        let k = model.num_pcs();
        // orig index → position in the kept map (for μ/σ lookups)
        let kept_pos: HashMap<usize, usize> =
            model.kept.iter().enumerate().map(|(p, &orig)| (orig, p)).collect();
        let mut index: HashMap<u32, Vec<(u32, f64)>> = HashMap::new();
        let mut offsets = vec![0.0f64; k];
        for (pc_idx, pc) in model.pcs.iter().enumerate() {
            for &(orig, loading) in &pc.loadings {
                let pos = *kept_pos.get(&orig).ok_or_else(|| {
                    LsspcaError::config(format!("PC {} loads unknown feature {orig}", pc_idx + 1))
                })?;
                let weight = if opts.normalize {
                    let s = model.kept_stds[pos];
                    if s > 0.0 {
                        loading / s
                    } else {
                        // constant feature: centered value is identically 0
                        0.0
                    }
                } else {
                    loading
                };
                if opts.center {
                    offsets[pc_idx] += weight * model.kept_means[pos];
                }
                index
                    .entry(orig as u32)
                    .or_default()
                    .push((pc_idx as u32, weight));
            }
        }
        let neg_offsets = offsets.iter().map(|&o| if o == 0.0 { 0.0 } else { -o }).collect();
        // Flatten the per-feature lists into one arena, ascending feature
        // order. Entry order within a feature is preserved.
        let mut feats: Vec<u32> = index.keys().copied().collect();
        feats.sort_unstable();
        let mut spans = HashMap::with_capacity(feats.len());
        let mut entries = Vec::with_capacity(index.values().map(Vec::len).sum());
        for f in feats {
            let list = &index[&f];
            spans.insert(f, (entries.len() as u32, list.len() as u32));
            entries.extend_from_slice(list);
        }
        Ok(Scorer { k, n_features: model.n_features, spans, entries, neg_offsets, opts })
    }

    /// Number of components K.
    pub fn num_pcs(&self) -> usize {
        self.k
    }

    /// Original-space feature count the model was trained on.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Options the scorer was compiled with.
    pub fn options(&self) -> ScoreOptions {
        self.opts
    }

    /// Distinct words with a nonzero loading on some PC (the inverted
    /// index's key count). Exposed for `/metrics`.
    pub fn index_terms(&self) -> usize {
        self.spans.len()
    }

    /// Word→PC weight postings held in the index arena. Exposed for
    /// `/metrics`.
    pub fn index_entries(&self) -> usize {
        self.entries.len()
    }

    /// Score one document (sorted `(word_id_0based, count)` pairs) into
    /// `out` (length K). Word ids outside the model's feature range are
    /// an error (dimension mismatch, not a zero score).
    pub fn score_into(&self, words: &[(u32, f64)], out: &mut [f64]) -> Result<(), LsspcaError> {
        assert_eq!(out.len(), self.k);
        out.copy_from_slice(&self.neg_offsets);
        for &(w, c) in words {
            if w as usize >= self.n_features {
                return Err(LsspcaError::numeric(format!(
                    "word id {w} out of range for model with n={}",
                    self.n_features
                )));
            }
            if let Some(&(start, len)) = self.spans.get(&w) {
                let span = &self.entries[start as usize..(start + len) as usize];
                for &(pc, weight) in span {
                    out[pc as usize] += weight * c;
                }
            }
        }
        Ok(())
    }

    /// Allocating convenience wrapper around [`score_into`](Self::score_into).
    pub fn score(&self, words: &[(u32, f64)]) -> Result<Vec<f64>, LsspcaError> {
        let mut out = vec![0.0; self.k];
        self.score_into(words, &mut out)?;
        Ok(out)
    }

    /// Top-k component indices by decreasing score, ties broken toward
    /// the lower PC index (deterministic assignment). `top` is taken as
    /// at least 1 and at most K.
    pub fn top_pcs(scores: &[f64], top: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let take = match top {
            0 => 1usize.min(scores.len()),
            t => t.min(scores.len()),
        };
        idx.truncate(take);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, ModelPc};

    fn tiny_model() -> Model {
        // n = 10, kept = {2, 5, 7}, two PCs
        Model {
            corpus_name: "tiny".into(),
            num_docs: 4,
            n_features: 10,
            vocab_hash: 0,
            seed: 0,
            elim_lambda: 0.1,
            kept: vec![2, 5, 7],
            kept_means: vec![1.0, 0.5, 2.0],
            kept_stds: vec![2.0, 1.0, 4.0],
            kept_words: vec!["a".into(), "b".into(), "c".into()],
            pcs: vec![
                ModelPc {
                    lambda: 0.3,
                    phi: 1.0,
                    explained_variance: 1.0,
                    loadings: vec![(2, 0.8), (5, -0.6)],
                },
                ModelPc {
                    lambda: 0.3,
                    phi: 0.5,
                    explained_variance: 0.5,
                    loadings: vec![(7, 1.0)],
                },
            ],
        }
    }

    #[test]
    fn raw_projection() {
        let s = Scorer::new(&tiny_model(), ScoreOptions { center: false, normalize: false })
            .unwrap();
        // doc: word 2 ×3, word 5 ×1, word 9 ×2 (off-support → no effect)
        let scores = s.score(&[(2, 3.0), (5, 1.0), (9, 2.0)]).unwrap();
        assert_eq!(scores.len(), 2);
        assert!((scores[0] - (0.8 * 3.0 - 0.6 * 1.0)).abs() < 1e-15);
        assert_eq!(scores[1], 0.0);
    }

    #[test]
    fn centering_subtracts_mean_projection() {
        let s = Scorer::new(&tiny_model(), ScoreOptions { center: true, normalize: false })
            .unwrap();
        // centered score of the mean document must be 0 on every PC:
        // x = μ on the kept set
        let scores = s.score(&[(2, 1.0), (5, 0.5), (7, 2.0)]).unwrap();
        for sc in scores {
            assert!(sc.abs() < 1e-12, "{sc}");
        }
    }

    #[test]
    fn normalization_divides_by_std() {
        let s = Scorer::new(&tiny_model(), ScoreOptions { center: false, normalize: true })
            .unwrap();
        let scores = s.score(&[(2, 2.0)]).unwrap();
        assert!((scores[0] - 0.8 / 2.0 * 2.0).abs() < 1e-15);
    }

    #[test]
    fn zero_std_feature_scores_zero() {
        let mut m = tiny_model();
        m.kept_stds[0] = 0.0;
        let s = Scorer::new(&m, ScoreOptions { center: true, normalize: true }).unwrap();
        let scores = s.score(&[(2, 100.0)]).unwrap();
        assert_eq!(scores[0], 0.0);
    }

    #[test]
    fn uncentered_empty_doc_scores_positive_zero() {
        // offsets are stored pre-negated; a zero offset must stay +0.0
        // so CSV/JSON never render "-0"
        let s = Scorer::new(&tiny_model(), ScoreOptions { center: false, normalize: false })
            .unwrap();
        for sc in s.score(&[]).unwrap() {
            assert_eq!(sc.to_bits(), 0.0f64.to_bits(), "{sc}");
        }
    }

    #[test]
    fn out_of_range_word_is_an_error() {
        let s = Scorer::new(&tiny_model(), ScoreOptions::default()).unwrap();
        let e = s.score(&[(10, 1.0)]).unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
    }

    #[test]
    fn top_pcs_deterministic_ties() {
        assert_eq!(Scorer::top_pcs(&[1.0, 3.0, 3.0, 2.0], 2), vec![1, 2]);
        assert_eq!(Scorer::top_pcs(&[0.0, 0.0], 1), vec![0]);
        // top larger than K clamps
        assert_eq!(Scorer::top_pcs(&[1.0, 2.0], 5), vec![1, 0]);
    }

    #[test]
    fn deterministic_bitwise_repeat() {
        let s = Scorer::new(&tiny_model(), ScoreOptions { center: true, normalize: true })
            .unwrap();
        let doc = [(2u32, 3.0), (5, 2.0), (7, 1.0)];
        let a = s.score(&doc).unwrap();
        let b = s.score(&doc).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
