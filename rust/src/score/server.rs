//! `lsspca serve` — a zero-dependency HTTP/1.1 scoring server.
//!
//! Built directly on [`std::net::TcpListener`] with the repo's own
//! bounded channel as the connection queue: one acceptor thread feeds a
//! fixed pool of connection-handler threads (the `serve.pool` knob), so
//! a slow client occupies one worker, never the acceptor, and the queue
//! applies backpressure under overload: when every worker is busy *and*
//! the queue is full, the acceptor sheds load with an immediate
//! `503 Service Unavailable` + `Retry-After` instead of stalling, so
//! health checks keep getting answers. Accepted sockets carry a
//! read/write timeout (`serve.timeout_secs`, 0 = none) so a stuck
//! client cannot pin a pool worker forever. Every response carries
//! `Connection: close` — one request per connection keeps the handler
//! loop trivially robust, and the OS connection setup cost is dwarfed by
//! scoring at the payload sizes involved.
//!
//! Routes (JSON in/out):
//!
//! - `GET /healthz` — liveness + model identity.
//! - `GET /topics` — the K sparse PCs with words and loadings (the
//!   paper's topic tables, as an API).
//! - `POST /score` — project one document: `{"words": [[id, count],
//!   ...]}` (0-based original-space ids) and/or `{"terms": {"word":
//!   count, ...}}`; optional `"top": k`. Terms not in the model's kept
//!   set have zero weight on every PC and are reported in
//!   `unknown_terms` rather than silently dropped.
//!
//! Request bodies are size-capped and parse through the depth-limited
//! [`crate::util::json`] parser; malformed input gets a 4xx JSON error,
//! never a worker panic.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::LsspcaError;
use crate::model::Model;
use crate::score::scorer::Scorer;
use crate::stream::{bounded, TrySendError};
use crate::util::json::{arr_f64, obj, Json};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub addr: String,
    /// Connection-handler threads.
    pub pool: usize,
    /// Maximum accepted request-body size in bytes.
    pub max_body_bytes: usize,
    /// Read/write timeout on accepted sockets, in seconds (0 = none).
    pub timeout_secs: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".into(),
            pool: 4,
            max_body_bytes: 1 << 20,
            timeout_secs: 10,
        }
    }
}

/// A bound (not yet running) server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    opts: ServeOptions,
}

struct ServerState {
    model: Model,
    scorer: Scorer,
    /// word string → original feature index, for `terms` payloads.
    term_index: HashMap<String, usize>,
    shutdown: AtomicBool,
    addr: SocketAddr,
}

/// Cloneable handle to stop a running server (used by tests and signal
/// handlers; `shutdown` is idempotent).
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServerState>,
}

impl ServerHandle {
    /// Request shutdown and unblock the acceptor with a dummy connection.
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept(); a failed connect is fine (listener
        // may already be gone).
        let _ = TcpStream::connect(self.state.addr);
    }
}

impl Server {
    /// Bind the listener and compile the routing state. Failures are
    /// [`LsspcaError::Serve`].
    pub fn bind(model: Model, scorer: Scorer, opts: ServeOptions) -> Result<Server, LsspcaError> {
        if opts.pool == 0 {
            return Err(LsspcaError::serve("serve.pool must be >= 1"));
        }
        let listener = TcpListener::bind(&opts.addr)
            .map_err(|e| LsspcaError::serve(format!("bind {}: {e}", opts.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| LsspcaError::serve(format!("local_addr: {e}")))?;
        let term_index = model
            .kept
            .iter()
            .zip(&model.kept_words)
            .map(|(&orig, w)| (w.clone(), orig))
            .collect();
        let state = Arc::new(ServerState {
            model,
            scorer,
            term_index,
            shutdown: AtomicBool::new(false),
            addr,
        });
        Ok(Server { listener, state, opts })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// A shutdown handle.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { state: Arc::clone(&self.state) }
    }

    /// Accept connections until [`ServerHandle::shutdown`] is called.
    /// Blocks the calling thread; handlers run on `opts.pool` workers.
    pub fn run(self) -> Result<(), LsspcaError> {
        let Server { listener, state, opts } = self;
        crate::info!(
            "serving model '{}' ({} PCs) on http://{} with {} workers",
            state.model.corpus_name,
            state.model.num_pcs(),
            state.addr,
            opts.pool
        );
        std::thread::scope(|scope| {
            let (tx, rx) = bounded::<TcpStream>(2 * opts.pool);
            for _ in 0..opts.pool {
                let rx = rx.clone();
                let state = Arc::clone(&state);
                let max_body = opts.max_body_bytes;
                let timeout_secs = opts.timeout_secs;
                scope.spawn(move || {
                    while let Some(stream) = rx.recv() {
                        handle_connection(stream, &state, max_body, timeout_secs);
                    }
                });
            }
            drop(rx);
            for incoming in listener.incoming() {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match incoming {
                    Ok(stream) => match tx.try_send(stream) {
                        Ok(()) => {}
                        // Queue full: every worker busy and the backlog at
                        // capacity. Shed the connection with a retryable
                        // 503 instead of blocking the acceptor behind it.
                        Err(TrySendError::Full(mut stream)) => {
                            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
                            let body = obj(vec![(
                                "error",
                                Json::Str("server overloaded; retry shortly".into()),
                            )])
                            .to_string();
                            let _ = write_response_with(
                                &mut stream,
                                503,
                                "Retry-After: 1\r\n",
                                &body,
                            );
                        }
                        Err(TrySendError::Closed(_)) => break, // all workers gone
                    },
                    Err(e) => {
                        crate::warn_!("accept error: {e}");
                    }
                }
            }
            tx.close();
        });
        Ok(())
    }
}

/// Bind and run in one call (the `lsspca serve` entrypoint).
pub fn serve(model: Model, scorer: Scorer, opts: ServeOptions) -> Result<(), LsspcaError> {
    Server::bind(model, scorer, opts)?.run()
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

fn handle_connection(stream: TcpStream, state: &ServerState, max_body: usize, timeout_secs: u64) {
    // A stuck client must not pin a pool worker forever (0 = no timeout).
    if timeout_secs > 0 {
        let t = Duration::from_secs(timeout_secs);
        let _ = stream.set_read_timeout(Some(t));
        let _ = stream.set_write_timeout(Some(t));
    }
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let (status, body) = match read_request(&mut reader, max_body) {
        Ok(req) => route(&req, state),
        Err(e) => (400, obj(vec![("error", Json::Str(e))])),
    };
    let _ = write_response(&mut out, status, &body.to_string());
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// Hard cap on one request's head (request line + headers). The body has
/// its own `max_body` cap; without this, a client streaming bytes with no
/// newline would grow `read_line`'s String without bound.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// `read_line` with a byte budget: errors once the cumulative head size
/// exceeds [`MAX_HEAD_BYTES`] instead of buffering indefinitely.
fn read_head_line(
    reader: &mut BufReader<TcpStream>,
    budget: &mut usize,
    what: &str,
) -> Result<String, String> {
    let mut line = String::new();
    let n = reader
        .take(*budget as u64 + 1)
        .read_line(&mut line)
        .map_err(|e| format!("read {what}: {e}"))?;
    if n > *budget {
        return Err(format!("request head too large (> {MAX_HEAD_BYTES} bytes)"));
    }
    *budget -= n;
    Ok(line)
}

fn read_request(reader: &mut BufReader<TcpStream>, max_body: usize) -> Result<Request, String> {
    let mut budget = MAX_HEAD_BYTES;
    let line = read_head_line(reader, &mut budget, "request line")?;
    let mut parts = line.split_ascii_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let target = parts.next().ok_or("missing request target")?.to_string();
    // ignore query string; route on the path only
    let path = target.split('?').next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    loop {
        let h = read_head_line(reader, &mut budget, "header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad Content-Length '{}'", value.trim()))?;
            }
        }
    }
    if content_length > max_body {
        return Err(format!("request body too large ({content_length} > {max_body} bytes)"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| format!("read body: {e}"))?;
    Ok(Request { method, path, body })
}

fn write_response(out: &mut impl Write, status: u16, body: &str) -> std::io::Result<()> {
    write_response_with(out, status, "", body)
}

/// [`write_response`] with extra raw headers (each `\r\n`-terminated) —
/// the 503 overload path adds `Retry-After` this way.
fn write_response_with(
    out: &mut impl Write,
    status: u16,
    extra_headers: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Error",
    };
    write!(
        out,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n{extra_headers}\r\n{body}",
        body.len()
    )?;
    out.flush()
}

fn route(req: &Request, state: &ServerState) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (
            200,
            obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(state.model.corpus_name.clone())),
                ("pcs", Json::Num(state.model.num_pcs() as f64)),
                ("kept", Json::Num(state.model.kept.len() as f64)),
                ("n_features", Json::Num(state.model.n_features as f64)),
            ]),
        ),
        ("GET", "/topics") => (200, topics_json(&state.model)),
        ("POST", "/score") => score_route(req, state),
        ("GET", "/score") => {
            (405, obj(vec![("error", Json::Str("POST a JSON document to /score".into()))]))
        }
        _ => (404, obj(vec![("error", Json::Str(format!("no route for {}", req.path)))])),
    }
}

fn topics_json(model: &Model) -> Json {
    let topics: Vec<Json> = model
        .pcs
        .iter()
        .enumerate()
        .map(|(k, pc)| {
            let words: Vec<Json> = pc
                .loadings
                .iter()
                .map(|&(idx, w)| {
                    obj(vec![
                        ("word", Json::Str(model.word_of(idx))),
                        ("index", Json::Num(idx as f64)),
                        ("loading", Json::Num(w)),
                    ])
                })
                .collect();
            obj(vec![
                ("pc", Json::Num((k + 1) as f64)),
                ("lambda", Json::Num(pc.lambda)),
                ("phi", Json::Num(pc.phi)),
                ("explained_variance", Json::Num(pc.explained_variance)),
                ("words", Json::Arr(words)),
            ])
        })
        .collect();
    obj(vec![("topics", Json::Arr(topics))])
}

fn score_route(req: &Request, state: &ServerState) -> (u16, Json) {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return (400, obj(vec![("error", Json::Str("body is not utf-8".into()))])),
    };
    let payload = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            let msg = format!("bad JSON: {}", e.message());
            return (400, obj(vec![("error", Json::Str(msg))]));
        }
    };
    let mut words: Vec<(u32, f64)> = Vec::new();
    let mut unknown_terms = 0u64;
    let mut saw_input = false;
    if let Some(ws) = payload.get("words") {
        saw_input = true;
        let Some(items) = ws.as_array() else {
            return (400, obj(vec![("error", Json::Str("\"words\" must be an array".into()))]));
        };
        for item in items {
            let pair = item.as_array().unwrap_or(&[]);
            let (Some(id), Some(count)) =
                (pair.first().and_then(Json::as_f64), pair.get(1).and_then(Json::as_f64))
            else {
                return (
                    400,
                    obj(vec![(
                        "error",
                        Json::Str("\"words\" entries must be [id, count] pairs".into()),
                    )]),
                );
            };
            if !(id.fract() == 0.0 && id >= 0.0 && id < u32::MAX as f64) || !count.is_finite() {
                return (
                    400,
                    obj(vec![(
                        "error",
                        Json::Str(format!("invalid word entry [{id}, {count}]")),
                    )]),
                );
            }
            words.push((id as u32, count));
        }
    }
    if let Some(terms) = payload.get("terms") {
        saw_input = true;
        let Json::Obj(pairs) = terms else {
            return (400, obj(vec![("error", Json::Str("\"terms\" must be an object".into()))]));
        };
        // Duplicate keys: last occurrence wins, matching `Json::get`'s
        // lookup semantics (scoring both would double-count the term).
        let mut last_at: HashMap<&str, usize> = HashMap::with_capacity(pairs.len());
        for (i, (term, _)) in pairs.iter().enumerate() {
            last_at.insert(term.as_str(), i);
        }
        for (i, (term, count)) in pairs.iter().enumerate() {
            if last_at[term.as_str()] != i {
                continue; // superseded by a later duplicate
            }
            let Some(c) = count.as_f64().filter(|c| c.is_finite()) else {
                return (
                    400,
                    obj(vec![("error", Json::Str(format!("bad count for term '{term}'")))]),
                );
            };
            match state.term_index.get(term) {
                Some(&orig) => words.push((orig as u32, c)),
                // outside the kept set every PC weight is exactly 0, so
                // the score is unaffected; report instead of dropping
                None => unknown_terms += 1,
            }
        }
    }
    if !saw_input {
        return (
            400,
            obj(vec![(
                "error",
                Json::Str(
                    "provide \"words\": [[id, count], ...] and/or \"terms\": {word: count}".into(),
                ),
            )]),
        );
    }
    let top = payload
        .get("top")
        .and_then(Json::as_f64)
        .map(|t| t.max(1.0) as usize)
        .unwrap_or(1);
    // Canonicalize to sorted word order (stable, so equal ids keep their
    // payload order): f64 addition is order-sensitive, and the bitwise
    // agreement with batch/in-memory scoring assumes docword ordering.
    words.sort_by_key(|&(w, _)| w);
    match state.scorer.score(&words) {
        Ok(scores) => {
            let tops: Vec<Json> = Scorer::top_pcs(&scores, top)
                .into_iter()
                .map(|p| Json::Num((p + 1) as f64))
                .collect();
            (
                200,
                obj(vec![
                    ("scores", arr_f64(&scores)),
                    ("top_pcs", Json::Arr(tops)),
                    ("unknown_terms", Json::Num(unknown_terms as f64)),
                ]),
            )
        }
        Err(e) => (400, obj(vec![("error", Json::Str(e.message().to_string()))])),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPc;
    use crate::score::scorer::ScoreOptions;

    fn test_model() -> Model {
        Model {
            corpus_name: "srv-test".into(),
            num_docs: 10,
            n_features: 100,
            vocab_hash: 0,
            seed: 1,
            elim_lambda: 0.2,
            kept: vec![3, 8, 15],
            kept_means: vec![0.0, 0.0, 0.0],
            kept_stds: vec![1.0, 1.0, 1.0],
            kept_words: vec!["alpha".into(), "beta".into(), "gamma".into()],
            pcs: vec![
                ModelPc {
                    lambda: 0.5,
                    phi: 1.0,
                    explained_variance: 1.0,
                    loadings: vec![(3, 0.6), (8, 0.8)],
                },
                ModelPc {
                    lambda: 0.5,
                    phi: 0.7,
                    explained_variance: 0.7,
                    loadings: vec![(15, 1.0)],
                },
            ],
        }
    }

    fn state() -> ServerState {
        let model = test_model();
        let scorer = Scorer::new(&model, ScoreOptions { center: false, normalize: false }).unwrap();
        let term_index = model
            .kept
            .iter()
            .zip(&model.kept_words)
            .map(|(&orig, w)| (w.clone(), orig))
            .collect();
        let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
        ServerState { model, scorer, term_index, shutdown: AtomicBool::new(false), addr }
    }

    fn post_score(body: &str) -> (u16, Json) {
        let req = Request {
            method: "POST".into(),
            path: "/score".into(),
            body: body.as_bytes().to_vec(),
        };
        route(&req, &state())
    }

    #[test]
    fn score_by_words() {
        let (code, v) = post_score(r#"{"words": [[3, 2], [15, 1]], "top": 2}"#);
        assert_eq!(code, 200, "{v:?}");
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 1.2).abs() < 1e-12);
        assert!((scores[1].as_f64().unwrap() - 1.0).abs() < 1e-12);
        let tops = v.get("top_pcs").unwrap().as_array().unwrap();
        assert_eq!(tops[0].as_f64(), Some(1.0));
        assert_eq!(tops[1].as_f64(), Some(2.0));
    }

    #[test]
    fn score_by_terms_counts_unknown() {
        let (code, v) = post_score(r#"{"terms": {"alpha": 1, "nosuchword": 3}}"#);
        assert_eq!(code, 200, "{v:?}");
        assert_eq!(v.get("unknown_terms").unwrap().as_f64(), Some(1.0));
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn duplicate_terms_last_occurrence_wins() {
        // must match Json::get's last-wins lookup, not double-count
        let (code, v) = post_score(r#"{"terms": {"alpha": 1, "alpha": 2}}"#);
        assert_eq!(code, 200, "{v:?}");
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert!((scores[0].as_f64().unwrap() - 0.6 * 2.0).abs() < 1e-12, "{scores:?}");
    }

    #[test]
    fn bad_payloads_rejected() {
        for body in [
            "not json",
            "{}",
            r#"{"words": 5}"#,
            r#"{"words": [[1]]}"#,
            r#"{"words": [[-1, 2]]}"#,
            r#"{"words": [[1.5, 2]]}"#,
            r#"{"terms": [1]}"#,
            r#"{"words": [[999, 1]]}"#, // id ≥ n_features → scorer error
        ] {
            let (code, v) = post_score(body);
            assert_eq!(code, 400, "{body} -> {v:?}");
            assert!(v.get("error").is_some());
        }
    }

    #[test]
    fn overload_response_is_retryable_503() {
        let mut buf: Vec<u8> = Vec::new();
        let body =
            obj(vec![("error", Json::Str("server overloaded; retry shortly".into()))]).to_string();
        write_response_with(&mut buf, 503, "Retry-After: 1\r\n", &body).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("\r\nRetry-After: 1\r\n"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
        let (head, got_body) = text.split_once("\r\n\r\n").unwrap();
        assert_eq!(got_body, body);
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, got_body.len());
    }

    #[test]
    fn routes() {
        let st = state();
        let get = |path: &str| {
            route(&Request { method: "GET".into(), path: path.into(), body: vec![] }, &st)
        };
        let (code, v) = get("/healthz");
        assert_eq!(code, 200);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("pcs").unwrap().as_f64(), Some(2.0));
        let (code, v) = get("/topics");
        assert_eq!(code, 200);
        let topics = v.get("topics").unwrap().as_array().unwrap();
        assert_eq!(topics.len(), 2);
        assert_eq!(
            topics[0].get("words").unwrap().as_array().unwrap()[1]
                .get("word")
                .unwrap()
                .as_str(),
            Some("beta")
        );
        assert_eq!(get("/nope").0, 404);
        assert_eq!(get("/score").0, 405);
    }
}
