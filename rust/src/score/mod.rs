//! The inference half of the system: project new bag-of-words documents
//! onto a trained sparse-PCA [`Model`](crate::model::Model).
//!
//! The paper's end product is a set of sparse PCs that organize a corpus
//! in a user-interpretable way; this module is what makes them *usable*
//! downstream (Luss & d'Aspremont use exactly this projection for
//! clustering and feature selection):
//!
//! - [`scorer`] — the core sparse dot-product projection: O(doc nnz ·
//!   avg PCs per word) per document, independent of the vocabulary size.
//! - [`batch`] — stream a docword file through sharded workers and write
//!   per-document scores + top-k topic assignments as CSV,
//!   deterministically for any thread count.
//!
//! Online serving lives in [`crate::serve`] (event-loop HTTP server,
//! multi-model registry, hot reload, `/metrics`); the old
//! `score::server` names are re-exported here, deprecated, for source
//! compatibility.

pub mod batch;
pub mod scorer;

pub use batch::{
    score_file, score_file_observed, score_stream, score_stream_observed, BatchOptions, BatchStats,
};
pub use scorer::{ScoreOptions, Scorer};
#[allow(deprecated)]
pub use crate::serve::{serve, ServeOptions};
pub use crate::serve::Server;
