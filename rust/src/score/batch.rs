//! Batch scoring: stream a docword corpus through the scorer and write
//! one CSV row per document.
//!
//! The stream is consumed in chunks (the same [`ChunkSource`] abstraction
//! the training passes use); within a chunk the per-document projections
//! run on [`crate::util::parallel::par_map_indexed`] workers and are
//! written back in document order, so the output file is **byte-identical
//! for any thread count** — the same determinism contract as the training
//! side. Scores are formatted with Rust's shortest-roundtrip `f64`
//! Display, so parsing a CSV cell back yields the bitwise-identical f64
//! the in-memory scorer produced.
//!
//! CSV schema (`top` = requested assignment depth):
//!
//! ```text
//! doc_id,pc1,...,pcK,top_pcs
//! 17,0.25,-1.5,...,"3;1"
//! ```
//!
//! `doc_id` is 1-based to match the UCI docword ids; `top_pcs` lists the
//! top-`top` component ids (1-based) by decreasing score, `;`-separated.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use crate::error::LsspcaError;
use crate::score::scorer::Scorer;
use crate::session::{NoopProgress, Progress, ProgressUpdate, Stage, StageGuard};
use crate::stream::{ChunkSource, FileSource};
use crate::util::timer::Timer;

/// Options for a batch scoring pass.
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// Worker threads per chunk (0 = all cores, 1 = serial).
    pub threads: usize,
    /// Documents per streamed chunk.
    pub chunk_docs: usize,
    /// Top-k assignment depth (clamped to [1, K]).
    pub top: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { threads: 1, chunk_docs: 2048, top: 1 }
    }
}

/// Statistics from a completed batch pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Documents scored.
    pub docs: u64,
    /// `(word, count)` pairs read.
    pub nnz: u64,
    /// Wall time of the pass.
    pub seconds: f64,
}

impl BatchStats {
    /// Throughput (guarded against zero elapsed time).
    pub fn docs_per_sec(&self) -> f64 {
        self.docs as f64 / self.seconds.max(1e-12)
    }
}

/// Render one document's CSV row (no trailing newline).
fn row(
    doc_id: usize,
    scorer: &Scorer,
    words: &[(u32, f64)],
    top: usize,
) -> Result<String, LsspcaError> {
    let scores = scorer.score(words)?;
    let mut line = String::with_capacity(16 * (scores.len() + 2));
    let _ = write!(line, "{}", doc_id + 1);
    for s in &scores {
        let _ = write!(line, ",{s}");
    }
    let tops: Vec<String> =
        Scorer::top_pcs(&scores, top).into_iter().map(|p| (p + 1).to_string()).collect();
    let _ = write!(line, ",\"{}\"", tops.join(";"));
    Ok(line)
}

/// Score every document of `source`, writing CSV to `out`.
pub fn score_stream<S: ChunkSource>(
    source: &mut S,
    scorer: &Scorer,
    opts: BatchOptions,
    out: &mut dyn std::io::Write,
) -> Result<BatchStats, LsspcaError> {
    score_stream_observed(source, scorer, opts, out, &NoopProgress)
}

/// [`score_stream`] with a [`Progress`] observer: emits
/// [`Stage::Score`] began/advanced (per chunk: docs + nnz)/finished
/// events, so callers can watch a long batch pass the same way they
/// watch training stages. The observer never changes the output — the
/// CSV stays byte-identical for any observer and thread count.
pub fn score_stream_observed<S: ChunkSource>(
    source: &mut S,
    scorer: &Scorer,
    opts: BatchOptions,
    out: &mut dyn std::io::Write,
    progress: &dyn Progress,
) -> Result<BatchStats, LsspcaError> {
    if source.num_features() != scorer.n_features() {
        return Err(LsspcaError::numeric(format!(
            "dimension mismatch: corpus has W={} features, model was trained with n={}",
            source.num_features(),
            scorer.n_features()
        )));
    }
    let t = Timer::start();
    // RAII pairing: stage_finished fires even when a write errors out.
    let guard = StageGuard::begin(progress, Stage::Score);
    let top = opts.top.clamp(1, scorer.num_pcs());
    let mut header = String::from("doc_id");
    for k in 0..scorer.num_pcs() {
        let _ = write!(header, ",pc{}", k + 1);
    }
    header.push_str(",top_pcs\n");
    let io_err = |e: std::io::Error| LsspcaError::io(format!("write csv: {e}"));
    out.write_all(header.as_bytes()).map_err(io_err)?;
    let mut stats = BatchStats::default();
    while let Some(chunk) = source.next_chunk(opts.chunk_docs.max(1))? {
        let (docs, nnz) = (chunk.docs.len() as u64, chunk.total_nnz() as u64);
        stats.docs += docs;
        stats.nnz += nnz;
        let lines = crate::util::parallel::par_map_indexed(opts.threads, chunk.docs.len(), |i| {
            let d = &chunk.docs[i];
            row(d.id, scorer, &d.words, top)
        });
        for line in lines {
            let line = line?;
            out.write_all(line.as_bytes()).map_err(io_err)?;
            out.write_all(b"\n").map_err(io_err)?;
        }
        progress.stage_advanced(Stage::Score, ProgressUpdate { docs, nnz });
    }
    out.flush().map_err(|e| LsspcaError::io(format!("flush csv: {e}")))?;
    stats.seconds = t.secs();
    guard.finish();
    Ok(stats)
}

/// Score a docword file (optionally `.gz`) to a CSV file.
pub fn score_file(
    input: &Path,
    scorer: &Scorer,
    opts: BatchOptions,
    out_path: &Path,
) -> Result<BatchStats, LsspcaError> {
    score_file_observed(input, scorer, opts, out_path, &NoopProgress)
}

/// [`score_file`] with a [`Progress`] observer (see
/// [`score_stream_observed`]).
pub fn score_file_observed(
    input: &Path,
    scorer: &Scorer,
    opts: BatchOptions,
    out_path: &Path,
    progress: &dyn Progress,
) -> Result<BatchStats, LsspcaError> {
    let mut src = FileSource::open(input)?;
    if let Some(dir) = out_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| LsspcaError::io_at(dir, format!("mkdir: {e}")))?;
        }
    }
    let f = std::fs::File::create(out_path)
        .map_err(|e| LsspcaError::io_at(out_path, format!("create csv: {e}")))?;
    let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
    let stats = score_stream_observed(&mut src, scorer, opts, &mut w, progress)?;
    w.flush().map_err(|e| LsspcaError::io_at(out_path, format!("flush csv: {e}")))?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusSpec, SynthCorpus};
    use crate::model::{Model, ModelPc};
    use crate::score::scorer::ScoreOptions;
    use crate::stream::SynthSource;

    fn model_for(corpus: &SynthCorpus) -> Model {
        // Hand-built 2-PC model over the first two planted topics.
        let t0 = &corpus.topic_word_ids[0];
        let t1 = &corpus.topic_word_ids[1];
        let kept: Vec<usize> = t0.iter().chain(t1.iter()).copied().collect();
        let nk = kept.len();
        Model {
            corpus_name: "batch-test".into(),
            num_docs: corpus.spec.num_docs as u64,
            n_features: corpus.spec.vocab_size,
            vocab_hash: 0,
            seed: corpus.seed,
            elim_lambda: 0.5,
            kept_means: vec![0.1; nk],
            kept_stds: vec![1.0; nk],
            kept_words: kept.iter().map(|&i| corpus.vocab.word(i)).collect(),
            pcs: vec![
                ModelPc {
                    lambda: 0.4,
                    phi: 1.0,
                    explained_variance: 1.0,
                    loadings: t0.iter().map(|&i| (i, 0.5)).collect(),
                },
                ModelPc {
                    lambda: 0.4,
                    phi: 0.8,
                    explained_variance: 0.8,
                    loadings: t1.iter().map(|&i| (i, 0.5)).collect(),
                },
            ],
            kept,
        }
    }

    #[test]
    fn csv_identical_for_any_thread_count() {
        let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(150, 1500), 31);
        let scorer = Scorer::new(&model_for(&corpus), ScoreOptions::default()).unwrap();
        let mut outputs = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut buf = Vec::new();
            let opts = BatchOptions { threads, chunk_docs: 37, top: 2 };
            let stats =
                score_stream(&mut SynthSource::new(&corpus), &scorer, opts, &mut buf).unwrap();
            assert_eq!(stats.docs, 150);
            outputs.push(buf);
        }
        assert_eq!(outputs[0], outputs[1]);
        assert_eq!(outputs[0], outputs[2]);
    }

    #[test]
    fn csv_rows_match_in_memory_scores_bitwise() {
        let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(40, 1500), 32);
        let scorer = Scorer::new(&model_for(&corpus), ScoreOptions::default()).unwrap();
        let mut buf = Vec::new();
        score_stream(
            &mut SynthSource::new(&corpus),
            &scorer,
            BatchOptions::default(),
            &mut buf,
        )
        .unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "doc_id,pc1,pc2,top_pcs");
        for (d, line) in lines.enumerate() {
            let cells: Vec<&str> = line.split(',').collect();
            assert_eq!(cells[0], (d + 1).to_string());
            let want = scorer.score(&corpus.generate_doc(d)).unwrap();
            for (k, w) in want.iter().enumerate() {
                let got: f64 = cells[1 + k].parse().unwrap();
                assert_eq!(got.to_bits(), w.to_bits(), "doc {d} pc {k}");
            }
        }
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(10, 1500), 33);
        let mut model = model_for(&corpus);
        model.n_features = 999_999; // model trained on a different vocab size
        let scorer = Scorer::new(&model, ScoreOptions::default()).unwrap();
        let mut buf = Vec::new();
        let e = score_stream(
            &mut SynthSource::new(&corpus),
            &scorer,
            BatchOptions::default(),
            &mut buf,
        )
        .unwrap_err();
        assert!(matches!(e, LsspcaError::Numeric { .. }));
        assert!(e.to_string().contains("dimension mismatch"), "{e}");
    }

    #[test]
    fn file_roundtrip() {
        let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(25, 1500), 34);
        let scorer = Scorer::new(&model_for(&corpus), ScoreOptions::default()).unwrap();
        let mut dw = std::env::temp_dir();
        dw.push(format!("lsspca_batch_{}.txt.gz", std::process::id()));
        corpus.write_docword(&dw).unwrap();
        let csv = dw.with_extension("csv");
        let stats = score_file(&dw, &scorer, BatchOptions::default(), &csv).unwrap();
        assert_eq!(stats.docs, 25);
        let text = std::fs::read_to_string(&csv).unwrap();
        assert_eq!(text.lines().count(), 26); // header + one per doc
        std::fs::remove_file(&dw).ok();
        std::fs::remove_file(dw.with_extension("vocab")).ok();
        std::fs::remove_file(&csv).ok();
    }
}
