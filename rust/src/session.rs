//! Staged, resumable pipeline sessions — the crate's primary library
//! API.
//!
//! The paper's central practical claim (§2.1 + §4) is that one expensive
//! streaming pass — per-feature moments plus safe elimination — is
//! **λ-independent** and therefore amortizes across many cheap solves at
//! different `(λ, K)`. [`Session`] makes that structure first-class: the
//! pipeline's stages are separate, individually cached calls, so a
//! server can stream a corpus once and re-solve per request without
//! touching the docword file again.
//!
//! ```text
//! SessionBuilder ── build() ──▶ Session
//!   session.stream()     → &CorpusStats       (variance pass, checkpointable)
//!   session.eliminate(k) → &EliminationPlan   (Thm 2.1 at λ̂ for target k)
//!   session.reduce()     → &ReducedCorpus     (covariance operator: dense /
//!                                              gram / disk / auto-planned)
//!   session.fit(λ, K)    → FitResult          (λ-search or fixed-λ solves,
//!                                              rank-K deflation, model)
//! ```
//!
//! Each stage runs its prerequisites on demand (`fit` alone is a full
//! one-shot run) and caches its result; a second `fit` at a new `(λ, K)`
//! reuses the streamed, eliminated, reduced corpus and performs **zero
//! docword reads**, returning PCs bitwise-identical to a fresh one-shot
//! run with the same parameters (pinned by `rust/tests/session_api.rs`).
//! [`crate::coordinator::Pipeline::run`] is now a thin compatibility
//! wrapper over this type.
//!
//! Progress is observable: attach a [`Progress`] implementation with
//! [`SessionBuilder::observer`] to receive stage began/advanced/finished
//! events (documents and nonzeros streamed, per chunk) and per-probe
//! λ-search evaluations. Observers never change results — only what you
//! can watch.
//!
//! # Example: build → stream → fit → warm re-fit
//!
//! ```
//! use lsspca::session::{LambdaSpec, Session};
//!
//! let mut session = Session::builder()
//!     .synthetic("nytimes")
//!     .synth_size(300, 1200)
//!     .max_reduced(32)
//!     .bca_sweeps(4)
//!     .build()
//!     .unwrap();
//!
//! // Stage 1 explicitly (the stats are reusable across every fit):
//! let docs = session.stream().unwrap().docs;
//! assert_eq!(docs, 300);
//!
//! // λ-search for one cardinality-5 PC:
//! let fit = session.fit(LambdaSpec::search(5, 2), 1).unwrap();
//! assert_eq!(fit.components.len(), 1);
//! let lambda = fit.components[0].lambda;
//!
//! // Warm re-fit at a fixed λ: no re-streaming, same reduced operator.
//! let refit = session.fit(LambdaSpec::Fixed(lambda), 1).unwrap();
//! assert_eq!(refit.components[0].lambda, lambda);
//! ```

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::PipelineConfig;
use crate::coordinator::{
    choose_elimination, disk_row_cache_mb, plan_backend, search_with_engine_observed,
    ComponentReport, MemoryPlan,
};
use crate::corpus::{CorpusSpec, SynthCorpus};
use crate::cov::{covariance_pass, gram_pass, reduced_csr_pass};
use crate::cov_disk::DiskGramCov;
use crate::covop::{CovOp, DenseCov, GramCov};
use crate::data::docword::DocChunk;
use crate::data::shardcache::{self, ShardCacheKey};
use crate::data::Vocab;
use crate::elim::SafeElimination;
use crate::engine::{Engine, NativeEngine};
#[cfg(feature = "xla")]
use crate::engine::XlaEngine;
use crate::error::LsspcaError;
use crate::incr::{
    chain_digest, drift_gate, AppendReport, CachedCsr, ChainSource, IncrState, LimitSource,
    ReplaySource,
};
use crate::model::Model;
use crate::moments::FeatureVariances;
use crate::solver::bca::BcaOptions;
use crate::solver::deflate::{DeflatedCov, Scheme};
use crate::solver::lambda::{LambdaEval, LambdaSearchOptions, LambdaSearchResult};
use crate::stream::{
    resumable_variance_pass, variance_pass, ChunkSource, FileSource, StreamOptions, SynthSource,
};
use crate::util::timer::{Profiler, Timer};

// ---------------------------------------------------------------------------
// Progress observers
// ---------------------------------------------------------------------------

/// The pipeline stages a [`Progress`] observer is notified about.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Pass 1: streamed per-feature variances ([`Session::stream`]).
    Stream,
    /// Safe feature elimination ([`Session::eliminate`]).
    Eliminate,
    /// Pass 2: reduced covariance operator assembly ([`Session::reduce`]).
    Reduce,
    /// λ-search + BCA + deflation ([`Session::fit`]).
    Fit,
    /// Batch scoring ([`crate::score::score_stream_observed`]).
    Score,
}

impl Stage {
    /// Lowercase stage label for logs and progress lines.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Stream => "stream",
            Stage::Eliminate => "eliminate",
            Stage::Reduce => "reduce",
            Stage::Fit => "fit",
            Stage::Score => "score",
        }
    }
}

/// One incremental progress report within a stage: how much corpus the
/// increment covered. For streamed stages an update fires once per
/// document chunk; `nnz` (stored `(word, count)` pairs) is the
/// I/O-proportional unit.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProgressUpdate {
    /// Documents processed in this increment.
    pub docs: u64,
    /// `(word, count)` pairs processed in this increment.
    pub nnz: u64,
}

/// Observer for pipeline progress. All methods have empty defaults —
/// implement only what you care about. Observers are shared across
/// worker threads (`Send + Sync`) and must not assume any particular
/// calling thread; events for one session arrive in order. Observing
/// never changes results.
pub trait Progress: Send + Sync {
    /// A stage started running. A stage whose result is already cached
    /// *in the session* (e.g. a second `stream()` call) emits no events
    /// at all; an *on-disk* cache hit inside a live run (variance
    /// checkpoint, verified shard cache) still fires began/finished,
    /// with no `advanced` events in between.
    fn stage_began(&self, stage: Stage) {
        let _ = stage;
    }

    /// Incremental progress within a stage — for streamed stages, one
    /// event per document chunk read from the corpus.
    fn stage_advanced(&self, stage: Stage, update: ProgressUpdate) {
        let _ = (stage, update);
    }

    /// A stage finished, with its wall-clock seconds. Fires exactly
    /// once per `stage_began` — **including when the stage fails** (the
    /// session pairs the events through an RAII guard), so observers
    /// may safely open spinners/timers on began and close on finished.
    fn stage_finished(&self, stage: Stage, seconds: f64) {
        let _ = (stage, seconds);
    }

    /// λ-grid progress: one cardinality-search evaluation for component
    /// `component` (0-based), in deterministic fold order.
    fn lambda_evaluated(&self, component: usize, eval: &LambdaEval) {
        let _ = (component, eval);
    }
}

/// The default observer: ignores every event.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopProgress;

impl Progress for NoopProgress {}

/// Progress printer to stderr (the CLI's `--progress` switch). Prints
/// began/finished lines per stage, a running docs/nnz total every few
/// chunks, and each λ-search evaluation.
#[derive(Debug, Default)]
pub struct StderrProgress {
    docs: AtomicU64,
    nnz: AtomicU64,
    updates: AtomicU64,
}

impl StderrProgress {
    /// A fresh printer with zeroed counters.
    pub fn new() -> StderrProgress {
        StderrProgress::default()
    }
}

impl Progress for StderrProgress {
    fn stage_began(&self, stage: Stage) {
        self.docs.store(0, Ordering::Relaxed);
        self.nnz.store(0, Ordering::Relaxed);
        self.updates.store(0, Ordering::Relaxed);
        eprintln!("[{}] started", stage.name());
    }

    fn stage_advanced(&self, stage: Stage, update: ProgressUpdate) {
        let docs = self.docs.fetch_add(update.docs, Ordering::Relaxed) + update.docs;
        let nnz = self.nnz.fetch_add(update.nnz, Ordering::Relaxed) + update.nnz;
        // every 8th chunk keeps the output bounded on big corpora
        if self.updates.fetch_add(1, Ordering::Relaxed) % 8 == 0 {
            eprintln!("[{}] {docs} docs, {nnz} nnz", stage.name());
        }
    }

    fn stage_finished(&self, stage: Stage, seconds: f64) {
        eprintln!("[{}] done in {seconds:.2}s", stage.name());
    }

    fn lambda_evaluated(&self, component: usize, eval: &LambdaEval) {
        eprintln!(
            "[fit] PC{} probe λ={:.4} → card={} φ={:.4}",
            component + 1,
            eval.lambda,
            eval.cardinality,
            eval.phi
        );
    }
}

/// Thread-safe counting observer: tallies events per stage. Useful for
/// instrumentation and tests — `rust/tests/session_api.rs` uses it to
/// pin that warm re-fits perform **zero** corpus reads.
#[derive(Debug, Default)]
pub struct CountingProgress {
    began: [AtomicU64; 5],
    advanced: [AtomicU64; 5],
    finished: [AtomicU64; 5],
    docs: [AtomicU64; 5],
    lambda_evals: AtomicU64,
}

impl CountingProgress {
    /// A fresh counter set.
    pub fn new() -> CountingProgress {
        CountingProgress::default()
    }

    fn slot(stage: Stage) -> usize {
        match stage {
            Stage::Stream => 0,
            Stage::Eliminate => 1,
            Stage::Reduce => 2,
            Stage::Fit => 3,
            Stage::Score => 4,
        }
    }

    /// `stage_began` events seen for a stage.
    pub fn began(&self, stage: Stage) -> u64 {
        self.began[Self::slot(stage)].load(Ordering::SeqCst)
    }

    /// `stage_advanced` events seen for a stage — for streamed stages,
    /// the number of corpus chunk reads.
    pub fn reads(&self, stage: Stage) -> u64 {
        self.advanced[Self::slot(stage)].load(Ordering::SeqCst)
    }

    /// `stage_finished` events seen for a stage.
    pub fn finished(&self, stage: Stage) -> u64 {
        self.finished[Self::slot(stage)].load(Ordering::SeqCst)
    }

    /// Total documents reported for a stage.
    pub fn docs(&self, stage: Stage) -> u64 {
        self.docs[Self::slot(stage)].load(Ordering::SeqCst)
    }

    /// Total λ-search evaluations observed.
    pub fn lambda_evals(&self) -> u64 {
        self.lambda_evals.load(Ordering::SeqCst)
    }

    /// Corpus chunk reads across *all* streamed stages — the "did
    /// anything touch the docword file" counter.
    pub fn corpus_reads(&self) -> u64 {
        self.reads(Stage::Stream) + self.reads(Stage::Reduce) + self.reads(Stage::Score)
    }
}

impl Progress for CountingProgress {
    fn stage_began(&self, stage: Stage) {
        self.began[Self::slot(stage)].fetch_add(1, Ordering::SeqCst);
    }

    fn stage_advanced(&self, stage: Stage, update: ProgressUpdate) {
        self.advanced[Self::slot(stage)].fetch_add(1, Ordering::SeqCst);
        self.docs[Self::slot(stage)].fetch_add(update.docs, Ordering::SeqCst);
    }

    fn stage_finished(&self, stage: Stage, _seconds: f64) {
        self.finished[Self::slot(stage)].fetch_add(1, Ordering::SeqCst);
    }

    fn lambda_evaluated(&self, _component: usize, _eval: &LambdaEval) {
        self.lambda_evals.fetch_add(1, Ordering::SeqCst);
    }
}

/// A [`ChunkSource`] wrapper that reports every chunk to a [`Progress`]
/// observer — how streamed stages (and observed batch scoring) account
/// for their corpus reads. Purely pass-through otherwise.
pub struct ObservedSource<'a, S: ChunkSource> {
    inner: &'a mut S,
    observer: &'a dyn Progress,
    stage: Stage,
}

impl<'a, S: ChunkSource> ObservedSource<'a, S> {
    /// Wrap `inner`, reporting chunks under `stage`.
    pub fn new(inner: &'a mut S, observer: &'a dyn Progress, stage: Stage) -> Self {
        ObservedSource { inner, observer, stage }
    }
}

impl<S: ChunkSource> ChunkSource for ObservedSource<'_, S> {
    fn num_features(&self) -> usize {
        self.inner.num_features()
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        let chunk = self.inner.next_chunk(max_docs)?;
        if let Some(c) = &chunk {
            self.observer.stage_advanced(
                self.stage,
                ProgressUpdate { docs: c.docs.len() as u64, nnz: c.total_nnz() as u64 },
            );
        }
        Ok(chunk)
    }
}

/// RAII pairing of `stage_began`/`stage_finished`: fires `began` on
/// construction and guarantees `finished` fires exactly once — via
/// [`StageGuard::finish`] on success, or on drop when the stage errors
/// out early. This is what keeps the [`Progress`] pairing contract true
/// on every `?` path.
pub(crate) struct StageGuard<'a> {
    observer: &'a dyn Progress,
    stage: Stage,
    timer: Timer,
    done: bool,
}

impl<'a> StageGuard<'a> {
    /// Fire `stage_began` and start the stage clock.
    pub(crate) fn begin(observer: &'a dyn Progress, stage: Stage) -> StageGuard<'a> {
        observer.stage_began(stage);
        StageGuard { observer, stage, timer: Timer::start(), done: false }
    }

    /// Fire `stage_finished` now; returns the stage's wall seconds.
    pub(crate) fn finish(mut self) -> f64 {
        let seconds = self.timer.secs();
        self.done = true;
        self.observer.stage_finished(self.stage, seconds);
        seconds
    }
}

impl Drop for StageGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.observer.stage_finished(self.stage, self.timer.secs());
        }
    }
}

// ---------------------------------------------------------------------------
// Stage results
// ---------------------------------------------------------------------------

/// Result of [`Session::stream`]: the corpus' identity and its streamed
/// per-feature variance profile — everything λ-independent.
#[derive(Clone, Debug)]
pub struct CorpusStats {
    /// Corpus name (synthetic preset) or input path.
    pub corpus_name: String,
    /// Streamed per-feature moments (the Fig 2 variance profile).
    pub variances: FeatureVariances,
    /// Documents streamed.
    pub docs: u64,
    /// `(word, count)` pairs streamed (0 on a checkpoint hit).
    pub nnz: u64,
    /// Wall seconds of the pass (≈0 on a checkpoint hit).
    pub seconds: f64,
    /// Whether the variances came from a checkpoint instead of a pass.
    pub from_checkpoint: bool,
    /// The training vocabulary (empty ⇒ synthesized `wNNNNN` labels).
    pub vocab: Vocab,
    /// FNV digest of the corpus identity — keys the variance checkpoint
    /// and the covariance shard cache.
    pub corpus_digest: u64,
}

impl CorpusStats {
    /// Original vocabulary size n.
    pub fn vocab_size(&self) -> usize {
        self.variances.variance.len()
    }
}

/// Result of [`Session::eliminate`]: the Thm 2.1 elimination chosen for
/// a target cardinality.
#[derive(Clone, Debug)]
pub struct EliminationPlan {
    /// The elimination: λ̂, kept features, reduction bookkeeping.
    pub elim: SafeElimination,
    /// Whether `max_reduced` bound the reduction.
    pub capped: bool,
    /// The target cardinality the λ̂ was chosen for.
    pub target_card: usize,
    /// Wall seconds to choose the elimination.
    pub seconds: f64,
}

/// Result of [`Session::reduce`]: the reduced covariance operator Σ̂,
/// behind whichever backend the configuration (or memory planner)
/// selected. This is the object every [`Session::fit`] reuses.
pub struct ReducedCorpus {
    cov: Box<dyn CovOp>,
    /// The backend serving Σ̂: `"dense"`, `"gram"` or `"disk"`.
    pub backend: String,
    /// The memory planner's decision, when `cov.backend = "auto"`.
    pub memory_plan: Option<MemoryPlan>,
    /// Wall seconds to assemble (≈ shard-verify time on a cache hit).
    pub seconds: f64,
}

impl ReducedCorpus {
    /// The reduced covariance operator.
    pub fn cov(&self) -> &dyn CovOp {
        self.cov.as_ref()
    }

    /// Reduced problem size n̂.
    pub fn n(&self) -> usize {
        self.cov.n()
    }
}

impl std::fmt::Debug for ReducedCorpus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReducedCorpus")
            .field("n", &self.cov.n())
            .field("backend", &self.backend)
            .field("memory_plan", &self.memory_plan)
            .field("seconds", &self.seconds)
            .finish()
    }
}

/// Result of one [`Session::fit`]: K sparse PCs with reporting
/// metadata, the rendered topic table, and the serving model artifact.
#[derive(Debug)]
pub struct FitResult {
    /// One entry per extracted sparse PC.
    pub components: Vec<ComponentReport>,
    /// Markdown topic table (the paper's Tables 1–2 format).
    pub topic_table: String,
    /// The serving artifact (not written to disk — call
    /// [`Model::save`], or let `Pipeline::run` honor `[model]
    /// save_path`).
    pub model: Model,
    /// Wall seconds of this fit.
    pub seconds: f64,
}

/// How [`Session::fit`] picks λ for each component.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaSpec {
    /// Cardinality-targeted bisection search (the paper's §4 workflow):
    /// accept a PC with `|card − target_card| ≤ slack`.
    Search {
        /// Desired PC cardinality (paper: 5).
        target_card: usize,
        /// Accepted distance from the target.
        slack: usize,
    },
    /// Solve at this fixed penalty λ — one point of a λ grid. The solve
    /// is bitwise-identical to the same λ landing as a search probe.
    Fixed(f64),
}

impl LambdaSpec {
    /// Shorthand for [`LambdaSpec::Search`].
    pub fn search(target_card: usize, slack: usize) -> LambdaSpec {
        LambdaSpec::Search { target_card, slack }
    }

    /// The search a configuration's `solver.target_card` /
    /// `solver.card_slack` describe — what `Pipeline::run` uses.
    pub fn from_config(cfg: &PipelineConfig) -> LambdaSpec {
        LambdaSpec::Search { target_card: cfg.target_card, slack: cfg.card_slack }
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Typed, programmatic construction of a [`Session`] — the library
/// alternative to a TOML [`PipelineConfig`] (which remains one way to
/// seed a builder, via [`SessionBuilder::from_config`]).
///
/// Every setter maps to one documented config knob;
/// [`SessionBuilder::build`] validates the combination exactly like
/// `PipelineConfig::validate`, so a builder cannot produce a session a
/// config file could not.
pub struct SessionBuilder {
    cfg: PipelineConfig,
    observer: Arc<dyn Progress>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

impl SessionBuilder {
    /// Start from the default configuration (synthetic NYTimes preset).
    pub fn new() -> SessionBuilder {
        SessionBuilder { cfg: PipelineConfig::default(), observer: Arc::new(NoopProgress) }
    }

    /// Seed every knob from an existing configuration (e.g. a parsed
    /// TOML file), then override via the typed setters.
    pub fn from_config(cfg: PipelineConfig) -> SessionBuilder {
        SessionBuilder { cfg, observer: Arc::new(NoopProgress) }
    }

    /// Train from a docword file (UCI bag-of-words, `.gz` supported).
    /// Clears the synthetic-corpus selection.
    pub fn input(mut self, path: impl Into<String>) -> Self {
        self.cfg.input = path.into();
        self
    }

    /// Train from a synthetic preset (`"nytimes"` | `"pubmed"`) instead
    /// of a file.
    pub fn synthetic(mut self, preset: &str) -> Self {
        self.cfg.input = String::new();
        self.cfg.synth_preset = preset.to_string();
        self
    }

    /// Synthetic corpus size overrides (0 = preset default).
    pub fn synth_size(mut self, docs: usize, vocab: usize) -> Self {
        self.cfg.synth_docs = docs;
        self.cfg.synth_vocab = vocab;
        self
    }

    /// Corpus / generator seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Directory for variance checkpoints and the covariance shard
    /// cache (empty = disabled).
    pub fn cache_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.cache_dir = dir.into();
        self
    }

    /// Moment-pass worker threads.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// Worker *processes* for the distributed corpus pass (0 =
    /// disabled; > 0 needs a cache dir) — see [`crate::dist`].
    pub fn dist_workers(mut self, workers: usize) -> Self {
        self.cfg.dist_workers = workers;
        self
    }

    /// Target documents per shard for the distributed pass (0 = auto).
    pub fn dist_shard_docs(mut self, docs: u64) -> Self {
        self.cfg.dist_shard_docs = docs;
        self
    }

    /// Solver-side worker threads (0 = all cores, 1 = serial).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Independent λ probes per bracketing round (1 = bisection).
    pub fn lambda_probes(mut self, probes: usize) -> Self {
        self.cfg.lambda_probes = probes;
        self
    }

    /// Documents per streamed chunk.
    pub fn chunk_docs(mut self, docs: usize) -> Self {
        self.cfg.chunk_docs = docs;
        self
    }

    /// Bounded reader→worker queue depth (backpressure).
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.cfg.queue_depth = depth;
        self
    }

    /// Default number of PCs (`Pipeline::run`'s K; [`Session::fit`]
    /// takes K explicitly).
    pub fn num_pcs(mut self, k: usize) -> Self {
        self.cfg.num_pcs = k;
        self
    }

    /// Target cardinality per PC (drives elimination λ̂ and the default
    /// λ-search).
    pub fn target_card(mut self, card: usize) -> Self {
        self.cfg.target_card = card;
        self
    }

    /// Accepted |cardinality − target| slack.
    pub fn card_slack(mut self, slack: usize) -> Self {
        self.cfg.card_slack = slack;
        self
    }

    /// Hard cap on the reduced problem size n̂.
    pub fn max_reduced(mut self, cap: usize) -> Self {
        self.cfg.max_reduced = cap;
        self
    }

    /// Covariance backend: `"dense"` | `"gram"` | `"disk"` | `"auto"`.
    pub fn cov_backend(mut self, backend: &str) -> Self {
        self.cfg.cov_backend = backend.to_string();
        self
    }

    /// Covariance-stage memory budget in MiB (0 = unlimited; drives the
    /// `"auto"` backend planner).
    pub fn memory_budget_mb(mut self, mb: usize) -> Self {
        self.cfg.memory_budget_mb = mb;
        self
    }

    /// Disk-backend shard size in MiB.
    pub fn shard_mb(mut self, mb: usize) -> Self {
        self.cfg.shard_mb = mb;
        self
    }

    /// Gram/disk-backend Σ-row cache budget in MiB.
    pub fn row_cache_mb(mut self, mb: usize) -> Self {
        self.cfg.row_cache_mb = mb;
        self
    }

    /// Maximum BCA sweeps per solve.
    pub fn bca_sweeps(mut self, sweeps: usize) -> Self {
        self.cfg.bca_sweeps = sweeps;
        self
    }

    /// Barrier ε (β = ε/n).
    pub fn epsilon(mut self, epsilon: f64) -> Self {
        self.cfg.epsilon = epsilon;
        self
    }

    /// Solver engine: `"native"` | `"xla"`.
    pub fn engine(mut self, engine: &str) -> Self {
        self.cfg.engine = engine.to_string();
        self
    }

    /// AOT-artifact directory for the `"xla"` engine.
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Deflation scheme: `"projection"` | `"hotelling"`.
    pub fn deflation(mut self, scheme: &str) -> Self {
        self.cfg.deflation = scheme.to_string();
        self
    }

    /// Compute a dual optimality certificate per component.
    pub fn certify(mut self, on: bool) -> Self {
        self.cfg.certify = on;
        self
    }

    /// Attach a [`Progress`] observer.
    pub fn observer(mut self, observer: Arc<dyn Progress>) -> Self {
        self.observer = observer;
        self
    }

    /// Validate and produce the [`Session`]. Fails with
    /// [`LsspcaError::Config`] on an invalid knob combination.
    pub fn build(self) -> Result<Session, LsspcaError> {
        self.cfg.validate()?;
        Ok(Session {
            cfg: self.cfg,
            observer: self.observer,
            prof: Profiler::new(),
            synth: None,
            stats: None,
            plan: None,
            reduced: None,
            incr: None,
        })
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// A staged, resumable pipeline run over one corpus. See the [module
/// docs](self) for the stage diagram and reuse contract.
pub struct Session {
    cfg: PipelineConfig,
    observer: Arc<dyn Progress>,
    prof: Profiler,
    synth: Option<SynthCorpus>,
    stats: Option<CorpusStats>,
    plan: Option<EliminationPlan>,
    reduced: Option<ReducedCorpus>,
    /// Incremental-corpus state (master Welford accumulator, replay
    /// store, chained digest) — present once [`Session::append`] or
    /// [`Session::refit_incremental`] has run. See [`crate::incr`].
    incr: Option<IncrState>,
}

impl Session {
    /// Start a typed [`SessionBuilder`].
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// Build directly from a validated configuration (TOML or
    /// programmatic) with no observer.
    pub fn from_config(cfg: PipelineConfig) -> Result<Session, LsspcaError> {
        SessionBuilder::from_config(cfg).build()
    }

    /// The session's configuration (immutable — build a new session to
    /// change corpus-identity knobs).
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Replace the progress observer (applies to subsequent stages).
    pub fn set_observer(&mut self, observer: Arc<dyn Progress>) {
        self.observer = observer;
    }

    /// Train-to-serve bridge: [`Session::fit`] with the session's
    /// configured λ spec and PC count, then hand the trained model to a
    /// [`crate::serve::ServerBuilder`] seeded from the same config
    /// (`[serve]` knobs, including any `models = ["name=path"]` rows).
    /// The fitted model is registered as `"session"` and made the
    /// default. Chain further builder calls, then `.build()?.run()`.
    pub fn serve(&mut self) -> Result<crate::serve::ServerBuilder, LsspcaError> {
        let lambda = LambdaSpec::from_config(&self.cfg);
        let num_pcs = self.cfg.num_pcs;
        let fit = self.fit(lambda, num_pcs)?;
        let score_opts = crate::score::scorer::ScoreOptions {
            center: self.cfg.score_center,
            normalize: self.cfg.score_normalize,
        };
        Ok(crate::serve::ServerBuilder::from_config(&self.cfg)?
            .score_options(score_opts)
            .register_model("session", fit.model)
            .default_model("session"))
    }

    /// The accumulated per-stage timing profile (same renderer as
    /// `PipelineReport::profile`).
    pub fn profile(&self) -> String {
        self.prof.report()
    }

    /// Drop every cached stage, forcing the next call to re-run from
    /// the corpus.
    pub fn reset(&mut self) {
        self.synth = None;
        self.stats = None;
        self.plan = None;
        self.reduced = None;
        self.incr = None;
    }

    /// Cached [`CorpusStats`] if [`Session::stream`] has run.
    pub fn stats(&self) -> Option<&CorpusStats> {
        self.stats.as_ref()
    }

    /// Cached [`EliminationPlan`] if [`Session::eliminate`] has run.
    pub fn elimination(&self) -> Option<&EliminationPlan> {
        self.plan.as_ref()
    }

    /// Cached [`ReducedCorpus`] if [`Session::reduce`] has run.
    pub fn reduced_corpus(&self) -> Option<&ReducedCorpus> {
        self.reduced.as_ref()
    }

    // -- stage 1: stream ----------------------------------------------------

    /// Pass 1: streamed per-feature variances (with checkpoint reuse
    /// when a cache dir is configured). Cached — repeated calls return
    /// the same stats without touching the corpus.
    pub fn stream(&mut self) -> Result<&CorpusStats, LsspcaError> {
        if self.stats.is_none() {
            self.run_stream()?;
        }
        Ok(self.stats.as_ref().expect("just streamed"))
    }

    fn run_stream(&mut self) -> Result<(), LsspcaError> {
        let cfg = self.cfg.clone();
        install_robustness(&cfg);
        let rc = resolve_corpus(&cfg)?;
        let ResolvedCorpus { synth, input_path, vocab, corpus_name, corpus_digest } = rc;
        crate::info!("pipeline start: corpus={corpus_name} engine={}", cfg.engine);
        let cache = if cfg.cache_dir.is_empty() {
            None
        } else {
            Some((
                crate::checkpoint::path_for(Path::new(&cfg.cache_dir), corpus_digest),
                corpus_digest,
            ))
        };
        // The corpus' live feature dimension, for checkpoint validation:
        // a cached file whose key collides but whose n differs must be
        // rejected up front, not panic later inside elimination.
        let expected_n: Option<usize> = match &synth {
            Some(s) => Some(s.spec.vocab_size),
            None => crate::data::docword::DocwordReader::open(&input_path)
                .ok()
                .map(|r| r.header().vocab_size),
        };
        let cached_fv = match &cache {
            Some((path, key)) => match crate::checkpoint::load(path, *key, expected_n) {
                Ok(hit) => {
                    if hit.is_some() {
                        crate::info!("variance pass: checkpoint hit at {}", path.display());
                    }
                    hit
                }
                Err(e) => {
                    crate::warn_!("ignoring bad variance checkpoint: {e}");
                    None
                }
            },
            None => None,
        };
        let obs = Arc::clone(&self.observer);
        let guard = StageGuard::begin(obs.as_ref(), Stage::Stream);
        let (fv, stats1, from_checkpoint) = match cached_fv {
            Some(fv) => {
                let stats = crate::stream::StreamStats { docs: fv.docs, ..Default::default() };
                (fv, stats, true)
            }
            None => {
                let t = Timer::start();
                let (fv, stats) = if cfg.dist_workers > 0 {
                    // `[dist] workers` shards the pass across worker
                    // processes; the dist manifest plays the job-state
                    // role, so the in-process resume machinery is
                    // bypassed — see `crate::dist`.
                    let params = dist_params(&cfg, synth.as_ref(), &input_path, corpus_digest)?;
                    crate::dist::dist_variance_pass(&params, obs.as_ref())?
                } else {
                    single_variance_pass(
                        &cfg,
                        &cache,
                        expected_n,
                        &synth,
                        corpus_digest,
                        obs.as_ref(),
                    )?
                };
                self.prof.add("variance_pass", t.secs());
                if let Some((path, key)) = &cache {
                    if let Err(e) = crate::checkpoint::save(path, *key, &fv) {
                        crate::warn_!("could not write variance checkpoint: {e}");
                    }
                }
                (fv, stats, false)
            }
        };
        let seconds = guard.finish();
        crate::info!(
            "variance pass: {} docs, {} nnz in {:.2}s",
            stats1.docs,
            stats1.nnz,
            stats1.seconds
        );
        self.synth = synth;
        self.stats = Some(CorpusStats {
            corpus_name,
            variances: fv,
            docs: stats1.docs,
            nnz: stats1.nnz,
            seconds,
            from_checkpoint,
            vocab,
            corpus_digest,
        });
        Ok(())
    }

    // -- stage 2: eliminate -------------------------------------------------

    /// Safe feature elimination (Thm 2.1) at a λ̂ chosen so the reduced
    /// problem comfortably contains a cardinality-`target_card`
    /// solution, capped at `max_reduced`. Streams first if needed.
    /// Cached per target — a different `target_card` recomputes the
    /// elimination and invalidates the reduced operator.
    pub fn eliminate(&mut self, target_card: usize) -> Result<&EliminationPlan, LsspcaError> {
        if target_card == 0 {
            return Err(LsspcaError::config("eliminate: target_card must be >= 1"));
        }
        if self.plan.as_ref().map(|p| p.target_card) != Some(target_card) {
            self.stream()?;
            let obs = Arc::clone(&self.observer);
            let guard = StageGuard::begin(obs.as_ref(), Stage::Eliminate);
            let fv = &self.stats.as_ref().expect("streamed").variances;
            let (elim, capped) = choose_elimination(fv, target_card, self.cfg.max_reduced);
            crate::info!(
                "safe elimination: λ={:.4e} keeps n̂={} of n={} ({}x reduction{})",
                elim.lambda,
                elim.reduced(),
                elim.original,
                elim.reduction_factor() as u64,
                if capped { ", capped" } else { "" }
            );
            if elim.reduced() == 0 {
                // guard drop still fires stage_finished
                return Err(LsspcaError::numeric(
                    "elimination removed every feature; lower solver.target λ̂",
                ));
            }
            let seconds = guard.finish();
            self.prof.add("elimination", seconds);
            // a new elimination invalidates any reduced operator
            self.reduced = None;
            self.plan = Some(EliminationPlan { elim, capped, target_card, seconds });
        }
        Ok(self.plan.as_ref().expect("just eliminated"))
    }

    // -- stage 3: reduce ----------------------------------------------------

    /// Pass 2: assemble the reduced covariance operator on the
    /// configured backend (`dense` / `gram` / `disk`, or `auto` via the
    /// memory-budget planner). Runs [`Session::stream`] and
    /// [`Session::eliminate`] (at the configured `target_card`) if
    /// needed. Cached — every subsequent [`Session::fit`] reuses it
    /// with zero corpus reads.
    pub fn reduce(&mut self) -> Result<&ReducedCorpus, LsspcaError> {
        if self.reduced.is_none() {
            if self.plan.is_none() {
                let target = self.cfg.target_card;
                self.eliminate(target)?;
            }
            self.run_reduce()?;
        }
        Ok(self.reduced.as_ref().expect("just reduced"))
    }

    fn run_reduce(&mut self) -> Result<(), LsspcaError> {
        // An incremental session assembles the operator from its cached
        // reduced CSR + replay store instead of re-streaming.
        if self.incr.is_some() {
            return self.run_reduce_incremental();
        }
        let cfg = self.cfg.clone();
        let opts = stream_opts(&cfg);
        let input_path = PathBuf::from(&cfg.input);
        // --- memory-budget planner -----------------------------------------
        // `auto` resolves to a concrete backend from footprint estimates
        // derived off the variance pass; explicit backends pass through.
        let (backend, memory_plan) = {
            let stats = self.stats.as_ref().expect("stream ran");
            let plan = self.plan.as_ref().expect("eliminate ran");
            if cfg.cov_backend == "auto" {
                let p = plan_backend(&stats.variances, &plan.elim, &cfg);
                crate::info!("memory planner: {}", p.describe());
                (p.backend.clone(), Some(p))
            } else {
                (cfg.cov_backend.clone(), None)
            }
        };
        let elim = self.plan.as_ref().expect("eliminate ran").elim.clone();
        let corpus_digest = self.stats.as_ref().expect("stream ran").corpus_digest;
        let obs = Arc::clone(&self.observer);
        let guard = StageGuard::begin(obs.as_ref(), Stage::Reduce);
        let mut profbuf: Vec<(&'static str, f64)> = Vec::new();
        let synth = self.synth.as_ref();

        let cov: Box<dyn CovOp> = match backend.as_str() {
            "disk" => {
                let dir = if cfg.cache_dir.is_empty() {
                    // No configured dir: fall back to a stable
                    // *per-user* location under the system temp dir so
                    // the cache still reuses across runs without two
                    // users fighting over one world-writable path.
                    let user = std::env::var("USER")
                        .or_else(|_| std::env::var("USERNAME"))
                        .unwrap_or_else(|_| "default".into());
                    std::env::temp_dir().join(format!("lsspca_shards_{user}"))
                } else {
                    PathBuf::from(&cfg.cache_dir)
                };
                // The fallback dir may sit under a shared tmp; keep it
                // private to this user where the platform supports it.
                if cfg.cache_dir.is_empty() {
                    make_private_dir(&dir);
                }
                let key = ShardCacheKey {
                    corpus_digest,
                    elim_digest: shardcache::elim_digest(&elim),
                };
                // A hit is only a hit once every shard verifies: the
                // operator cannot return errors mid-solve, so a corrupt
                // or truncated shard must be caught (and the cache
                // rebuilt) here, not hours into BCA.
                let opened = match shardcache::open(&dir, &key) {
                    Ok(Some(man)) => {
                        let t = Timer::start();
                        let verified = shardcache::verify_shards(&dir, &man, cfg.threads);
                        profbuf.push(("shard_verify", t.secs()));
                        match verified {
                            Ok(()) => {
                                crate::info!(
                                    "shard cache hit: {} shards, nnz={} at {}",
                                    man.shards.len(),
                                    man.nnz,
                                    dir.display()
                                );
                                Some(man)
                            }
                            Err(e) => {
                                crate::warn_!("rebuilding shard cache: {e}");
                                None
                            }
                        }
                    }
                    Ok(None) => None,
                    Err(e) => {
                        crate::warn_!("rebuilding shard cache: {e}");
                        None
                    }
                };
                let man = match opened {
                    Some(man) => man,
                    None => {
                        let t = Timer::start();
                        let dist = if cfg.dist_workers > 0 {
                            let r = dist_reduce(
                                &cfg,
                                synth,
                                &input_path,
                                corpus_digest,
                                &elim,
                                obs.as_ref(),
                            )?;
                            Some(r)
                        } else {
                            None
                        };
                        let (csr, stats2) = match (dist, synth) {
                            (Some(r), _) => Ok(r),
                            (None, Some(s)) => {
                                let mut inner = SynthSource::new(s);
                                let mut src =
                                    ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                                reduced_csr_pass(&mut src, &elim, opts)
                            }
                            (None, None) => {
                                let policy = record_policy(&cfg, &input_path, corpus_digest)?;
                                let mut inner =
                                    FileSource::open_with_policy(&input_path, policy)?;
                                let r = {
                                    let mut src = ObservedSource::new(
                                        &mut inner,
                                        obs.as_ref(),
                                        Stage::Reduce,
                                    );
                                    reduced_csr_pass(&mut src, &elim, opts)
                                };
                                report_quarantined(&inner, "reduced-csr pass");
                                r
                            }
                        }?;
                        profbuf.push(("gram_pass", t.secs()));
                        let t = Timer::start();
                        let man = shardcache::write(
                            &dir,
                            &key,
                            &csr,
                            stats2.docs,
                            cfg.shard_mb * 1024 * 1024,
                        )?;
                        profbuf.push(("shard_write", t.secs()));
                        crate::info!(
                            "shard cache written: {} shards, nnz={} at {}",
                            man.shards.len(),
                            man.nnz,
                            dir.display()
                        );
                        man
                    }
                };
                // Cache sized against the *actual* decode wave: an
                // oversized single-column shard shrinks the row cache
                // rather than silently blowing the budget.
                let cache_mb = disk_row_cache_mb(&cfg, man.max_shard_bytes());
                let disk = DiskGramCov::new(&dir, man, cache_mb, cfg.threads);
                crate::info!(
                    "disk covariance backend: row cache {} rows ≤ {} MiB, {} worker threads",
                    disk.cache_capacity_rows(),
                    cache_mb,
                    crate::util::parallel::resolve_threads(cfg.threads)
                );
                Box::new(disk)
            }
            "gram" => {
                let t = Timer::start();
                let dist = if cfg.dist_workers > 0 {
                    let r =
                        dist_reduce(&cfg, synth, &input_path, corpus_digest, &elim, obs.as_ref())?;
                    Some(r)
                } else {
                    None
                };
                let (gram, _stats2) = match (dist, synth) {
                    (Some((csr, stats2)), _) => {
                        Ok((GramCov::new(csr, stats2.docs, cfg.row_cache_mb), stats2))
                    }
                    (None, Some(s)) => {
                        let mut inner = SynthSource::new(s);
                        let mut src = ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                        gram_pass(&mut src, &elim, opts, cfg.row_cache_mb)
                    }
                    (None, None) => {
                        let policy = record_policy(&cfg, &input_path, corpus_digest)?;
                        let mut inner = FileSource::open_with_policy(&input_path, policy)?;
                        let r = {
                            let mut src =
                                ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                            gram_pass(&mut src, &elim, opts, cfg.row_cache_mb)
                        };
                        report_quarantined(&inner, "gram pass");
                        r
                    }
                }?;
                profbuf.push(("gram_pass", t.secs()));
                crate::info!(
                    "gram pass: reduced term matrix nnz={} (row cache {} rows ≤ {} MiB)",
                    gram.nnz(),
                    gram.cache_capacity_rows(),
                    cfg.row_cache_mb
                );
                Box::new(gram)
            }
            _ => {
                let t = Timer::start();
                // Distributed dense path: replay the canonical reduced
                // CSR through a fresh accumulator — bitwise equal to a
                // `stream.workers = 1` in-process covariance pass.
                let dist = if cfg.dist_workers > 0 {
                    let r =
                        dist_reduce(&cfg, synth, &input_path, corpus_digest, &elim, obs.as_ref())?;
                    Some(r)
                } else {
                    None
                };
                let (cov, _stats2) = match (dist, synth) {
                    (Some((csr, stats2)), _) => {
                        Ok((crate::cov::covariance_from_canonical_csr(&csr, stats2.docs), stats2))
                    }
                    (None, Some(s)) => {
                        let mut inner = SynthSource::new(s);
                        let mut src = ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                        covariance_pass(&mut src, &elim, opts)
                    }
                    (None, None) => {
                        let policy = record_policy(&cfg, &input_path, corpus_digest)?;
                        let mut inner = FileSource::open_with_policy(&input_path, policy)?;
                        let r = {
                            let mut src =
                                ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                            covariance_pass(&mut src, &elim, opts)
                        };
                        report_quarantined(&inner, "covariance pass");
                        r
                    }
                }?;
                profbuf.push(("covariance_pass", t.secs()));
                Box::new(DenseCov::new(cov))
            }
        };
        let seconds = guard.finish();
        for (name, secs) in profbuf {
            self.prof.add(name, secs);
        }
        self.reduced = Some(ReducedCorpus { cov, backend, memory_plan, seconds });
        Ok(())
    }

    /// The incremental arm of [`Session::reduce`]: assemble the reduced
    /// operator from the cached reduced CSR plus the in-memory replay
    /// store. While the elimination plan holds this performs **zero**
    /// corpus reads — the cached CSR is extended with the appended
    /// documents' reduced rows (appended global ids all exceed the
    /// cached rows' ids, so concatenation equals the cold canonical
    /// finalize bitwise) and, on the disk backend, the previous shard
    /// manifest's column partition is extended in place. Only after a
    /// drift-forced re-elimination does the base corpus re-stream —
    /// capped at `base_docs` via [`LimitSource`], because in watch mode
    /// the input file has grown in place and the suffix must come from
    /// the replay store, not be double-counted.
    fn run_reduce_incremental(&mut self) -> Result<(), LsspcaError> {
        let cfg = self.cfg.clone();
        let opts = stream_opts(&cfg);
        let (backend, memory_plan) = {
            let stats = self.stats.as_ref().expect("stream ran");
            let plan = self.plan.as_ref().expect("eliminate ran");
            if cfg.cov_backend == "auto" {
                let p = plan_backend(&stats.variances, &plan.elim, &cfg);
                crate::info!("memory planner: {}", p.describe());
                (p.backend.clone(), Some(p))
            } else {
                (cfg.cov_backend.clone(), None)
            }
        };
        let elim = self.plan.as_ref().expect("eliminate ran").elim.clone();
        let elim_dig = shardcache::elim_digest(&elim);
        let stats = self.stats.as_ref().expect("stream ran");
        let total_docs = stats.docs;
        let corpus_digest = stats.corpus_digest;
        let obs = Arc::clone(&self.observer);
        let guard = StageGuard::begin(obs.as_ref(), Stage::Reduce);
        let mut profbuf: Vec<(&'static str, f64)> = Vec::new();

        // --- canonical reduced CSR: extend the cache or rebuild -------------
        let csr = {
            let incr = self.incr.as_ref().expect("incremental session");
            let reuse = incr
                .csr
                .as_ref()
                .filter(|c| c.elim_digest == elim_dig && c.docs <= total_docs);
            match reuse {
                Some(cached) => {
                    let t = Timer::start();
                    let lookup = crate::cov::reduced_lookup(&elim);
                    let mut acc = crate::cov::ReducedDocsAccum::new();
                    // Appended doc `start + i` has global id
                    // `base_docs + start + i = cached.docs + i`.
                    let start = (cached.docs - incr.base_docs) as usize;
                    for (i, words) in incr.appended[start..].iter().enumerate() {
                        acc.push_doc(cached.docs + i as u64, words, &lookup);
                    }
                    let seg = acc.finalize(elim.reduced());
                    let mut merged = cached.csr.clone();
                    let offset = *merged.indptr.last().expect("csr indptr");
                    for r in 0..seg.rows {
                        merged.indptr.push(offset + seg.indptr[r + 1]);
                    }
                    merged.indices.extend_from_slice(&seg.indices);
                    merged.values.extend_from_slice(&seg.values);
                    merged.rows += seg.rows;
                    profbuf.push(("csr_extend", t.secs()));
                    crate::info!(
                        "incremental reduce: extended cached CSR by {} rows (zero corpus reads)",
                        seg.rows
                    );
                    merged
                }
                None => {
                    let t = Timer::start();
                    let replay =
                        ReplaySource::new(&incr.appended, incr.base_docs, incr.num_features());
                    let (csr, _s2) = match self.synth.as_ref() {
                        Some(s) => {
                            let mut inner = SynthSource::new(s);
                            let base =
                                ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                            let mut chain = ChainSource::new(
                                LimitSource::new(base, incr.base_docs),
                                replay,
                            )?;
                            reduced_csr_pass(&mut chain, &elim, opts)?
                        }
                        None => {
                            let input_path = PathBuf::from(&cfg.input);
                            let policy = record_policy(&cfg, &input_path, corpus_digest)?;
                            let mut inner = FileSource::open_with_policy(&input_path, policy)?;
                            let r = {
                                let base =
                                    ObservedSource::new(&mut inner, obs.as_ref(), Stage::Reduce);
                                let mut chain = ChainSource::new(
                                    LimitSource::new(base, incr.base_docs),
                                    replay,
                                )?;
                                reduced_csr_pass(&mut chain, &elim, opts)?
                            };
                            report_quarantined(&inner, "incremental reduce");
                            r
                        }
                    };
                    profbuf.push(("gram_pass", t.secs()));
                    csr
                }
            }
        };

        // --- backend assembly from the owned canonical CSR ------------------
        let mut new_shard_key: Option<ShardCacheKey> = None;
        let cov: Box<dyn CovOp> = match backend.as_str() {
            "disk" => {
                let dir = if cfg.cache_dir.is_empty() {
                    let user = std::env::var("USER")
                        .or_else(|_| std::env::var("USERNAME"))
                        .unwrap_or_else(|_| "default".into());
                    std::env::temp_dir().join(format!("lsspca_shards_{user}"))
                } else {
                    PathBuf::from(&cfg.cache_dir)
                };
                if cfg.cache_dir.is_empty() {
                    make_private_dir(&dir);
                }
                let key = ShardCacheKey { corpus_digest, elim_digest: elim_dig };
                let opened = match shardcache::open(&dir, &key) {
                    Ok(Some(man)) => {
                        let t = Timer::start();
                        let verified = shardcache::verify_shards(&dir, &man, cfg.threads);
                        profbuf.push(("shard_verify", t.secs()));
                        match verified {
                            Ok(()) => Some(man),
                            Err(e) => {
                                crate::warn_!("rebuilding shard cache: {e}");
                                None
                            }
                        }
                    }
                    Ok(None) => None,
                    Err(e) => {
                        crate::warn_!("rebuilding shard cache: {e}");
                        None
                    }
                };
                let man = match opened {
                    Some(man) => man,
                    None => {
                        let t = Timer::start();
                        // Extend the previous append's shards under the
                        // chained key: same column partition, untouched
                        // column payloads byte-identical.
                        let prev = self
                            .incr
                            .as_ref()
                            .expect("incremental session")
                            .last_shard_key
                            .filter(|k| *k != key);
                        let extended = prev.and_then(|old_key| {
                            let old = match shardcache::open(&dir, &old_key) {
                                Ok(Some(m)) if m.nhat == csr.cols => m,
                                _ => return None,
                            };
                            match shardcache::extend(&dir, &old, &key, &csr, total_docs) {
                                Ok(man) => {
                                    crate::info!(
                                        "shard cache extended: {} shards reused their \
                                         column partition",
                                        man.shards.len()
                                    );
                                    Some(man)
                                }
                                Err(e) => {
                                    crate::warn_!("shard extend failed, rewriting: {e}");
                                    None
                                }
                            }
                        });
                        let man = match extended {
                            Some(man) => man,
                            None => shardcache::write(
                                &dir,
                                &key,
                                &csr,
                                total_docs,
                                cfg.shard_mb * 1024 * 1024,
                            )?,
                        };
                        profbuf.push(("shard_write", t.secs()));
                        man
                    }
                };
                let cache_mb = disk_row_cache_mb(&cfg, man.max_shard_bytes());
                let disk = DiskGramCov::new(&dir, man, cache_mb, cfg.threads);
                new_shard_key = Some(key);
                Box::new(disk)
            }
            "gram" => {
                let t = Timer::start();
                let gram = GramCov::new(csr.clone(), total_docs, cfg.row_cache_mb);
                profbuf.push(("gram_build", t.secs()));
                Box::new(gram)
            }
            _ => {
                let t = Timer::start();
                // Bitwise equal to a `stream.workers = 1` covariance
                // pass over the concatenated corpus, same as the
                // distributed dense path.
                let cov = crate::cov::covariance_from_canonical_csr(&csr, total_docs);
                profbuf.push(("covariance_fold", t.secs()));
                Box::new(DenseCov::new(cov))
            }
        };
        let seconds = guard.finish();
        for (name, secs) in profbuf {
            self.prof.add(name, secs);
        }
        let incr = self.incr.as_mut().expect("incremental session");
        incr.csr = Some(CachedCsr { csr, docs: total_docs, elim_digest: elim_dig });
        if let Some(k) = new_shard_key {
            incr.last_shard_key = Some(k);
        }
        self.reduced = Some(ReducedCorpus { cov, backend, memory_plan, seconds });
        Ok(())
    }

    // -- stage 4: fit -------------------------------------------------------

    /// Extract `num_pcs` sparse PCs from the cached reduced operator —
    /// λ-search per component ([`LambdaSpec::Search`]) or a fixed-λ
    /// solve ([`LambdaSpec::Fixed`]) — with rank-K deflation between
    /// components, exactly as `Pipeline::run` does.
    ///
    /// Every fit builds a fresh engine and deflation stack, so repeated
    /// fits are independent: a warm `fit` at `(λ, K)` returns PCs
    /// bitwise-identical to a fresh session (or `Pipeline::run`) with
    /// the same parameters, while performing **zero** corpus reads.
    pub fn fit(&mut self, lambda: LambdaSpec, num_pcs: usize) -> Result<FitResult, LsspcaError> {
        if num_pcs == 0 {
            return Err(LsspcaError::config("fit: num_pcs must be >= 1"));
        }
        if let LambdaSpec::Search { target_card, .. } = lambda {
            if target_card == 0 {
                return Err(LsspcaError::config("fit: target_card must be >= 1"));
            }
        }
        self.fit_inner(lambda, None, num_pcs)
    }

    /// The fit body behind [`Session::fit`] and the incremental warm
    /// refit. `per_component` overrides component `k`'s λ with a fixed
    /// value (a remembered λ from the previous fit) — each such solve is
    /// bitwise-identical to that λ landing as a search probe, but skips
    /// the search entirely.
    fn fit_inner(
        &mut self,
        lambda: LambdaSpec,
        per_component: Option<&[f64]>,
        num_pcs: usize,
    ) -> Result<FitResult, LsspcaError> {
        self.reduce()?;
        let cfg = self.cfg.clone();
        let obs = Arc::clone(&self.observer);
        let guard = StageGuard::begin(obs.as_ref(), Stage::Fit);
        let mut engine = make_engine(&cfg)?;
        let scheme = Scheme::parse(&cfg.deflation)
            .ok_or_else(|| LsspcaError::config("bad deflation scheme"))?;
        let mut profbuf: Vec<(&'static str, f64)> = Vec::new();
        let (components, topic_table, model) = {
            let stats = self.stats.as_ref().expect("stream ran");
            let plan = self.plan.as_ref().expect("eliminate ran");
            let reduced = self.reduced.as_ref().expect("reduce ran");
            let elim = &plan.elim;
            let vocab = &stats.vocab;
            let mut defl = DeflatedCov::new(reduced.cov());
            let mut components: Vec<ComponentReport> = Vec::new();
            for k in 0..num_pcs {
                let t = Timer::start();
                // Warm incremental refit: component k re-solves at the λ
                // the previous fit landed on, skipping the search.
                let eff = match per_component {
                    Some(l) => LambdaSpec::Fixed(l[k]),
                    None => lambda,
                };
                let bca = BcaOptions {
                    max_sweeps: cfg.bca_sweeps,
                    epsilon: cfg.epsilon,
                    tol: 1e-7,
                    // The pipeline never reads the per-sweep history, and on
                    // the gram backend each history point costs a full pass
                    // of Σ-row gathers (frob_with) per sweep.
                    track_history: false,
                    ..Default::default()
                };
                // Parallel λ-search. The probe schedule comes from config —
                // never derived from the thread count — so the numerical
                // results are identical on every machine and for every
                // `threads` setting; threads only change wall time.
                let sopts = LambdaSearchOptions {
                    target_card: match eff {
                        LambdaSpec::Search { target_card, .. } => target_card,
                        LambdaSpec::Fixed(_) => cfg.target_card,
                    },
                    slack: match eff {
                        LambdaSpec::Search { slack, .. } => slack,
                        LambdaSpec::Fixed(_) => cfg.card_slack,
                    },
                    bca,
                    probes_per_round: cfg.lambda_probes,
                    threads: cfg.threads,
                    ..Default::default()
                };
                let t_solve = Timer::start();
                let res = match eff {
                    LambdaSpec::Search { .. } => {
                        let mut on_eval = |e: &LambdaEval| obs.lambda_evaluated(k, e);
                        search_with_engine_observed(&mut *engine, &defl, &sopts, &mut on_eval)?
                    }
                    LambdaSpec::Fixed(lam) => {
                        let res = evaluate_with_engine(&mut *engine, &defl, lam, &sopts)?;
                        obs.lambda_evaluated(k, &res.trace[0]);
                        res
                    }
                };
                profbuf.push(("lambda_search+bca", t_solve.secs()));
                let words: Vec<String> = res
                    .pc
                    .support
                    .iter()
                    .map(|&r| vocab.word(elim.kept[r]))
                    .collect();
                crate::info!(
                    "PC {}: card={} λ={:.4} φ={:.4} [{}] in {:.2}s",
                    k + 1,
                    res.pc.cardinality(),
                    res.lambda,
                    res.solution.phi,
                    words.join(", "),
                    t.secs()
                );
                let explained = defl.quad_form(&res.pc.vector);
                let certificate_gap = if cfg.certify {
                    let t_cert = Timer::start();
                    // certify on the survivors of res.lambda (the solve
                    // space); the eliminated coordinates are provably zero.
                    // The certificate's eigendecompositions need an
                    // explicit matrix, so the survivor submatrix is
                    // materialized here (small: the solve space).
                    let diags: Vec<f64> = (0..defl.n()).map(|i| defl.diag(i)).collect();
                    let sub_elim = SafeElimination::apply(&diags, res.lambda, None);
                    let sub = defl.materialize(&sub_elim.kept);
                    let cert =
                        crate::solver::certificate::certify(&sub, &res.solution.z, res.lambda);
                    profbuf.push(("certificate", t_cert.secs()));
                    crate::info!(
                        "PC {} certificate: φ={:.4} ≤ {:.4} (gap {:.2e})",
                        k + 1,
                        cert.primal,
                        cert.upper_bound,
                        cert.gap
                    );
                    Some(cert.gap)
                } else {
                    None
                };
                let t_defl = Timer::start();
                defl.push(scheme, &res.pc.vector);
                profbuf.push(("deflation", t_defl.secs()));
                components.push(ComponentReport {
                    lambda: res.lambda,
                    phi: res.solution.phi,
                    explained_variance: explained,
                    words,
                    seconds: t.secs(),
                    pc: res.pc,
                    certificate_gap,
                });
            }
            let topic_table = crate::report::topic_table(
                &components.iter().map(|c| c.pc.clone()).collect::<Vec<_>>(),
                vocab,
                Some(&elim.kept),
            );
            // --- model artifact: the hand-off to `score` / `serve` ---------
            let fv = &stats.variances;
            let n_orig = fv.variance.len();
            let model = Model {
                corpus_name: stats.corpus_name.clone(),
                num_docs: stats.docs,
                n_features: n_orig,
                vocab_hash: crate::model::vocab_hash(vocab),
                seed: cfg.seed,
                elim_lambda: elim.lambda,
                kept: elim.kept.clone(),
                kept_means: elim.kept.iter().map(|&i| fv.mean[i]).collect(),
                kept_stds: elim.kept.iter().map(|&i| fv.variance[i].sqrt()).collect(),
                kept_words: elim.kept.iter().map(|&i| vocab.word(i)).collect(),
                pcs: components
                    .iter()
                    .map(|c| crate::model::ModelPc {
                        lambda: c.lambda,
                        phi: c.phi,
                        explained_variance: c.explained_variance,
                        loadings: c.pc.mapped(&elim.kept, n_orig).loadings(),
                    })
                    .collect(),
            };
            (components, topic_table, model)
        };
        let seconds = guard.finish();
        for (name, secs) in profbuf {
            self.prof.add(name, secs);
        }
        // Remember this fit's λs so the next incremental refit can take
        // the warm (fixed-λ) path; also clears the drift flag.
        if let Some(incr) = self.incr.as_mut() {
            incr.record_fit(components.iter().map(|c| c.lambda).collect());
        }
        Ok(FitResult { components, topic_table, model, seconds })
    }

    // -- incremental corpora ------------------------------------------------

    /// Fold an appended docword segment into the session — the
    /// incremental-corpus entry point (see [`crate::incr`]).
    ///
    /// `identity` fingerprints the segment (same convention as the base
    /// corpus: `"file:<path>:<len>"` or `"synth:..."`); the session's
    /// corpus digest advances to `H(digest ‖ H(identity))` **only if the
    /// whole fold succeeds** — a failed or corrupt segment leaves the
    /// session, its digest, and every digest-keyed cache untouched.
    ///
    /// The fold is chunk-aligned and merged in global chunk order, so
    /// the merged variances are bitwise-identical to a (resumable) cold
    /// pass over the concatenated corpus. The segment's documents are
    /// retained in an in-memory replay store: subsequent
    /// [`Session::reduce`]/[`Session::fit`] calls extend the reduced
    /// operator without re-reading **any** corpus bytes. After the fold,
    /// the drift gate decides whether the current elimination survives;
    /// if it fires, elimination (and everything downstream) re-runs cold
    /// on the next stage call.
    ///
    /// With a cache dir and `[robustness] job_state = true`, the fold
    /// persists resumable job state under the *chained* digest: a run
    /// killed mid-append resumes bitwise-identically.
    pub fn append<S: ChunkSource>(
        &mut self,
        source: &mut S,
        identity: &str,
    ) -> Result<AppendReport, LsspcaError> {
        self.ensure_incr()?;
        let cfg = self.cfg.clone();
        install_robustness(&cfg);
        let obs = Arc::clone(&self.observer);
        let seg_digest = crate::checkpoint::corpus_key(identity);
        let new_digest = chain_digest(self.incr.as_ref().expect("ensured").digest(), seg_digest);

        // Clone-commit: mutate a copy of the incremental state and swap
        // it in only on success, so any error below (I/O, corrupt
        // segment, feature mismatch) leaves the session unchanged.
        let mut next = self.incr.as_ref().expect("ensured").clone();

        // Resumable job state for the append fold, keyed by the chained
        // digest (so state from a different base or segment can never be
        // adopted). Any chunk count a mid-append persist recorded lies
        // strictly past the pre-append total — the first merged chunk
        // completes the pre-append tail — so the resumed fold skips
        // exactly `covered - total_pre` segment documents (they are
        // already in the master) while still replay-storing them.
        let js_path = if !cfg.cache_dir.is_empty() && cfg.robust_job_state {
            Some(crate::jobstate::path_for(Path::new(&cfg.cache_dir), new_digest))
        } else {
            None
        };
        let chunk_docs = cfg.chunk_docs as u64;
        let mut skip_folded = 0u64;
        if let Some(path) = &js_path {
            let total_pre = next.total_docs();
            match crate::jobstate::load_kind(
                path,
                new_digest,
                next.num_features(),
                chunk_docs,
                crate::jobstate::KIND_APPEND,
            ) {
                Ok(Some(js)) => {
                    let covered = js.completed_chunks * chunk_docs;
                    if js.moments.docs == covered
                        && js.completed_chunks > next.chunks_done
                        && covered >= total_pre
                    {
                        crate::info!(
                            "append: resuming from job state at chunk {} \
                             ({} docs already folded)",
                            js.completed_chunks,
                            js.moments.docs
                        );
                        skip_folded = covered - total_pre;
                        next.moments = js.moments;
                        next.chunks_done = js.completed_chunks;
                        next.tail.clear();
                    } else {
                        crate::warn_!("ignoring inconsistent append job state");
                    }
                }
                Ok(None) => {}
                Err(e) => crate::warn_!("ignoring bad job state: {e}"),
            }
        }

        let guard = StageGuard::begin(obs.as_ref(), Stage::Stream);
        let (docs, nnz) = {
            let mut src = ObservedSource::new(source, obs.as_ref(), Stage::Stream);
            match &js_path {
                Some(path) => {
                    let persist = |m: &crate::moments::FeatureMoments, done: u64| {
                        crate::jobstate::save(
                            path,
                            &crate::jobstate::JobState {
                                key: new_digest,
                                kind: crate::jobstate::KIND_APPEND,
                                chunk_docs,
                                completed_chunks: done,
                                moments: m.clone(),
                            },
                        )
                    };
                    next.append_docs(
                        &mut src,
                        cfg.robust_job_state_chunks as u64,
                        persist,
                        skip_folded,
                    )?
                }
                None => next.append_docs(&mut src, 0, |_, _| Ok(()), skip_folded)?,
            }
        };
        let fv = next.finalize_variances();
        let seconds = guard.finish();
        self.prof.add("append_fold", seconds);
        if let Some(path) = &js_path {
            if let Err(e) = crate::jobstate::remove(path) {
                crate::warn_!("could not remove job state: {e}");
            }
        }

        // Drift gate: does the current elimination survive the merge?
        let drift = match self.plan.as_ref() {
            Some(plan) => {
                let gate = drift_gate(&plan.elim, &fv, cfg.incr_drift_tol);
                if gate.fired {
                    crate::info!(
                        "append: drift gate fired (mandatory={}, max_shift={:.3e}) — \
                         re-elimination scheduled",
                        gate.mandatory,
                        gate.max_shift
                    );
                } else {
                    crate::info!(
                        "append: drift gate quiet (max_shift={:.3e} ≤ tol={:.3e}) — \
                         elimination plan reused",
                        gate.max_shift,
                        cfg.incr_drift_tol
                    );
                }
                gate.fired
            }
            // No plan yet: nothing to invalidate, the next eliminate()
            // works from the merged variances anyway.
            None => false,
        };

        // Commit.
        next.digest = new_digest;
        if drift {
            next.mark_drift();
            self.plan = None;
        }
        self.reduced = None;
        let stats = self.stats.as_mut().expect("ensured");
        stats.variances = fv;
        stats.docs = next.total_docs();
        stats.nnz = next.total_nnz();
        stats.corpus_digest = new_digest;
        stats.from_checkpoint = false;
        stats.seconds = seconds;
        crate::info!(
            "append: {docs} docs, {nnz} nnz folded in {seconds:.2}s \
             (digest {new_digest:016x}, drift={drift})"
        );
        self.incr = Some(next);
        Ok(AppendReport { docs, nnz, drift, digest: new_digest, seconds })
    }

    /// Re-fit after appends, reusing everything that is still valid.
    ///
    /// If the drift gate has stayed quiet since the last fit, each
    /// component re-solves at its previous λ (no λ-search) against the
    /// incrementally extended reduced operator — the warm path the
    /// `session_append` bench gate pins at ≪ a cold run. After a
    /// drift-forced re-elimination (or on the first call) this is a
    /// full [`Session::fit`] with the configured λ spec.
    pub fn refit_incremental(&mut self) -> Result<FitResult, LsspcaError> {
        self.ensure_incr()?;
        let lambda = LambdaSpec::from_config(&self.cfg);
        let num_pcs = self.cfg.num_pcs;
        let warm: Option<Vec<f64>> = {
            let incr = self.incr.as_ref().expect("ensured");
            (!incr.drift_since_fit() && incr.last_lambdas.len() == num_pcs)
                .then(|| incr.last_lambdas.clone())
        };
        match warm {
            Some(l) => self.fit_inner(lambda, Some(&l), num_pcs),
            None => self.fit_inner(lambda, None, num_pcs),
        }
    }

    /// Bootstrap the incremental state: one chunk-aligned pass over the
    /// base corpus that *retains* the master Welford accumulator (a
    /// variance checkpoint cannot — it only stores finalized variances,
    /// and Welford merge order matters bitwise). Overwrites the cached
    /// corpus stats with the bootstrap's (bitwise-identical) result.
    fn ensure_incr(&mut self) -> Result<(), LsspcaError> {
        if self.incr.is_some() {
            return Ok(());
        }
        let cfg = self.cfg.clone();
        install_robustness(&cfg);
        let rc = resolve_corpus(&cfg)?;
        let obs = Arc::clone(&self.observer);
        let guard = StageGuard::begin(obs.as_ref(), Stage::Stream);
        let (st, _boot_stats) = match &rc.synth {
            Some(s) => {
                let mut inner = SynthSource::new(s);
                let mut src = ObservedSource::new(&mut inner, obs.as_ref(), Stage::Stream);
                IncrState::bootstrap(&mut src, cfg.chunk_docs, rc.corpus_digest)?
            }
            None => {
                let policy = record_policy(&cfg, &rc.input_path, rc.corpus_digest)?;
                let mut inner = FileSource::open_with_policy(&rc.input_path, policy)?;
                let r = {
                    let mut src = ObservedSource::new(&mut inner, obs.as_ref(), Stage::Stream);
                    IncrState::bootstrap(&mut src, cfg.chunk_docs, rc.corpus_digest)?
                };
                report_quarantined(&inner, "incremental bootstrap");
                r
            }
        };
        let fv = st.finalize_variances();
        let seconds = guard.finish();
        self.prof.add("incr_bootstrap", seconds);
        crate::info!(
            "incremental bootstrap: {} docs, {} nnz (digest {:016x})",
            st.total_docs(),
            st.total_nnz(),
            rc.corpus_digest
        );
        // The bootstrap is authoritative for the variance profile (it
        // *is* the deterministic pass); downstream stages recompute from
        // it on demand.
        self.synth = rc.synth;
        self.plan = None;
        self.reduced = None;
        self.stats = Some(CorpusStats {
            corpus_name: rc.corpus_name,
            variances: fv,
            docs: st.total_docs(),
            nnz: st.total_nnz(),
            seconds,
            from_checkpoint: false,
            vocab: rc.vocab,
            corpus_digest: rc.corpus_digest,
        });
        self.incr = Some(st);
        Ok(())
    }
}

fn stream_opts(cfg: &PipelineConfig) -> StreamOptions {
    StreamOptions {
        workers: cfg.workers,
        chunk_docs: cfg.chunk_docs,
        queue_depth: cfg.queue_depth,
    }
}

/// A configuration's corpus, resolved: the synthetic generator (if any),
/// the training vocabulary, the display name, and the FNV digest of the
/// corpus identity that keys every cache.
struct ResolvedCorpus {
    synth: Option<SynthCorpus>,
    input_path: PathBuf,
    vocab: Vocab,
    corpus_name: String,
    corpus_digest: u64,
}

/// Resolve a configuration's corpus — shared by [`Session::run_stream`]
/// and the incremental bootstrap so both derive the identical identity
/// digest for the same knobs.
fn resolve_corpus(cfg: &PipelineConfig) -> Result<ResolvedCorpus, LsspcaError> {
    let synth: Option<SynthCorpus> = if cfg.input.is_empty() {
        let spec = CorpusSpec::preset(&cfg.synth_preset)
            .ok_or_else(|| LsspcaError::config(format!("unknown preset {}", cfg.synth_preset)))?
            .scaled(cfg.synth_docs, cfg.synth_vocab);
        Some(SynthCorpus::new(spec, cfg.seed))
    } else {
        None
    };
    let input_path = PathBuf::from(&cfg.input);
    let vocab = match &synth {
        Some(s) => s.vocab.clone(),
        None => {
            let vp = input_path.with_extension("vocab");
            if vp.exists() {
                Vocab::load(&vp)?
            } else {
                Vocab::default()
            }
        }
    };
    let corpus_name = synth
        .as_ref()
        .map(|s| s.spec.name.to_string())
        .unwrap_or_else(|| input_path.display().to_string());

    // Fingerprint the corpus identity: synthetic params, or the
    // input path + its size (cheap mtime-free invalidation). Shared
    // by the variance checkpoint and the covariance shard cache.
    let identity = match &synth {
        Some(s) => format!(
            "synth:{}:{}:{}:{}",
            s.spec.name, s.spec.num_docs, s.spec.vocab_size, s.seed
        ),
        None => {
            let len = std::fs::metadata(&input_path).map(|m| m.len()).unwrap_or(0);
            format!("file:{}:{len}", input_path.display())
        }
    };
    let corpus_digest = crate::checkpoint::corpus_key(&identity);
    Ok(ResolvedCorpus { synth, input_path, vocab, corpus_name, corpus_digest })
}

/// Install the process-wide robustness knobs from config: the
/// transient-I/O retry schedule and (if scripted) the fault-injection
/// plan. Called at the top of every streaming stage — idempotent.
fn install_robustness(cfg: &PipelineConfig) {
    crate::util::retry::set_policy(crate::util::retry::RetryPolicy {
        attempts: cfg.robust_retry_attempts as u32,
        base_delay_ms: cfg.robust_retry_base_ms,
        ..Default::default()
    });
    if !cfg.robust_faults.is_empty() {
        match crate::util::faultinject::FaultPlan::parse(&cfg.robust_faults) {
            Ok(plan) => crate::util::faultinject::install(plan),
            Err(e) => crate::warn_!("ignoring bad [robustness] faults: {e}"),
        }
    }
}

/// Log how many records a pass left in the dead-letter queue, if any.
fn report_quarantined(src: &FileSource, pass: &str) {
    let n = src.bad_records();
    if n > 0 {
        crate::warn_!("{pass}: {n} bad records quarantined (see dead-letter queue)");
    }
}

/// The in-process variance pass with optional resumable job state — the
/// `[dist] workers = 0` arm of [`Session::run_stream`].
fn single_variance_pass(
    cfg: &PipelineConfig,
    cache: &Option<(PathBuf, u64)>,
    expected_n: Option<usize>,
    synth: &Option<SynthCorpus>,
    corpus_digest: u64,
    obs: &dyn Progress,
) -> Result<(FeatureVariances, crate::stream::StreamStats), LsspcaError> {
    let opts = stream_opts(cfg);
    let input_path = PathBuf::from(&cfg.input);
    // Resumable job state: with a cache dir, the pass snapshots its
    // partial accumulators every `job_state_chunks` chunks so a killed
    // run restarts at the last completed chunk, not byte zero (see
    // `jobstate`). The load is advisory: corrupt/stale/foreign state is
    // rejected with a warning and the pass starts over.
    let job = match (cache, cfg.robust_job_state, expected_n) {
        (Some((_, key)), true, Some(n)) => {
            let js_path = crate::jobstate::path_for(Path::new(&cfg.cache_dir), *key);
            let resume = match crate::jobstate::load(&js_path, *key, n, opts.chunk_docs as u64) {
                Ok(Some(js)) => {
                    crate::info!(
                        "variance pass: resuming from job state at chunk {} \
                         ({} docs already folded)",
                        js.completed_chunks,
                        js.moments.docs
                    );
                    Some((js.moments, js.completed_chunks))
                }
                Ok(None) => None,
                Err(e) => {
                    crate::warn_!("ignoring bad job state: {e}");
                    None
                }
            };
            Some((js_path, *key, resume))
        }
        _ => None,
    };
    match job {
        None => match synth {
            Some(s) => {
                let mut inner = SynthSource::new(s);
                let mut src = ObservedSource::new(&mut inner, obs, Stage::Stream);
                variance_pass(&mut src, opts)
            }
            None => {
                let policy = record_policy(cfg, &input_path, corpus_digest)?;
                let mut inner = FileSource::open_with_policy(&input_path, policy)?;
                let r = {
                    let mut src = ObservedSource::new(&mut inner, obs, Stage::Stream);
                    variance_pass(&mut src, opts)
                };
                report_quarantined(&inner, "variance pass");
                r
            }
        },
        Some((js_path, key, resume)) => {
            let persist_every = cfg.robust_job_state_chunks as u64;
            let chunk_docs = opts.chunk_docs as u64;
            let persist = |m: &crate::moments::FeatureMoments, done: u64| {
                crate::jobstate::save(
                    &js_path,
                    &crate::jobstate::JobState {
                        key,
                        kind: crate::jobstate::KIND_VARIANCE,
                        chunk_docs,
                        completed_chunks: done,
                        moments: m.clone(),
                    },
                )
            };
            let r = match synth {
                Some(s) => {
                    let mut inner = SynthSource::new(s);
                    let mut src = ObservedSource::new(&mut inner, obs, Stage::Stream);
                    resumable_variance_pass(&mut src, opts, resume, persist_every, persist)?
                }
                None => {
                    let policy = record_policy(cfg, &input_path, corpus_digest)?;
                    let mut inner = FileSource::open_with_policy(&input_path, policy)?;
                    let r = {
                        let mut src = ObservedSource::new(&mut inner, obs, Stage::Stream);
                        resumable_variance_pass(&mut src, opts, resume, persist_every, persist)?
                    };
                    report_quarantined(&inner, "variance pass");
                    r
                }
            };
            // The pass completed: the job state has served its purpose
            // and a stale copy must not outlive it.
            if let Err(e) = crate::jobstate::remove(&js_path) {
                crate::warn_!("could not remove job state: {e}");
            }
            Ok(r)
        }
    }
}

/// Assemble the distributed-pass parameters shared by the variance and
/// reduce dispatches: the corpus identity re-encoded as a
/// [`crate::jobstate::CorpusSource`] worker processes can rebuild their
/// stream from.
fn dist_params(
    cfg: &PipelineConfig,
    synth: Option<&SynthCorpus>,
    input_path: &Path,
    corpus_digest: u64,
) -> Result<crate::dist::DistPassParams, LsspcaError> {
    let (source, num_docs, n) = match synth {
        Some(s) => (
            crate::jobstate::CorpusSource::Synth {
                preset: cfg.synth_preset.clone(),
                docs: s.spec.num_docs as u64,
                vocab: s.spec.vocab_size as u64,
                seed: s.seed,
            },
            s.spec.num_docs as u64,
            s.spec.vocab_size as u64,
        ),
        None => {
            let reader = crate::data::docword::DocwordReader::open(input_path)?;
            let hdr = reader.header();
            (
                crate::jobstate::CorpusSource::File { path: input_path.display().to_string() },
                hdr.num_docs as u64,
                hdr.vocab_size as u64,
            )
        }
    };
    let dead_letter = if cfg.robust_max_bad_records > 0 && synth.is_none() {
        Some(dead_letter_path(cfg, input_path, corpus_digest))
    } else {
        None
    };
    Ok(crate::dist::DistPassParams {
        cache_dir: PathBuf::from(&cfg.cache_dir),
        workers: cfg.dist_workers,
        shard_docs: cfg.dist_shard_docs,
        chunk_docs: cfg.chunk_docs as u64,
        key: corpus_digest,
        source,
        num_docs,
        n,
        max_bad_records: cfg.robust_max_bad_records,
        dead_letter,
        threads: cfg.workers,
    })
}

/// Run the distributed reduce pass for [`Session::run_reduce`]'s
/// backends: one canonical reduced CSR, reused by the dense / gram /
/// disk arms.
fn dist_reduce(
    cfg: &PipelineConfig,
    synth: Option<&SynthCorpus>,
    input_path: &Path,
    corpus_digest: u64,
    elim: &SafeElimination,
    obs: &dyn Progress,
) -> Result<(crate::data::CsrMatrix, crate::stream::StreamStats), LsspcaError> {
    let kept: Vec<u32> = elim.kept.iter().map(|&k| k as u32).collect();
    let params = dist_params(cfg, synth, input_path, corpus_digest)?;
    crate::dist::dist_reduced_csr_pass(&params, &kept, obs)
}

/// Build the dead-letter record policy from config. `None` (strict
/// reads) when `[robustness] max_bad_records` is 0 or the corpus is
/// synthetic — a generator cannot produce malformed lines, only a file
/// can.
pub(crate) fn record_policy(
    cfg: &PipelineConfig,
    input_path: &Path,
    corpus_digest: u64,
) -> Result<Option<crate::deadletter::RecordPolicy>, LsspcaError> {
    if cfg.robust_max_bad_records == 0 || cfg.input.is_empty() {
        return Ok(None);
    }
    let path = dead_letter_path(cfg, input_path, corpus_digest);
    let dlq = crate::deadletter::DeadLetterQueue::open(&path)?;
    Ok(Some(crate::deadletter::RecordPolicy::new(cfg.robust_max_bad_records, dlq)))
}

/// Where quarantined records go: the configured `dead_letter_path`, else
/// `deadletter_<digest>.jsonl` in the cache dir, else
/// `<input>.deadletter.jsonl` beside the corpus.
pub(crate) fn dead_letter_path(
    cfg: &PipelineConfig,
    input_path: &Path,
    corpus_digest: u64,
) -> PathBuf {
    if !cfg.robust_dead_letter_path.is_empty() {
        PathBuf::from(&cfg.robust_dead_letter_path)
    } else if !cfg.cache_dir.is_empty() {
        Path::new(&cfg.cache_dir).join(format!("deadletter_{corpus_digest:016x}.jsonl"))
    } else {
        let mut name = input_path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "corpus".into());
        name.push_str(".deadletter.jsonl");
        input_path.with_file_name(name)
    }
}

/// Build the configured solver engine.
pub(crate) fn make_engine(cfg: &PipelineConfig) -> Result<Box<dyn Engine>, LsspcaError> {
    match cfg.engine.as_str() {
        "native" => Ok(Box::new(NativeEngine::new().with_threads(cfg.threads))),
        #[cfg(feature = "xla")]
        "xla" => Ok(Box::new(XlaEngine::load(Path::new(&cfg.artifacts_dir))?)),
        #[cfg(not(feature = "xla"))]
        "xla" => Err(LsspcaError::config(
            "this build has no XLA support (rebuild with --features xla)",
        )),
        other => Err(LsspcaError::config(format!("unknown engine '{other}'"))),
    }
}

/// One fixed-λ evaluation on an engine: the [`LambdaSpec::Fixed`] path.
/// On the native engine this is exactly a [`crate::solver::lambda`]
/// search probe (per-λ elimination mask + BCA + lift), so a grid point
/// is bitwise-identical to the same λ landing inside a search; other
/// engines go through [`crate::engine::bca_solve`] with the same mask.
fn evaluate_with_engine(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    lambda: f64,
    opts: &LambdaSearchOptions,
) -> Result<LambdaSearchResult, LsspcaError> {
    let (solution, pc) = if engine.name() == "native" {
        crate::solver::lambda::evaluate(sigma, lambda, opts)
    } else {
        let diags: Vec<f64> = (0..sigma.n()).map(|i| sigma.diag(i)).collect();
        crate::coordinator::engine_probe(engine, sigma, &diags, lambda, opts)?
    };
    let cardinality = pc.cardinality();
    let phi = solution.phi;
    let hit_target = cardinality.abs_diff(opts.target_card) <= opts.slack;
    Ok(LambdaSearchResult {
        lambda,
        solution,
        pc,
        trace: vec![LambdaEval { lambda, cardinality, phi }],
        hit_target,
    })
}

/// Create `dir` (and parents) with user-only permissions where the
/// platform supports it — the default shard-cache location sits under
/// a shared temp directory. Errors are deferred to the first write.
fn make_private_dir(dir: &Path) {
    #[cfg(unix)]
    {
        use std::os::unix::fs::DirBuilderExt;
        let _ = std::fs::DirBuilder::new().recursive(true).mode(0o700).create(dir);
    }
    #[cfg(not(unix))]
    {
        let _ = std::fs::create_dir_all(dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_builder() -> SessionBuilder {
        Session::builder()
            .synthetic("nytimes")
            .synth_size(400, 2000)
            .workers(2)
            .chunk_docs(128)
            .target_card(5)
            .card_slack(2)
            .max_reduced(48)
            .bca_sweeps(5)
    }

    #[test]
    fn builder_validates() {
        assert!(matches!(
            Session::builder().engine("gpu").build().unwrap_err(),
            LsspcaError::Config { .. }
        ));
        assert!(Session::builder().build().is_ok());
    }

    #[test]
    fn stages_cache_and_chain() {
        let mut s = tiny_builder().build().unwrap();
        assert!(s.stats().is_none());
        let docs = s.stream().unwrap().docs;
        assert_eq!(docs, 400);
        // cached: same stats object again
        assert_eq!(s.stream().unwrap().docs, 400);
        let n1 = s.eliminate(5).unwrap().elim.reduced();
        assert!(n1 > 0 && n1 <= 48);
        let n2 = s.reduce().unwrap().n();
        assert_eq!(n1, n2);
        let fit = s.fit(LambdaSpec::search(5, 2), 2).unwrap();
        assert_eq!(fit.components.len(), 2);
        for c in &fit.components {
            assert!(c.pc.cardinality() >= 1);
        }
        fit.model.validate().unwrap();
    }

    #[test]
    fn serve_bridges_a_fit_into_a_bound_server() {
        let mut s = tiny_builder().num_pcs(2).build().unwrap();
        let srv = s.serve().unwrap().addr("127.0.0.1:0").build().unwrap();
        assert_ne!(srv.local_addr().port(), 0);
        // the fit that fed the server is cached on the session
        assert!(s.stats().is_some());
    }

    #[test]
    fn fit_alone_runs_the_whole_pipeline() {
        let mut s = tiny_builder().build().unwrap();
        let fit = s.fit(LambdaSpec::search(5, 2), 1).unwrap();
        assert_eq!(fit.components.len(), 1);
        // the implicit stages are now cached
        assert!(s.stats().is_some());
        assert!(s.elimination().is_some());
        assert!(s.reduced_corpus().is_some());
    }

    #[test]
    fn changing_target_invalidates_reduced() {
        let mut s = tiny_builder().build().unwrap();
        s.reduce().unwrap();
        assert!(s.reduced_corpus().is_some());
        // same target: cache kept
        s.eliminate(5).unwrap();
        assert!(s.reduced_corpus().is_some());
        // new target: reduced dropped, then rebuilt on demand
        s.eliminate(3).unwrap();
        assert!(s.reduced_corpus().is_none());
        assert!(s.reduce().unwrap().n() > 0);
    }

    #[test]
    fn warm_refits_are_deterministic() {
        let mut s = tiny_builder().build().unwrap();
        let a = s.fit(LambdaSpec::search(5, 2), 2).unwrap();
        let b = s.fit(LambdaSpec::search(5, 2), 2).unwrap();
        assert_eq!(a.components.len(), b.components.len());
        for (x, y) in a.components.iter().zip(&b.components) {
            assert_eq!(x.lambda.to_bits(), y.lambda.to_bits());
            assert_eq!(x.phi.to_bits(), y.phi.to_bits());
            assert_eq!(x.pc.support, y.pc.support);
            for (u, v) in x.pc.vector.iter().zip(&y.pc.vector) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn fixed_lambda_grid_reuses_stages() {
        let obs = Arc::new(CountingProgress::new());
        let mut s = tiny_builder().observer(Arc::clone(&obs) as Arc<dyn Progress>).build().unwrap();
        s.reduce().unwrap();
        let reads_after_reduce = obs.corpus_reads();
        assert!(reads_after_reduce > 0, "reduce must stream the corpus");
        let rc = s.reduced_corpus().unwrap();
        let max_diag = (0..rc.n()).map(|i| rc.cov().diag(i)).fold(0.0f64, f64::max);
        let lam_hi = 0.8 * max_diag;
        for i in 1..=3 {
            let lam = lam_hi * i as f64 / 4.0;
            let fit = s.fit(LambdaSpec::Fixed(lam), 1).unwrap();
            assert_eq!(fit.components[0].lambda, lam);
        }
        assert_eq!(obs.corpus_reads(), reads_after_reduce, "fits must not re-read the corpus");
        assert_eq!(obs.lambda_evals(), 3);
        assert_eq!(obs.began(Stage::Fit), 3);
        assert_eq!(obs.finished(Stage::Fit), 3);
    }

    #[test]
    fn observer_sees_stream_chunks() {
        let obs = Arc::new(CountingProgress::new());
        let mut s = tiny_builder()
            .chunk_docs(100)
            .observer(Arc::clone(&obs) as Arc<dyn Progress>)
            .build()
            .unwrap();
        s.stream().unwrap();
        assert_eq!(obs.began(Stage::Stream), 1);
        assert_eq!(obs.finished(Stage::Stream), 1);
        assert_eq!(obs.reads(Stage::Stream), 4, "400 docs / 100 per chunk");
        assert_eq!(obs.docs(Stage::Stream), 400);
    }

    #[test]
    fn reset_forces_restream() {
        let obs = Arc::new(CountingProgress::new());
        let mut s = tiny_builder().observer(Arc::clone(&obs) as Arc<dyn Progress>).build().unwrap();
        s.stream().unwrap();
        let r1 = obs.reads(Stage::Stream);
        s.stream().unwrap(); // cached
        assert_eq!(obs.reads(Stage::Stream), r1);
        s.reset();
        s.stream().unwrap();
        assert_eq!(obs.reads(Stage::Stream), 2 * r1);
    }

    #[test]
    fn stage_events_pair_even_when_a_stage_fails() {
        let obs = Arc::new(CountingProgress::new());
        // engine = "xla": validates (with the dense backend), streams and
        // reduces natively, then fit fails at engine construction — after
        // stage_began(Fit) has fired. The guard must still pair it.
        let mut s = tiny_builder()
            .engine("xla")
            .observer(Arc::clone(&obs) as Arc<dyn Progress>)
            .build()
            .unwrap();
        assert!(s.fit(LambdaSpec::search(5, 2), 1).is_err());
        for stage in [Stage::Stream, Stage::Eliminate, Stage::Reduce, Stage::Fit] {
            assert_eq!(obs.began(stage), obs.finished(stage), "unpaired events for {stage:?}");
        }
        assert_eq!(obs.began(Stage::Fit), 1);
    }

    #[test]
    fn lambda_spec_from_config() {
        let cfg = PipelineConfig { target_card: 7, card_slack: 1, ..Default::default() };
        assert_eq!(LambdaSpec::from_config(&cfg), LambdaSpec::Search { target_card: 7, slack: 1 });
    }
}
