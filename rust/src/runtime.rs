//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust hot path. Python never runs at request time — `make artifacts`
//! lowers the JAX/Pallas graphs to HLO *text* once (xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos; the text parser reassigns
//! instruction ids, so text round-trips — see /opt/xla-example/README.md),
//! and this module compiles + runs them through the `xla` crate.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Name of an artifact as emitted by `python/compile/aot.py`:
/// `<stem>.hlo.txt` → stem like `bca_sweep_n128`.
fn artifact_stem(path: &Path) -> Option<String> {
    let name = path.file_name()?.to_str()?;
    name.strip_suffix(".hlo.txt").map(|s| s.to_string())
}

/// A compiled artifact ready to execute.
pub struct Artifact {
    /// Artifact name (file stem).
    pub name: String,
    /// Source `.hlo.txt` path.
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

/// Typed input to an execution: an f64 buffer with a shape.
#[derive(Clone, Debug)]
pub struct TensorF64 {
    /// Row-major element buffer.
    pub data: Vec<f64>,
    /// Shape (XLA convention, i64 dims).
    pub dims: Vec<i64>,
}

impl TensorF64 {
    /// Wrap a buffer with a shape (asserts the element count matches).
    pub fn new(data: Vec<f64>, dims: &[usize]) -> TensorF64 {
        let expect: usize = dims.iter().product();
        assert_eq!(data.len(), expect, "shape/data mismatch");
        TensorF64 { data, dims: dims.iter().map(|&d| d as i64).collect() }
    }

    /// Rank-0 tensor.
    pub fn scalar(v: f64) -> TensorF64 {
        TensorF64 { data: vec![v], dims: vec![] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.dims.is_empty() {
            // rank-0: reshape a 1-element vec to scalar shape
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&self.dims)?)
        }
    }
}

/// The PJRT runtime holding a CPU client and the compiled artifact
/// registry.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
}

impl Runtime {
    /// Create a runtime with the PJRT CPU client.
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "PJRT runtime up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, artifacts: HashMap::new() })
    }

    /// Load and compile one HLO-text artifact under the given name.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        crate::debug!("compiled artifact '{name}' from {}", path.display());
        self.artifacts.insert(
            name.to_string(),
            Artifact { name: name.to_string(), path: path.to_path_buf(), exe },
        );
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; returns the loaded names.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("reading artifact dir {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if let Some(stem) = artifact_stem(&path) {
                self.load(&stem, &path)?;
                names.push(stem);
            }
        }
        names.sort();
        if names.is_empty() {
            bail!(
                "no *.hlo.txt artifacts in {} — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(names)
    }

    /// Whether an artifact with this name was loaded.
    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Sorted names of the loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute an artifact on f64 inputs; returns the tuple elements as
    /// flat f64 buffers (all our L2 graphs are lowered with
    /// `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[TensorF64]) -> Result<Vec<Vec<f64>>> {
        let artifact = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded (have: {:?})", self.names()))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = artifact
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{name}'"))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("untupling result")?;
        let mut buffers = Vec::with_capacity(parts.len());
        for p in parts {
            buffers.push(p.to_vec::<f64>().context("reading f64 output")?);
        }
        Ok(buffers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that need artifacts only run when `make artifacts` has been
    /// executed (CI runs it first; `cargo test` alone skips gracefully).
    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join(".stamp").exists().then_some(dir)
    }

    #[test]
    fn stem_parsing() {
        assert_eq!(
            artifact_stem(Path::new("/x/bca_sweep_n128.hlo.txt")),
            Some("bca_sweep_n128".to_string())
        );
        assert_eq!(artifact_stem(Path::new("/x/readme.md")), None);
    }

    #[test]
    fn tensor_shape_checks() {
        let t = TensorF64::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims, vec![2, 2]);
        let s = TensorF64::scalar(7.0);
        assert!(s.dims.is_empty());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_rejects_bad_shape() {
        TensorF64::new(vec![1.0], &[2, 2]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::new().unwrap();
        assert!(rt.execute("nope", &[]).is_err());
        assert!(!rt.has("nope"));
    }

    #[test]
    fn load_dir_roundtrip_if_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new().unwrap();
        let names = rt.load_dir(&dir).unwrap();
        assert!(!names.is_empty());
        for n in &names {
            assert!(rt.has(n));
        }
    }
}
