//! Reduced covariance assembly — the second streaming pass.
//!
//! After safe elimination keeps n̂ ≪ n features, the solver needs the dense
//! n̂ × n̂ *centered* covariance of exactly those features:
//!
//! ```text
//! Σ̂_ab = (1/m) Σ_d x_{d,k(a)} x_{d,k(b)}  −  μ_a μ_b
//! ```
//!
//! A document contributes the outer product of its *kept* words only —
//! O(k_d²) work for k_d kept words in the document, so the pass stays
//! cheap even at PubMed scale. Partial accumulators (sum of outer products
//! + per-feature sums) merge additively across workers.
//!
//! Two accumulators live here, one per covariance backend:
//!
//! - [`CovAccum`] → a dense [`SymMat`] (the `cov.backend = "dense"` path);
//! - [`ReducedDocsAccum`] → the reduced sparse term matrix behind
//!   [`GramCov`] (the `"gram"` path) — same streaming pass shape, but it
//!   keeps the kept-feature rows themselves (O(nnz) memory) instead of
//!   folding them into an O(n̂²) buffer.

use crate::covop::GramCov;
use crate::data::docword::DocChunk;
use crate::data::sparse::CsrMatrix;
use crate::data::SymMat;
use crate::elim::SafeElimination;
use crate::stream::{parallel_fold, ChunkSource, StreamOptions, StreamStats};

/// Mergeable accumulator for the covariance pass.
#[derive(Clone, Debug)]
pub struct CovAccum {
    /// n̂ × n̂ sum of outer products over kept coordinates (upper triangle
    /// maintained, mirrored at finalize).
    outer: Vec<f64>,
    /// Per-kept-feature sums.
    sums: Vec<f64>,
    /// Documents seen.
    docs: u64,
    nhat: usize,
    /// Reusable kept-entry gather buffer — one allocation per
    /// accumulator, not one per document (`push_doc` is called once per
    /// document across the whole corpus).
    scratch: Vec<(u32, f64)>,
}

impl CovAccum {
    /// Zeroed accumulator for `nhat` kept features.
    pub fn new(nhat: usize) -> CovAccum {
        CovAccum {
            outer: vec![0.0; nhat * nhat],
            sums: vec![0.0; nhat],
            docs: 0,
            nhat,
            scratch: Vec::new(),
        }
    }

    /// Fold one document given a full→reduced lookup (u32::MAX = dropped).
    pub fn push_doc(&mut self, words: &[(u32, f64)], lookup: &[u32]) {
        self.docs += 1;
        // Gather kept entries (reduced index, count) into the reusable
        // scratch buffer (taken out of self to split the borrow).
        let mut kept = std::mem::take(&mut self.scratch);
        kept.clear();
        for &(w, c) in words {
            let r = lookup[w as usize];
            if r != u32::MAX {
                kept.push((r, c));
            }
        }
        for (i, &(a, ca)) in kept.iter().enumerate() {
            self.sums[a as usize] += ca;
            for &(b, cb) in &kept[i..] {
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                self.outer[lo as usize * self.nhat + hi as usize] += ca * cb;
            }
        }
        self.scratch = kept;
    }

    /// Fold another worker's partial sums in (additive).
    pub fn merge(&mut self, other: &CovAccum) {
        assert_eq!(self.nhat, other.nhat);
        for (a, b) in self.outer.iter_mut().zip(&other.outer) {
            *a += b;
        }
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        self.docs += other.docs;
    }

    /// Finalize into a centered covariance matrix (population convention).
    pub fn finalize(&self) -> SymMat {
        let m = self.docs.max(1) as f64;
        let nhat = self.nhat;
        let mut cov = SymMat::zeros(nhat);
        for a in 0..nhat {
            let mu_a = self.sums[a] / m;
            for b in a..nhat {
                let mu_b = self.sums[b] / m;
                let v = self.outer[a * nhat + b] / m - mu_a * mu_b;
                cov.set(a, b, v);
            }
        }
        cov
    }
}

/// Build the full→reduced lookup table from an elimination result.
pub fn reduced_lookup(elim: &SafeElimination) -> Vec<u32> {
    let mut lookup = vec![u32::MAX; elim.original];
    for (r, &orig) in elim.kept.iter().enumerate() {
        lookup[orig] = r as u32;
    }
    lookup
}

/// [`reduced_lookup`] from a bare kept-id list (the form the distributed
/// job manifest carries across the process boundary).
pub fn reduced_lookup_from_kept(kept: &[u32], n: usize) -> Vec<u32> {
    let mut lookup = vec![u32::MAX; n];
    for (r, &orig) in kept.iter().enumerate() {
        lookup[orig as usize] = r as u32;
    }
    lookup
}

/// Streaming reduced-covariance pass.
pub fn covariance_pass<S: ChunkSource>(
    source: &mut S,
    elim: &SafeElimination,
    opts: StreamOptions,
) -> Result<(SymMat, StreamStats), crate::error::LsspcaError> {
    let nhat = elim.reduced();
    let lookup = std::sync::Arc::new(reduced_lookup(elim));
    let (acc, stats) = parallel_fold(
        source,
        opts,
        || CovAccum::new(nhat),
        {
            let lookup = std::sync::Arc::clone(&lookup);
            move |acc: &mut CovAccum, chunk: &DocChunk| {
                for doc in &chunk.docs {
                    acc.push_doc(&doc.words, &lookup);
                }
            }
        },
        |a, b| a.merge(&b),
    )?;
    Ok((acc.finalize(), stats))
}

/// Mergeable accumulator for the implicit-Gram pass: collects each
/// document's kept-feature entries into flat per-worker arrays (no
/// per-document allocations; 12 bytes/nnz, the CSR's own footprint),
/// tagged with the document id so rows reassemble in corpus order no
/// matter which worker processed which chunk (stronger determinism than
/// [`CovAccum`], whose float merges depend on chunk scheduling).
#[derive(Clone, Debug)]
pub struct ReducedDocsAccum {
    /// Ids of documents with ≥ 1 kept feature, in fold order.
    doc_ids: Vec<u64>,
    /// Prefix offsets into `idx`/`val`; `doc_ptr.len() == doc_ids.len()+1`.
    doc_ptr: Vec<usize>,
    /// Kept entries of all folded documents, concatenated.
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl Default for ReducedDocsAccum {
    fn default() -> Self {
        ReducedDocsAccum::new()
    }
}

impl ReducedDocsAccum {
    /// Empty accumulator.
    pub fn new() -> ReducedDocsAccum {
        ReducedDocsAccum { doc_ids: Vec::new(), doc_ptr: vec![0], idx: Vec::new(), val: Vec::new() }
    }

    /// Fold one document given a full→reduced lookup (u32::MAX = dropped).
    pub fn push_doc(&mut self, doc_id: u64, words: &[(u32, f64)], lookup: &[u32]) {
        let start = self.idx.len();
        for &(w, c) in words {
            let r = lookup[w as usize];
            if r != u32::MAX {
                self.idx.push(r);
                self.val.push(c);
            }
        }
        if self.idx.len() > start {
            self.doc_ids.push(doc_id);
            self.doc_ptr.push(self.idx.len());
        }
    }

    /// Append another worker's documents (doc-id sort happens at
    /// [`ReducedDocsAccum::finalize`]).
    pub fn merge(&mut self, other: ReducedDocsAccum) {
        let base = self.idx.len();
        self.doc_ids.extend_from_slice(&other.doc_ids);
        // other.doc_ptr[0] == 0; shift the rest by our current nnz.
        self.doc_ptr.extend(other.doc_ptr[1..].iter().map(|&p| base + p));
        self.idx.extend_from_slice(&other.idx);
        self.val.extend_from_slice(&other.val);
    }

    /// Decompose into raw parts `(doc_ids, doc_ptr, idx, val)` — the
    /// distributed shard format persists per-chunk accumulators in
    /// exactly this shape ([`crate::dist::shardio`]).
    pub fn into_parts(self) -> (Vec<u64>, Vec<usize>, Vec<u32>, Vec<f64>) {
        (self.doc_ids, self.doc_ptr, self.idx, self.val)
    }

    /// Reassemble from raw parts (inverse of
    /// [`ReducedDocsAccum::into_parts`]). `doc_ptr` must be a valid
    /// prefix-offset table: `doc_ptr[0] == 0`, monotone, last entry ==
    /// `idx.len()`, and `doc_ptr.len() == doc_ids.len() + 1`.
    pub fn from_parts(
        doc_ids: Vec<u64>,
        doc_ptr: Vec<usize>,
        idx: Vec<u32>,
        val: Vec<f64>,
    ) -> ReducedDocsAccum {
        assert_eq!(doc_ptr.len(), doc_ids.len() + 1);
        assert_eq!(doc_ptr.first(), Some(&0));
        assert_eq!(doc_ptr.last(), Some(&idx.len()));
        assert_eq!(idx.len(), val.len());
        ReducedDocsAccum { doc_ids, doc_ptr, idx, val }
    }

    /// Assemble the reduced CSR (rows = documents with ≥ 1 kept feature,
    /// in ascending doc-id order; cols = kept features in elimination
    /// order). Within each row the entries are sorted by reduced column
    /// index — the *canonical* layout both covariance backends consume,
    /// and the precondition for the out-of-core backend's bitwise
    /// equality with the in-memory one (a column-range sweep of the
    /// shard cache replays exactly this per-row summation order).
    pub fn finalize(self, nhat: usize) -> CsrMatrix {
        let ndocs = self.doc_ids.len();
        let mut order: Vec<u32> = (0..ndocs as u32).collect();
        order.sort_unstable_by_key(|&d| self.doc_ids[d as usize]);
        let nnz = self.idx.len();
        let mut indptr = Vec::with_capacity(ndocs + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        let mut row: Vec<(u32, f64)> = Vec::new();
        indptr.push(0usize);
        for &d in &order {
            let (lo, hi) = (self.doc_ptr[d as usize], self.doc_ptr[d as usize + 1]);
            row.clear();
            row.extend(self.idx[lo..hi].iter().copied().zip(self.val[lo..hi].iter().copied()));
            // Reduced indices are variance-ranked, not monotone in the
            // original word id, so the pushed order is arbitrary; sort.
            row.sort_unstable_by_key(|&(c, _)| c);
            indices.extend(row.iter().map(|&(c, _)| c));
            values.extend(row.iter().map(|&(_, v)| v));
            indptr.push(indices.len());
        }
        CsrMatrix { rows: ndocs, cols: nhat, indptr, indices, values }
    }
}

/// Streaming reduced-term-matrix pass: the shared front half of the
/// `"gram"` and `"disk"` covariance backends. Same reader/worker
/// topology as [`covariance_pass`], but the result is the reduced,
/// doc-id-sorted, column-sorted CSR itself — the canonical matrix the
/// in-memory [`GramCov`] wraps and the on-disk shard cache
/// ([`crate::data::shardcache`]) persists.
pub fn reduced_csr_pass<S: ChunkSource>(
    source: &mut S,
    elim: &SafeElimination,
    opts: StreamOptions,
) -> Result<(CsrMatrix, StreamStats), crate::error::LsspcaError> {
    let nhat = elim.reduced();
    let lookup = std::sync::Arc::new(reduced_lookup(elim));
    let (acc, stats) = parallel_fold(
        source,
        opts,
        ReducedDocsAccum::new,
        {
            let lookup = std::sync::Arc::clone(&lookup);
            move |acc: &mut ReducedDocsAccum, chunk: &DocChunk| {
                for doc in &chunk.docs {
                    acc.push_doc(doc.id as u64, &doc.words, &lookup);
                }
            }
        },
        |a, b| a.merge(b),
    )?;
    Ok((acc.finalize(nhat), stats))
}

/// Streaming implicit-Gram pass: the `cov.backend = "gram"` counterpart
/// of [`covariance_pass`]. Same reader/worker topology, but the result is
/// a [`GramCov`] operator over the reduced term matrix — O(nnz + n̂)
/// memory plus the `cache_mb` row-cache budget, never an n̂ × n̂ dense
/// matrix.
pub fn gram_pass<S: ChunkSource>(
    source: &mut S,
    elim: &SafeElimination,
    opts: StreamOptions,
    cache_mb: usize,
) -> Result<(GramCov, StreamStats), crate::error::LsspcaError> {
    let (csr, stats) = reduced_csr_pass(source, elim, opts)?;
    Ok((GramCov::new(csr, stats.docs, cache_mb), stats))
}

/// Dense covariance replayed from an already-reduced *canonical* CSR
/// (the [`ReducedDocsAccum::finalize`] layout: rows ascending by doc id,
/// columns sorted within each row). Used by the distributed dense
/// backend: the merged shard CSR is replayed through a fresh
/// [`CovAccum`] row by row, with the document count overridden to
/// `docs` (the CSR omits documents with zero kept features, but the
/// single-process pass counts them toward the `1/m` normalizer).
///
/// Bitwise equal to a single-process [`covariance_pass`] at
/// `stream.workers = 1`: within one document every kept feature (and
/// feature pair) touches its accumulator slot exactly once, so each
/// slot sees the same per-document addition sequence in the same
/// ascending doc order regardless of within-row entry order.
pub fn covariance_from_canonical_csr(m: &CsrMatrix, docs: u64) -> SymMat {
    let nhat = m.cols;
    let lookup: Vec<u32> = (0..nhat as u32).collect();
    let mut acc = CovAccum::new(nhat);
    let mut words: Vec<(u32, f64)> = Vec::new();
    for d in 0..m.rows {
        words.clear();
        words.extend(m.row(d).map(|(c, v)| (c as u32, v)));
        acc.push_doc(&words, &lookup);
    }
    acc.docs = docs;
    acc.finalize()
}

/// Dense reference: centered covariance of selected columns of a CSR
/// matrix (O(m·n̂) memory-light two-pass; used by tests and small runs).
pub fn covariance_from_csr(m: &CsrMatrix, kept: &[usize]) -> SymMat {
    covariance_from_csr_par(m, kept, 1)
}

/// Fixed row-shard size for the parallel dense passes. Shard boundaries
/// depend only on this constant (never on the thread count), so partial
/// accumulators merge in the same order for any `threads` — bitwise
/// deterministic output (see `util::parallel`).
const ROW_SHARD: usize = 1024;

/// Shards are processed in bounded *waves* so only one wave of partial
/// accumulators is alive at once — transient memory stays
/// O(max(threads, SHARD_WAVE) · n̂²) no matter how many rows stream
/// through (a PubMed-scale 8M-doc pass would otherwise hold thousands of
/// partials). The wave size grows with the thread count so big machines
/// keep every core busy; determinism is unaffected because the merge is
/// a strict fold in shard order regardless of wave boundaries.
const SHARD_WAVE: usize = 16;

fn wave_cap(threads: usize) -> usize {
    crate::util::parallel::resolve_threads(threads).max(SHARD_WAVE)
}

/// Parallel variant of [`covariance_from_csr`]: rows are split into fixed
/// shards, each folded into its own [`CovAccum`] on a worker, then merged
/// in shard order, wave by wave.
pub fn covariance_from_csr_par(m: &CsrMatrix, kept: &[usize], threads: usize) -> SymMat {
    let nhat = kept.len();
    let mut lookup = vec![u32::MAX; m.cols];
    for (r, &orig) in kept.iter().enumerate() {
        lookup[orig] = r as u32;
    }
    let shards = m.rows.div_ceil(ROW_SHARD).max(1);
    let cap = wave_cap(threads);
    let mut acc = CovAccum::new(nhat);
    let mut wave_start = 0;
    while wave_start < shards {
        let wave = (shards - wave_start).min(cap);
        let partials = crate::util::parallel::par_map_indexed(threads, wave, |k| {
            let s = wave_start + k;
            let start = s * ROW_SHARD;
            let end = ((s + 1) * ROW_SHARD).min(m.rows);
            let mut part = CovAccum::new(nhat);
            for d in start..end {
                let words: Vec<(u32, f64)> = m.row(d).map(|(c, v)| (c as u32, v)).collect();
                part.push_doc(&words, &lookup);
            }
            part
        });
        for p in &partials {
            acc.merge(p);
        }
        wave_start += wave;
    }
    acc.finalize()
}

/// Parallel Gram matrix `AᵀA/m` of a dense row-major `m × n` block: fixed
/// row shards accumulate partial outer products on workers, merged in
/// shard order wave by wave (deterministic for any `threads`; a single
/// shard is bit-identical to [`SymMat::gram`]).
pub fn gram_parallel(m_rows: usize, n: usize, data: &[f64], threads: usize) -> SymMat {
    assert_eq!(data.len(), m_rows * n);
    let shard_rows = 256usize;
    let shards = m_rows.div_ceil(shard_rows).max(1);
    if shards <= 1 {
        return SymMat::gram(m_rows, n, data);
    }
    let cap = wave_cap(threads);
    let mut acc = vec![0.0f64; n * n];
    let mut wave_start = 0;
    while wave_start < shards {
        let wave = (shards - wave_start).min(cap);
        let partials = crate::util::parallel::par_map_indexed(threads, wave, |k| {
            let s = wave_start + k;
            let start = s * shard_rows;
            let end = ((s + 1) * shard_rows).min(m_rows);
            let mut part = vec![0.0f64; n * n];
            for r in start..end {
                let row = &data[r * n..(r + 1) * n];
                for i in 0..n {
                    let fi = row[i];
                    if fi == 0.0 {
                        continue;
                    }
                    let pi = &mut part[i * n..(i + 1) * n];
                    // Element-wise axpy through the dispatch layer:
                    // bitwise-identical on every tier, so the single-
                    // shard pin against SymMat::gram holds unchanged.
                    crate::kernels::axpy(fi, row, pi);
                }
            }
            part
        });
        for part in &partials {
            for (a, b) in acc.iter_mut().zip(part) {
                *a += b;
            }
        }
        wave_start += wave;
    }
    let inv = 1.0 / m_rows as f64;
    let mut g = SymMat::zeros(n);
    for (dst, src) in g.as_mut_slice().iter_mut().zip(&acc) {
        *dst = src * inv;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusSpec, SynthCorpus};
    use crate::elim::SafeElimination;
    use crate::stream::{variance_pass, SynthSource};
    use crate::util::check::{close, property};
    use crate::util::rng::Rng;

    #[test]
    fn prop_matches_dense_definition() {
        property("covariance pass == dense centered covariance", 15, |rng| {
            // random small sparse corpus
            let docs = rng.range(2, 30);
            let vocab = rng.range(2, 12);
            let mut dense = vec![0.0f64; docs * vocab];
            let mut chunks = Vec::new();
            for d in 0..docs {
                let mut words = Vec::new();
                for w in 0..vocab {
                    if rng.bool(0.5) {
                        let c = (1 + rng.below(4)) as f64;
                        dense[d * vocab + w] = c;
                        words.push((w as u32, c));
                    }
                }
                chunks.push(words);
            }
            // keep a random subset
            let nkeep = rng.range(1, vocab + 1);
            let kept_orig = rng.sample_indices(vocab, nkeep);
            let elim = SafeElimination {
                lambda: 0.0,
                original: vocab,
                kept: kept_orig.clone(),
                kept_variances: vec![0.0; nkeep],
            };
            let lookup = reduced_lookup(&elim);
            let mut acc = CovAccum::new(nkeep);
            for words in &chunks {
                acc.push_doc(words, &lookup);
            }
            let cov = acc.finalize();
            // dense reference
            for a in 0..nkeep {
                for b in 0..nkeep {
                    let (i, j) = (kept_orig[a], kept_orig[b]);
                    let mi: f64 =
                        (0..docs).map(|d| dense[d * vocab + i]).sum::<f64>() / docs as f64;
                    let mj: f64 =
                        (0..docs).map(|d| dense[d * vocab + j]).sum::<f64>() / docs as f64;
                    let want: f64 = (0..docs)
                        .map(|d| (dense[d * vocab + i] - mi) * (dense[d * vocab + j] - mj))
                        .sum::<f64>()
                        / docs as f64;
                    close(cov.get(a, b), want, 1e-10)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_equals_single() {
        let mut rng = Rng::seed_from(71);
        let vocab = 6;
        let lookup: Vec<u32> = (0..vocab).map(|i| i as u32).collect();
        let docs: Vec<Vec<(u32, f64)>> = (0..20)
            .map(|_| {
                let mut words = Vec::new();
                for w in 0..vocab {
                    if rng.bool(0.5) {
                        words.push((w as u32, 1.0 + rng.below(3) as f64));
                    }
                }
                words
            })
            .collect();
        let mut whole = CovAccum::new(vocab);
        for d in &docs {
            whole.push_doc(d, &lookup);
        }
        let mut a = CovAccum::new(vocab);
        let mut b = CovAccum::new(vocab);
        for d in &docs[..9] {
            a.push_doc(d, &lookup);
        }
        for d in &docs[9..] {
            b.push_doc(d, &lookup);
        }
        a.merge(&b);
        let (ca, cw) = (a.finalize(), whole.finalize());
        for i in 0..vocab {
            for j in 0..vocab {
                assert!((ca.get(i, j) - cw.get(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn diagonal_matches_variance_pass() {
        // The covariance diagonal must equal the variances from the moment
        // pass — the consistency which Thm 2.1's λ < σ²min assumption needs.
        let c = SynthCorpus::new(CorpusSpec::nytimes().scaled(200, 800), 3);
        let opts = StreamOptions { workers: 2, chunk_docs: 50, queue_depth: 2 };
        let (fv, _) = variance_pass(&mut SynthSource::new(&c), opts).unwrap();
        let elim = SafeElimination::from_variances(&fv, 0.05, Some(32));
        assert!(elim.reduced() > 0);
        let (cov, _) = covariance_pass(&mut SynthSource::new(&c), &elim, opts).unwrap();
        for (r, &orig) in elim.kept.iter().enumerate() {
            assert!(
                (cov.get(r, r) - fv.variance[orig]).abs() < 1e-9 * (1.0 + fv.variance[orig]),
                "diag mismatch at {r}"
            );
        }
        // PSD check on the assembled covariance
        assert!(crate::linalg::chol::is_psd(&cov, 1e-8), "covariance must be PSD");
    }

    #[test]
    fn gram_pass_matches_covariance_pass() {
        use crate::covop::CovOp;
        let c = SynthCorpus::new(CorpusSpec::nytimes().scaled(250, 900), 21);
        let opts = StreamOptions { workers: 2, chunk_docs: 40, queue_depth: 2 };
        let (fv, _) = variance_pass(&mut SynthSource::new(&c), opts).unwrap();
        let elim = SafeElimination::from_variances(&fv, 0.03, Some(24));
        assert!(elim.reduced() > 1);
        let (dense, _) = covariance_pass(&mut SynthSource::new(&c), &elim, opts).unwrap();
        let (gram, stats) = gram_pass(&mut SynthSource::new(&c), &elim, opts, 8).unwrap();
        assert_eq!(stats.docs, 250);
        assert_eq!(gram.n(), elim.reduced());
        let mut row = vec![0.0; elim.reduced()];
        for j in 0..elim.reduced() {
            assert!((gram.diag(j) - dense.get(j, j)).abs() < 1e-9);
            gram.row_into(j, &mut row);
            for k in 0..elim.reduced() {
                assert!(
                    (row[k] - dense.get(j, k)).abs() < 1e-9,
                    "Σ[{j},{k}]: gram {} vs dense {}",
                    row[k],
                    dense.get(j, k)
                );
            }
        }
    }

    #[test]
    fn gram_pass_deterministic_across_workers() {
        use crate::covop::CovOp;
        let c = SynthCorpus::new(CorpusSpec::nytimes().scaled(400, 1200), 29);
        let (fv, _) =
            variance_pass(&mut SynthSource::new(&c), StreamOptions::default()).unwrap();
        let elim = SafeElimination::from_variances(&fv, 0.02, Some(16));
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for workers in [1, 4] {
            let opts = StreamOptions { workers, chunk_docs: 33, queue_depth: 2 };
            let (gram, _) = gram_pass(&mut SynthSource::new(&c), &elim, opts, 4).unwrap();
            let mut flat = Vec::new();
            let mut row = vec![0.0; elim.reduced()];
            for j in 0..elim.reduced() {
                gram.row_into(j, &mut row);
                flat.extend_from_slice(&row);
            }
            rows.push(flat);
        }
        // doc-id sort makes the gram pass bitwise identical for any
        // worker count (unlike the dense accumulator's float merges)
        assert_eq!(rows[0], rows[1]);
    }

    #[test]
    fn csr_reference_agrees_with_streaming() {
        let c = SynthCorpus::new(CorpusSpec::nytimes().scaled(150, 600), 9);
        let csr = c.to_csr();
        let opts = StreamOptions { workers: 1, chunk_docs: 64, queue_depth: 2 };
        let (fv, _) = variance_pass(&mut SynthSource::new(&c), opts).unwrap();
        let elim = SafeElimination::from_variances(&fv, 0.02, Some(20));
        let (cov_stream, _) = covariance_pass(&mut SynthSource::new(&c), &elim, opts).unwrap();
        let cov_csr = covariance_from_csr(&csr, &elim.kept);
        for i in 0..elim.reduced() {
            for j in 0..elim.reduced() {
                assert!((cov_stream.get(i, j) - cov_csr.get(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn canonical_csr_replay_is_bitwise_vs_sequential_pass() {
        // The distributed dense backend's determinism contract: replaying
        // the canonical reduced CSR equals a workers=1 streaming pass
        // bit for bit (per-slot addition sequences are identical).
        let c = SynthCorpus::new(CorpusSpec::nytimes().scaled(180, 700), 17);
        let opts = StreamOptions { workers: 1, chunk_docs: 41, queue_depth: 2 };
        let (fv, _) = variance_pass(&mut SynthSource::new(&c), opts).unwrap();
        let elim = SafeElimination::from_variances(&fv, 0.02, Some(24));
        let (cov_seq, stats) = covariance_pass(&mut SynthSource::new(&c), &elim, opts).unwrap();
        let (csr, _) = reduced_csr_pass(&mut SynthSource::new(&c), &elim, opts).unwrap();
        let cov_replay = covariance_from_canonical_csr(&csr, stats.docs);
        for i in 0..elim.reduced() {
            for j in 0..elim.reduced() {
                assert_eq!(
                    cov_replay.get(i, j).to_bits(),
                    cov_seq.get(i, j).to_bits(),
                    "Σ[{i},{j}] drifted"
                );
            }
        }
    }

    #[test]
    fn reduced_accum_parts_roundtrip() {
        let lookup: Vec<u32> = vec![0, u32::MAX, 1, 2];
        let mut acc = ReducedDocsAccum::new();
        acc.push_doc(7, &[(0, 2.0), (2, 1.0)], &lookup);
        acc.push_doc(9, &[(1, 5.0)], &lookup); // fully dropped → no row
        acc.push_doc(3, &[(3, 4.0)], &lookup);
        let (doc_ids, doc_ptr, idx, val) = acc.clone().into_parts();
        assert_eq!(doc_ids, vec![7, 3]);
        assert_eq!(doc_ptr, vec![0, 2, 3]);
        let back = ReducedDocsAccum::from_parts(doc_ids, doc_ptr, idx, val);
        let (a, b) = (acc.finalize(3), back.finalize(3));
        assert_eq!(a.indptr, b.indptr);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
    }
}
