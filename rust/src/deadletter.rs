//! Dead-letter quarantine for malformed corpus records.
//!
//! Real-world docword dumps carry damage — a truncated line from an
//! interrupted export, a wordID past the declared vocabulary, ids pasted
//! in the wrong order. Today's strict reader aborts a multi-hour pass on
//! the first such line; with `[robustness] max_bad_records > 0` the
//! reader instead *quarantines* the record here and keeps streaming: the
//! offending raw line goes to an append-only `deadletter.jsonl` next to
//! the cache, with its source line number, a typed [`BadRecordReason`],
//! a human detail string, and a per-record xor-fold checksum so later
//! tooling can verify the quarantine file itself was not damaged.
//!
//! Records are deduplicated by source offset: the pipeline streams the
//! corpus twice (variance pass, reduced-CSR pass) and a resumed run
//! re-reads the completed prefix, so the same bad line is *encountered*
//! many times but *recorded* once — and the bad-record budget counts
//! distinct lines, not encounters.
//!
//! Record layout (one JSON object per line, fixed key order):
//!
//! ```json
//! {"offset":17,"reason":"word-out-of-range","detail":"wordID 9 exceeds W=5","line":"3 9 1","crc":"89abcdef01234567"}
//! ```
//!
//! `crc` is the [`crate::util::xor_fold_checksum`] (as 16 hex digits) of
//! the record serialized *without* the `crc` field — i.e. of the bytes
//! `{"offset":...,"line":"..."}`. `lsspca dlq` inspects and re-validates
//! these files; `lsspca dlq --retry` re-parses the quarantined lines
//! against a corpus header to report which became recoverable.

use std::collections::HashSet;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

use crate::error::LsspcaError;
use crate::util::json::Json;
use crate::util::xor_fold_checksum;

/// Why a record was quarantined instead of folded into the pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BadRecordReason {
    /// The docID token would not parse as an integer.
    BadDocId,
    /// The wordID token would not parse as an integer.
    BadWordId,
    /// The count token would not parse as a number.
    BadCount,
    /// A docID or wordID of 0 in the 1-based UCI format.
    ZeroId,
    /// wordID past the header's declared vocabulary size W.
    WordOutOfRange,
    /// docID went backwards — UCI files are sorted by document.
    NonMonotonicDoc,
    /// The gzip member's CRC32 trailer did not match its contents.
    GzipCrc,
}

impl BadRecordReason {
    /// The stable string form stored in `deadletter.jsonl`.
    pub fn as_str(self) -> &'static str {
        match self {
            BadRecordReason::BadDocId => "bad-doc-id",
            BadRecordReason::BadWordId => "bad-word-id",
            BadRecordReason::BadCount => "bad-count",
            BadRecordReason::ZeroId => "zero-id",
            BadRecordReason::WordOutOfRange => "word-out-of-range",
            BadRecordReason::NonMonotonicDoc => "non-monotonic-doc",
            BadRecordReason::GzipCrc => "gzip-crc",
        }
    }

    /// Parse the stable string form back.
    pub fn parse(s: &str) -> Option<BadRecordReason> {
        Some(match s {
            "bad-doc-id" => BadRecordReason::BadDocId,
            "bad-word-id" => BadRecordReason::BadWordId,
            "bad-count" => BadRecordReason::BadCount,
            "zero-id" => BadRecordReason::ZeroId,
            "word-out-of-range" => BadRecordReason::WordOutOfRange,
            "non-monotonic-doc" => BadRecordReason::NonMonotonicDoc,
            "gzip-crc" => BadRecordReason::GzipCrc,
            _ => return None,
        })
    }
}

/// Minimal deterministic JSON string escaping (the exact bytes the
/// Python mirror reproduces): backslash, double quote, and control
/// characters below 0x20 as `\u00XX`; everything else verbatim UTF-8.
fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Serialize a record without its `crc` field — the checksum input.
fn record_prefix(offset: u64, reason: BadRecordReason, detail: &str, line: &str) -> String {
    let mut s = String::with_capacity(64 + detail.len() + line.len());
    s.push_str(&format!("{{\"offset\":{offset},\"reason\":\"{}\",\"detail\":\"", reason.as_str()));
    escape_json(detail, &mut s);
    s.push_str("\",\"line\":\"");
    escape_json(line, &mut s);
    s.push_str("\"}");
    s
}

/// Serialize one full record line (with `crc`, without the trailing
/// newline) — exposed for the format-mirror tests.
pub fn format_record(offset: u64, reason: BadRecordReason, detail: &str, line: &str) -> String {
    let prefix = record_prefix(offset, reason, detail, line);
    let crc = xor_fold_checksum(prefix.as_bytes());
    format!("{},\"crc\":\"{crc:016x}\"}}", &prefix[..prefix.len() - 1])
}

/// One parsed entry of a `deadletter.jsonl` file.
#[derive(Clone, Debug)]
pub struct DeadLetterRecord {
    /// 1-based data-line number in the corpus file (counting from the
    /// first line after the three-line header).
    pub offset: u64,
    /// The typed reason, if the stored string is a known one.
    pub reason: Option<BadRecordReason>,
    /// The stored reason string (kept verbatim for unknown reasons).
    pub reason_str: String,
    /// Human-readable detail from the reader.
    pub detail: String,
    /// The raw quarantined corpus line.
    pub line: String,
    /// Whether the record's own checksum verified.
    pub crc_ok: bool,
}

/// The append-side handle a streaming pass quarantines into.
pub struct DeadLetterQueue {
    path: PathBuf,
    file: Option<File>,
    seen: HashSet<u64>,
}

impl DeadLetterQueue {
    /// Open (or create lazily on first quarantine) the queue at `path`,
    /// loading existing records so re-runs deduplicate and the budget
    /// counts distinct bad lines across passes.
    pub fn open(path: &Path) -> Result<DeadLetterQueue, LsspcaError> {
        let mut seen = HashSet::new();
        if path.exists() {
            for r in read_records(path)? {
                seen.insert(r.offset);
            }
        }
        Ok(DeadLetterQueue { path: path.to_path_buf(), file: None, seen })
    }

    /// Where this queue writes.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Distinct quarantined source lines (pre-existing + this run).
    pub fn len(&self) -> u64 {
        self.seen.len() as u64
    }

    /// `true` when nothing has ever been quarantined here.
    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }

    /// Quarantine one record. Duplicate offsets (a second pass or a
    /// resumed run re-reading the same line) are counted once and not
    /// re-written. Each append is flushed so a later crash cannot lose
    /// the evidence of records already skipped.
    pub fn quarantine(
        &mut self,
        offset: u64,
        reason: BadRecordReason,
        detail: &str,
        line: &str,
    ) -> Result<(), LsspcaError> {
        if !self.seen.insert(offset) {
            return Ok(());
        }
        if self.file.is_none() {
            if let Some(dir) = self.path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir).map_err(|e| {
                        LsspcaError::io_at(&self.path, format!("mkdir for dead-letter queue: {e}"))
                    })?;
                }
            }
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(&self.path)
                .map_err(|e| LsspcaError::io_at(&self.path, format!("open dead-letter queue: {e}")))?;
            self.file = Some(f);
        }
        let f = self.file.as_mut().unwrap();
        let rec = format_record(offset, reason, detail, line);
        writeln!(f, "{rec}")
            .and_then(|_| f.flush())
            .map_err(|e| LsspcaError::io_at(&self.path, format!("append dead-letter record: {e}")))
    }
}

/// Reader-side quarantine policy: the bad-record budget plus the queue
/// malformed records spill into. `[robustness] max_bad_records` > 0
/// creates one of these; 0 (the default) leaves the reader strict.
pub struct RecordPolicy {
    max_bad_records: u64,
    dlq: DeadLetterQueue,
}

impl RecordPolicy {
    /// Tolerate up to `max_bad_records` distinct bad lines, spilling them
    /// into `dlq`.
    pub fn new(max_bad_records: u64, dlq: DeadLetterQueue) -> RecordPolicy {
        RecordPolicy { max_bad_records, dlq }
    }

    /// Quarantine one malformed record, then enforce the budget: once the
    /// count of *distinct* quarantined lines exceeds `max_bad_records`
    /// this errors — the evidence is on disk either way.
    pub fn admit(
        &mut self,
        offset: u64,
        reason: BadRecordReason,
        detail: &str,
        line: &str,
    ) -> Result<(), LsspcaError> {
        self.dlq.quarantine(offset, reason, detail, line)?;
        if self.dlq.len() > self.max_bad_records {
            return Err(LsspcaError::corpus(format!(
                "too many bad records: {} quarantined, max_bad_records = {} (see {})",
                self.dlq.len(),
                self.max_bad_records,
                self.dlq.path().display()
            )));
        }
        Ok(())
    }

    /// Distinct quarantined lines so far (all passes).
    pub fn quarantined(&self) -> u64 {
        self.dlq.len()
    }

    /// The queue file this policy spills into.
    pub fn path(&self) -> &Path {
        self.dlq.path()
    }
}

/// Parse every record of a `deadletter.jsonl`, verifying each record's
/// own checksum (`crc_ok`). Unparsable lines are an error — the queue
/// file is machine-written, so damage to it should be loud.
pub fn read_records(path: &Path) -> Result<Vec<DeadLetterRecord>, LsspcaError> {
    let f = File::open(path)
        .map_err(|e| LsspcaError::io_at(path, format!("open dead-letter queue: {e}")))?;
    let mut out = Vec::new();
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line
            .map_err(|e| LsspcaError::io_at(path, format!("read dead-letter queue: {e}")))?;
        if line.trim().is_empty() {
            continue;
        }
        let bad = |what: &str| {
            LsspcaError::io_at(path, format!("dead-letter record {}: {what}", i + 1))
        };
        let v = Json::parse(&line).map_err(|e| bad(&format!("bad JSON: {}", e.message())))?;
        let offset = v
            .get("offset")
            .and_then(Json::as_f64)
            .filter(|o| o.fract() == 0.0 && *o >= 0.0)
            .ok_or_else(|| bad("missing offset"))? as u64;
        let reason_str = v
            .get("reason")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing reason"))?
            .to_string();
        let detail =
            v.get("detail").and_then(Json::as_str).ok_or_else(|| bad("missing detail"))?.to_string();
        let raw =
            v.get("line").and_then(Json::as_str).ok_or_else(|| bad("missing line"))?.to_string();
        let stored_crc = v.get("crc").and_then(Json::as_str).unwrap_or("").to_string();
        let reason = BadRecordReason::parse(&reason_str);
        let crc_ok = match reason {
            Some(r) => {
                let prefix = record_prefix(offset, r, &detail, &raw);
                format!("{:016x}", xor_fold_checksum(prefix.as_bytes())) == stored_crc
            }
            None => false,
        };
        out.push(DeadLetterRecord { offset, reason, reason_str, detail, line: raw, crc_ok });
    }
    Ok(out)
}

/// Merge per-shard dead-letter files from a distributed run into the
/// main queue at `main`, deduplicating by source offset: two workers (or
/// two passes) that both hit the same malformed line quarantine it
/// exactly once in the merged queue. Records are folded in ascending
/// offset order so the merged file's line order is independent of shard
/// completion order. Shard files are removed after a successful merge;
/// a missing shard file is fine (that worker saw no bad records).
/// Returns the merged queue's distinct-record count.
pub fn merge_shard_queues(main: &Path, shard_paths: &[PathBuf]) -> Result<u64, LsspcaError> {
    let mut incoming: Vec<DeadLetterRecord> = Vec::new();
    for p in shard_paths {
        if !p.exists() {
            continue;
        }
        incoming.extend(read_records(p)?);
    }
    incoming.sort_by_key(|r| r.offset);
    let mut q = DeadLetterQueue::open(main)?;
    for r in &incoming {
        let Some(reason) = r.reason else {
            // machine-written shard files only carry known reasons; an
            // unknown one means damage, which must stay loud
            return Err(LsspcaError::io_at(
                main,
                format!("shard dead-letter record with unknown reason {:?}", r.reason_str),
            ));
        };
        q.quarantine(r.offset, reason, &r.detail, &r.line)?;
    }
    for p in shard_paths {
        match std::fs::remove_file(p) {
            Err(e) if e.kind() != std::io::ErrorKind::NotFound => {
                return Err(LsspcaError::io_at(p, format!("remove shard dead-letter file: {e}")));
            }
            _ => {}
        }
    }
    Ok(q.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lsspca_dlq_{}_{name}", std::process::id()))
    }

    #[test]
    fn quarantine_roundtrips_with_valid_crc() {
        let p = tmp("rt.jsonl");
        std::fs::remove_file(&p).ok();
        let mut q = DeadLetterQueue::open(&p).unwrap();
        q.quarantine(17, BadRecordReason::WordOutOfRange, "wordID 9 exceeds W=5", "3 9 1")
            .unwrap();
        q.quarantine(21, BadRecordReason::ZeroId, "ids are 1-based", "0 3 1").unwrap();
        assert_eq!(q.len(), 2);
        let recs = read_records(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].offset, 17);
        assert_eq!(recs[0].reason, Some(BadRecordReason::WordOutOfRange));
        assert_eq!(recs[0].line, "3 9 1");
        assert!(recs.iter().all(|r| r.crc_ok), "{recs:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_offsets_recorded_once() {
        let p = tmp("dup.jsonl");
        std::fs::remove_file(&p).ok();
        let mut q = DeadLetterQueue::open(&p).unwrap();
        q.quarantine(5, BadRecordReason::BadCount, "x", "1 2 huh").unwrap();
        q.quarantine(5, BadRecordReason::BadCount, "x", "1 2 huh").unwrap();
        assert_eq!(q.len(), 1);
        drop(q);
        // a second pass re-opens the queue and re-encounters the line
        let mut q2 = DeadLetterQueue::open(&p).unwrap();
        assert_eq!(q2.len(), 1, "existing records count toward the budget");
        q2.quarantine(5, BadRecordReason::BadCount, "x", "1 2 huh").unwrap();
        assert_eq!(q2.len(), 1);
        assert_eq!(read_records(&p).unwrap().len(), 1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn tampered_record_fails_crc() {
        let p = tmp("tamper.jsonl");
        std::fs::remove_file(&p).ok();
        let mut q = DeadLetterQueue::open(&p).unwrap();
        q.quarantine(3, BadRecordReason::BadDocId, "bad docID", "x 2 1").unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, text.replace("x 2 1", "y 2 1")).unwrap();
        let recs = read_records(&p).unwrap();
        assert!(!recs[0].crc_ok, "{recs:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn record_bytes_are_stable() {
        // Pinned layout shared with python/tests/test_fault_mirror.py:
        // the identical inputs must serialize to the identical line,
        // checksum hex included, in both languages.
        let rec = format_record(17, BadRecordReason::WordOutOfRange, "wordID 9 exceeds W=5", "3 9 1");
        assert_eq!(
            rec,
            "{\"offset\":17,\"reason\":\"word-out-of-range\",\
             \"detail\":\"wordID 9 exceeds W=5\",\"line\":\"3 9 1\",\
             \"crc\":\"7e673c33f156083c\"}"
        );
        // escaping: quotes, backslashes, control chars
        let rec = format_record(1, BadRecordReason::BadDocId, "a\"b\\c", "tab\there");
        assert!(rec.contains("a\\\"b\\\\c"), "{rec}");
        assert!(rec.contains("tab\\u0009here"), "{rec}");
    }

    #[test]
    fn policy_enforces_budget_after_recording() {
        let p = tmp("budget.jsonl");
        std::fs::remove_file(&p).ok();
        let mut pol = RecordPolicy::new(2, DeadLetterQueue::open(&p).unwrap());
        pol.admit(1, BadRecordReason::BadCount, "x", "1 1 a").unwrap();
        pol.admit(2, BadRecordReason::BadCount, "x", "1 1 b").unwrap();
        // a duplicate offset does not consume budget
        pol.admit(2, BadRecordReason::BadCount, "x", "1 1 b").unwrap();
        let err = pol.admit(3, BadRecordReason::BadCount, "x", "1 1 c").unwrap_err();
        assert!(matches!(err, LsspcaError::Corpus { .. }));
        assert!(err.to_string().contains("too many bad records"), "{err}");
        // the record that broke the budget is still on disk (evidence)
        assert_eq!(read_records(&p).unwrap().len(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shard_queues_merge_with_offset_dedup() {
        let main = tmp("merge_main.jsonl");
        let s0 = tmp("merge_s0.jsonl");
        let s1 = tmp("merge_s1.jsonl");
        for p in [&main, &s0, &s1] {
            std::fs::remove_file(p).ok();
        }
        // both shards saw offset 9 (a chunk-boundary re-read); shard 1
        // additionally saw offset 4, which must sort before 9
        let mut q0 = DeadLetterQueue::open(&s0).unwrap();
        q0.quarantine(9, BadRecordReason::ZeroId, "ids are 1-based", "0 3 1").unwrap();
        drop(q0);
        let mut q1 = DeadLetterQueue::open(&s1).unwrap();
        q1.quarantine(9, BadRecordReason::ZeroId, "ids are 1-based", "0 3 1").unwrap();
        q1.quarantine(4, BadRecordReason::BadCount, "x", "1 2 huh").unwrap();
        drop(q1);
        let total = merge_shard_queues(&main, &[s0.clone(), s1.clone()]).unwrap();
        assert_eq!(total, 2);
        let recs = read_records(&main).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].offset, 4, "merged order is ascending offset");
        assert_eq!(recs[1].offset, 9);
        assert!(recs.iter().all(|r| r.crc_ok));
        assert!(!s0.exists() && !s1.exists(), "shard files removed after merge");
        // merging again (e.g. a resumed coordinator) is a no-op
        let total = merge_shard_queues(&main, &[s0.clone(), s1.clone()]).unwrap();
        assert_eq!(total, 2);
        assert_eq!(read_records(&main).unwrap().len(), 2);
        std::fs::remove_file(&main).ok();
    }

    #[test]
    fn reason_strings_roundtrip() {
        for r in [
            BadRecordReason::BadDocId,
            BadRecordReason::BadWordId,
            BadRecordReason::BadCount,
            BadRecordReason::ZeroId,
            BadRecordReason::WordOutOfRange,
            BadRecordReason::NonMonotonicDoc,
            BadRecordReason::GzipCrc,
        ] {
            assert_eq!(BadRecordReason::parse(r.as_str()), Some(r));
        }
        assert_eq!(BadRecordReason::parse("whatever"), None);
    }
}
