//! The two covariance models of the paper's Fig 1 speed comparison.

use crate::data::SymMat;
use crate::linalg::vec::normalize;
use crate::util::rng::Rng;

/// `Σ = FᵀF / m` with `F ∈ R^{m×n}` i.i.d. standard Gaussian — the
/// left-panel model of Fig 1.
pub fn gaussian_factor_cov(n: usize, m: usize, rng: &mut Rng) -> SymMat {
    let f: Vec<f64> = (0..m * n).map(|_| rng.gauss()).collect();
    SymMat::gram(m, n, &f)
}

/// Spiked covariance `Σ = snr·uuᵀ + VVᵀ/m` with a sparse unit spike `u`
/// of cardinality `card` and Gaussian noise `V ∈ R^{n×m}` — the
/// right-panel model of Fig 1 (after [2]). Returns `(Σ, u)` so recovery
/// can be verified against ground truth.
pub fn spiked_covariance_with_u(
    n: usize,
    m: usize,
    card: usize,
    snr: f64,
    rng: &mut Rng,
) -> (SymMat, Vec<f64>) {
    assert!(card >= 1 && card <= n);
    let mut u = vec![0.0f64; n];
    let support = rng.sample_indices(n, card);
    for &i in &support {
        // nonzero magnitudes bounded away from 0 so the support is crisp
        u[i] = rng.range_f64(0.5, 1.0) * if rng.bool(0.5) { 1.0 } else { -1.0 };
    }
    normalize(&mut u);
    // noise part VVᵀ/m
    let v: Vec<f64> = (0..n * m).map(|_| rng.gauss()).collect();
    let mut sigma = SymMat::zeros(n);
    {
        let buf = sigma.as_mut_slice();
        for i in 0..n {
            for j in i..n {
                let mut acc = 0.0;
                let (ri, rj) = (&v[i * m..(i + 1) * m], &v[j * m..(j + 1) * m]);
                for k in 0..m {
                    acc += ri[k] * rj[k];
                }
                let val = acc / m as f64 + snr * u[i] * u[j];
                buf[i * n + j] = val;
                buf[j * n + i] = val;
            }
        }
    }
    (sigma, u)
}

/// Spiked covariance, discarding the ground-truth spike.
pub fn spiked_covariance(n: usize, m: usize, card: usize, snr: f64, rng: &mut Rng) -> SymMat {
    spiked_covariance_with_u(n, m, card, snr, rng).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::is_psd;
    use crate::linalg::vec::{cardinality, norm2};
    use crate::util::check::{ensure, property};

    #[test]
    fn gaussian_factor_psd_and_scale() {
        let mut rng = Rng::seed_from(61);
        let s = gaussian_factor_cov(12, 40, &mut rng);
        assert!(is_psd(&s, 1e-9));
        // E[Σ_ii] = 1 for standard Gaussian factors
        let mean_diag = s.trace() / 12.0;
        assert!((mean_diag - 1.0).abs() < 0.5, "mean diag {mean_diag}");
    }

    #[test]
    fn spiked_properties() {
        property("spiked model: PSD, unit sparse spike", 10, |rng| {
            let n = rng.range(5, 30);
            let card = rng.range(1, n.min(6));
            let m = rng.range(5, 40);
            let (s, u) = spiked_covariance_with_u(n, m, card, 2.0, rng);
            ensure(is_psd(&s, 1e-9), "spiked must be PSD")?;
            ensure(cardinality(&u, 1e-12) == card, "spike cardinality")?;
            crate::util::check::close(norm2(&u), 1.0, 1e-9)?;
            Ok(())
        });
    }

    #[test]
    fn spike_dominates_leading_direction() {
        // With high SNR the top eigenvector should align with u.
        let mut rng = Rng::seed_from(63);
        let (s, u) = spiked_covariance_with_u(30, 200, 3, 10.0, &mut rng);
        let e = crate::linalg::eig::JacobiEig::new(&s);
        let v = e.vector(0);
        let align: f64 = v.iter().zip(&u).map(|(a, b)| a * b).sum::<f64>().abs();
        assert!(align > 0.95, "alignment {align}");
    }
}
