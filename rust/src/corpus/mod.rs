//! Synthetic workload generation.
//!
//! The paper evaluates on the UCI NYTimes and PubMed bag-of-words corpora,
//! which are not available in this offline environment. Per DESIGN.md §3 we
//! substitute generators that preserve exactly the structure the paper's
//! method exploits:
//!
//! - [`synth`] — Zipf-distributed word marginals with planted topics,
//!   emitted in the UCI `docword` format. Zipf marginals give the
//!   rapidly-decaying ranked variance profile of Fig 2; planted topics give
//!   recoverable interpretable sparse PCs (Tables 1–2) *with ground truth*.
//! - [`models`] — the two covariance models of Fig 1: `Σ = FᵀF/m` with
//!   Gaussian `F`, and the spiked model `Σ = uuᵀ + VVᵀ/m`.
//! - [`alias`] — Walker alias sampling, the O(1) categorical sampler the
//!   document generator is built on.

pub mod alias;
pub mod models;
pub mod synth;

pub use alias::AliasTable;
pub use models::{gaussian_factor_cov, spiked_covariance, spiked_covariance_with_u};
pub use synth::{CorpusSpec, SynthCorpus, TopicSpec};
