//! Synthetic bag-of-words corpora with Zipf marginals and planted topics.
//!
//! Substitutes the UCI NYTimes / PubMed corpora (DESIGN.md §3). The
//! generative model:
//!
//! - Background word frequencies follow a Zipf law `p(r) ∝ (r+1)^(-s)` —
//!   this yields the rapidly decaying ranked variance profile of Fig 2.
//! - `K` planted topics, each with a short signature word list (taken from
//!   the paper's own Tables 1–2, so a successful reproduction prints
//!   recognizably the same topic tables). A topical document draws a
//!   fraction `topic_mix` of its tokens from its topic's signature words,
//!   making those words *bursty*: high variance, strongly co-occurring —
//!   exactly the structure sparse PCA extracts.
//! - Document lengths are Poisson.
//!
//! Generation is deterministic given a seed, and the docword writer uses
//! two passes with the *same* seed (first to count NNZ for the header,
//! then to emit triples), so corpora of any size stream to disk in O(1)
//! memory — the property that makes PubMed-scale generation feasible.

use std::path::Path;

use crate::corpus::alias::AliasTable;
use crate::data::docword::{DocwordHeader, DocwordWriter};
use crate::data::sparse::{CsrMatrix, TripletMatrix};
use crate::data::vocab::Vocab;
use crate::util::rng::Rng;

/// One planted topic.
#[derive(Clone, Debug)]
pub struct TopicSpec {
    /// Topic label (reporting only).
    pub name: &'static str,
    /// Signature words planted for this topic.
    pub words: Vec<&'static str>,
}

/// Full corpus specification.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Preset name (reporting / cache identity).
    pub name: &'static str,
    /// Documents to generate.
    pub num_docs: usize,
    /// Vocabulary size n.
    pub vocab_size: usize,
    /// Zipf exponent for background frequencies.
    pub zipf_exponent: f64,
    /// Zipf rank shift: weight(r) ∝ (r + shift)^(-s). A shift flattens the
    /// extreme head of the distribution so that the very top background
    /// words do not out-variance the bursty topic words — mirroring real
    /// bag-of-words data where stopword-ish heads are pruned from the UCI
    /// vocabularies (both NYTimes and PubMed ship with stopwords removed).
    pub zipf_shift: f64,
    /// Mean document length (tokens).
    pub mean_doc_len: f64,
    /// Fraction of documents that are topical (vs pure background).
    pub topic_doc_fraction: f64,
    /// Fraction of a topical document's tokens drawn from its topic.
    pub topic_mix: f64,
    /// First background rank reserved for topic signature words.
    pub topic_rank_base: usize,
    /// The planted topics.
    pub topics: Vec<TopicSpec>,
}

impl CorpusSpec {
    /// NYTimes-like preset. The five planted topics are the paper's
    /// Table 1 principal components (business / sports / U.S. / politics /
    /// education). Scaled to this testbed by default; use
    /// [`CorpusSpec::scaled`] for other sizes.
    pub fn nytimes() -> CorpusSpec {
        CorpusSpec {
            name: "nytimes-synth",
            num_docs: 50_000,
            vocab_size: 30_000,
            zipf_exponent: 1.05,
            zipf_shift: 50.0,
            mean_doc_len: 150.0,
            topic_doc_fraction: 0.5,
            topic_mix: 0.25,
            topic_rank_base: 120,
            topics: vec![
                TopicSpec {
                    name: "business",
                    words: vec!["million", "percent", "business", "company", "market", "companies"],
                },
                TopicSpec {
                    name: "sports",
                    words: vec!["point", "play", "team", "season", "game"],
                },
                TopicSpec {
                    name: "us",
                    words: vec!["official", "government", "united_states", "u_s", "attack"],
                },
                TopicSpec {
                    name: "politics",
                    words: vec!["president", "campaign", "bush", "administration"],
                },
                TopicSpec {
                    name: "education",
                    words: vec!["school", "program", "children", "student"],
                },
            ],
        }
    }

    /// PubMed-like preset; topics are the paper's Table 2 components.
    pub fn pubmed() -> CorpusSpec {
        CorpusSpec {
            name: "pubmed-synth",
            num_docs: 80_000,
            vocab_size: 40_000,
            zipf_exponent: 1.1,
            zipf_shift: 50.0,
            mean_doc_len: 90.0, // abstracts are shorter than articles
            topic_doc_fraction: 0.5,
            topic_mix: 0.25,
            topic_rank_base: 120,
            topics: vec![
                TopicSpec {
                    name: "clinical",
                    words: vec!["patient", "cell", "treatment", "protein", "disease"],
                },
                TopicSpec {
                    name: "pharmacology",
                    words: vec!["effect", "level", "activity", "concentration", "rat"],
                },
                TopicSpec {
                    name: "molecular",
                    words: vec!["human", "expression", "receptor", "binding"],
                },
                TopicSpec {
                    name: "oncology",
                    words: vec!["tumor", "mice", "cancer", "malignant", "carcinoma"],
                },
                TopicSpec {
                    name: "pediatric",
                    words: vec!["year", "infection", "age", "children", "child"],
                },
            ],
        }
    }

    /// Preset by name ("nytimes" | "pubmed").
    pub fn preset(name: &str) -> Option<CorpusSpec> {
        match name {
            "nytimes" => Some(Self::nytimes()),
            "pubmed" => Some(Self::pubmed()),
            _ => None,
        }
    }

    /// Override document and vocabulary counts (0 keeps the preset value).
    pub fn scaled(mut self, docs: usize, vocab: usize) -> CorpusSpec {
        if docs > 0 {
            self.num_docs = docs;
        }
        if vocab > 0 {
            self.vocab_size = vocab;
        }
        let needed = self.topic_rank_base + self.topics.iter().map(|t| t.words.len()).sum::<usize>();
        assert!(
            self.vocab_size > needed,
            "vocab_size {} too small for topic layout (need > {needed})",
            self.vocab_size
        );
        self
    }
}

/// A prepared generator for one corpus.
pub struct SynthCorpus {
    /// The specification this generator realizes.
    pub spec: CorpusSpec,
    /// Generator seed (documents are a pure function of `(spec, seed)`).
    pub seed: u64,
    /// Vocabulary (topic words at their planted ids, `wNNNNN` elsewhere).
    pub vocab: Vocab,
    /// Planted topic → vocab ids (ground truth for recovery checks).
    pub topic_word_ids: Vec<Vec<usize>>,
    background: AliasTable,
    topic_tables: Vec<AliasTable>,
}

impl SynthCorpus {
    /// Prepare the alias tables for a spec (no documents generated yet).
    pub fn new(spec: CorpusSpec, seed: u64) -> SynthCorpus {
        let v = spec.vocab_size;
        // Background Zipf weights over all vocab ids. Vocab id == frequency
        // rank (id 0 most frequent) — matches how UCI vocab files tend to
        // correlate with frequency, and makes Fig 2's x-axis natural.
        let mut weights: Vec<f64> = (0..v)
            .map(|r| 1.0 / ((r + 1) as f64 + spec.zipf_shift).powf(spec.zipf_exponent))
            .collect();
        // Plant topic words at consecutive ids starting at topic_rank_base;
        // their *background* weight stays the Zipf weight of that rank (they
        // are ordinary mid-frequency words outside their topic).
        let mut names: Vec<String> = (0..v).map(|i| format!("w{i:06}")).collect();
        let mut topic_word_ids = Vec::new();
        let mut next = spec.topic_rank_base;
        for t in &spec.topics {
            let mut ids = Vec::new();
            for w in &t.words {
                assert!(next < v, "vocab too small for topic words");
                names[next] = (*w).to_string();
                ids.push(next);
                next += 1;
            }
            topic_word_ids.push(ids);
        }
        // Per-topic signature sampler: mildly uneven weights so the PC
        // loading order is stable (first listed word loads heaviest,
        // mirroring the paper's table ordering).
        let topic_tables = topic_word_ids
            .iter()
            .map(|ids| {
                let w: Vec<f64> = (0..ids.len()).map(|k| 1.0 / (1.0 + 0.25 * k as f64)).collect();
                AliasTable::new(&w)
            })
            .collect();
        // Topic words keep their background weight too — fine; build table.
        let background = AliasTable::new(&weights);
        weights.clear();
        SynthCorpus {
            spec,
            seed,
            vocab: Vocab::new(names),
            topic_word_ids,
            background,
            topic_tables,
        }
    }

    /// Topic assignment for a document index (None = background doc).
    /// Derived from the doc's own RNG so both generation passes agree.
    fn doc_topic(&self, rng: &mut Rng) -> Option<usize> {
        if rng.bool(self.spec.topic_doc_fraction) {
            Some(rng.below(self.spec.topics.len()))
        } else {
            None
        }
    }

    /// Generate document `d` as sorted `(word_id, count)` pairs.
    ///
    /// Each document uses an RNG seeded from `(corpus seed, d)`, so
    /// generation is random-access: pass 1 (count nnz) and pass 2 (write)
    /// see identical documents, and chunked/parallel generation is safe.
    pub fn generate_doc(&self, d: usize) -> Vec<(u32, f64)> {
        let mut rng = Rng::seed_from(self.seed ^ (d as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let topic = self.doc_topic(&mut rng);
        let len = rng.poisson(self.spec.mean_doc_len).max(1);
        let mut counts: Vec<(u32, f64)> = Vec::with_capacity(len as usize / 2);
        let mut raw: Vec<u32> = Vec::with_capacity(len as usize);
        for _ in 0..len {
            let w = match topic {
                Some(t) if rng.f64() < self.spec.topic_mix => {
                    let k = self.topic_tables[t].sample(&mut rng);
                    self.topic_word_ids[t][k] as u32
                }
                _ => self.background.sample(&mut rng) as u32,
            };
            raw.push(w);
        }
        raw.sort_unstable();
        for w in raw {
            match counts.last_mut() {
                Some((lw, c)) if *lw == w => *c += 1.0,
                _ => counts.push((w, 1.0)),
            }
        }
        counts
    }

    /// Write the corpus in UCI docword format (two deterministic passes:
    /// count then emit). Also writes `<path>.vocab` with the vocabulary.
    pub fn write_docword(&self, path: &Path) -> Result<DocwordHeader, crate::error::LsspcaError> {
        // pass 1: count nnz
        let mut nnz = 0usize;
        for d in 0..self.spec.num_docs {
            nnz += self.generate_doc(d).len();
        }
        let header = DocwordHeader {
            num_docs: self.spec.num_docs,
            vocab_size: self.spec.vocab_size,
            nnz,
        };
        // pass 2: emit
        let mut w = DocwordWriter::create(path, header)?;
        for d in 0..self.spec.num_docs {
            let doc = self.generate_doc(d);
            w.write_doc(d, &doc)?;
        }
        w.finish()?;
        let vocab_path = path.with_extension("vocab");
        self.vocab.save(&vocab_path)?;
        Ok(header)
    }

    /// Materialize the whole corpus as an in-memory CSR matrix (for tests
    /// and small benchmark runs; prefer streaming for large corpora).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut t = TripletMatrix::new(self.spec.num_docs, self.spec.vocab_size);
        for d in 0..self.spec.num_docs {
            for (w, c) in self.generate_doc(d) {
                t.push(d, w as usize, c);
            }
        }
        t.to_csr()
    }

    /// All planted topic word ids, flattened (ground truth support union).
    pub fn planted_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.topic_word_ids.iter().flatten().copied().collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moments::FeatureMoments;

    fn tiny() -> SynthCorpus {
        let spec = CorpusSpec::nytimes().scaled(400, 2000);
        SynthCorpus::new(spec, 99)
    }

    #[test]
    fn docs_deterministic_and_sorted() {
        let c = tiny();
        let d1 = c.generate_doc(7);
        let d2 = c.generate_doc(7);
        assert_eq!(d1, d2);
        assert!(d1.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(!d1.is_empty());
    }

    #[test]
    fn distinct_docs_differ() {
        let c = tiny();
        assert_ne!(c.generate_doc(1), c.generate_doc(2));
    }

    #[test]
    fn vocab_contains_topic_words() {
        let c = tiny();
        assert_eq!(c.topic_word_ids.len(), 5);
        let id = c.topic_word_ids[0][0];
        assert_eq!(c.vocab.word(id), "million");
        // planted ids are in the reserved band
        for ids in &c.topic_word_ids {
            for &i in ids {
                assert!(i >= c.spec.topic_rank_base);
                assert!(i < c.spec.topic_rank_base + 30);
            }
        }
    }

    #[test]
    fn write_and_reread_roundtrip() {
        let spec = CorpusSpec::nytimes().scaled(60, 1500);
        let c = SynthCorpus::new(spec, 5);
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_synth_{}.txt", std::process::id()));
        let hdr = c.write_docword(&p).unwrap();
        assert_eq!(hdr.num_docs, 60);
        let mut r = crate::data::docword::DocwordReader::open(&p).unwrap();
        assert_eq!(r.header(), hdr);
        let mut total = 0;
        let mut docs = 0;
        while let Some(chunk) = r.next_chunk(16).unwrap() {
            for doc in &chunk.docs {
                assert_eq!(doc.words, c.generate_doc(doc.id));
                docs += 1;
                total += doc.words.len();
            }
        }
        assert_eq!(docs, 60);
        assert_eq!(total, hdr.nnz);
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(p.with_extension("vocab")).ok();
    }

    #[test]
    fn topic_words_are_high_variance() {
        // The planted mechanism must make signature words high-variance —
        // that's what lets them survive safe elimination.
        let c = tiny();
        let mut m = FeatureMoments::new(c.spec.vocab_size);
        for d in 0..c.spec.num_docs {
            m.push_doc(&c.generate_doc(d));
        }
        let f = m.finalize();
        let ranked = f.ranked();
        let top: Vec<usize> = ranked.iter().take(80).map(|&(i, _)| i).collect();
        let planted = c.planted_ids();
        let hits = planted.iter().filter(|id| top.contains(id)).count();
        assert!(
            hits >= planted.len() * 3 / 4,
            "only {hits}/{} planted words in top-80 by variance",
            planted.len()
        );
    }

    #[test]
    fn variance_profile_decays() {
        let c = tiny();
        let mut m = FeatureMoments::new(c.spec.vocab_size);
        for d in 0..c.spec.num_docs {
            m.push_doc(&c.generate_doc(d));
        }
        let sv = m.finalize().sorted_variances();
        // strong decay: median variance orders of magnitude below max
        let mid = sv[sv.len() / 2];
        assert!(sv[0] > 50.0 * mid.max(1e-12), "sv0={} mid={}", sv[0], mid);
    }

    #[test]
    fn presets_valid() {
        for name in ["nytimes", "pubmed"] {
            let s = CorpusSpec::preset(name).unwrap();
            assert!(s.vocab_size > s.topic_rank_base + 40);
            assert_eq!(s.topics.len(), 5);
        }
        assert!(CorpusSpec::preset("bogus").is_none());
    }
}
