//! Walker alias method: O(n) construction, O(1) sampling from a fixed
//! categorical distribution. The document generator draws ~10⁶–10⁸ words
//! per corpus, so constant-time sampling matters (see §Perf).

use crate::util::rng::Rng;

/// Alias table over `n` categories.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights.
    pub fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one category");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        assert!(
            weights.iter().all(|&w| w >= 0.0 && w.is_finite()),
            "weights must be finite and non-negative"
        );
        // Scaled probabilities * n; split into small/large worklists.
        let mut prob: Vec<f64> = weights.iter().map(|&w| w * n as f64 / total).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s as usize] = l;
            // large donates the deficit of small
            prob[l as usize] -= 1.0 - prob[s as usize];
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Remaining entries are exactly 1 (up to FP error).
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Whether the table has no categories.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one category index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{ensure, property};

    #[test]
    fn single_category() {
        let t = AliasTable::new(&[3.0]);
        let mut rng = Rng::seed_from(51);
        for _ in 0..10 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let t = AliasTable::new(&[1.0, 0.0, 1.0]);
        let mut rng = Rng::seed_from(52);
        for _ in 0..5000 {
            assert_ne!(t.sample(&mut rng), 1);
        }
    }

    #[test]
    fn prop_empirical_matches_weights() {
        property("alias sampling matches distribution", 8, |rng| {
            let n = rng.range(2, 12);
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
            let total: f64 = w.iter().sum();
            let t = AliasTable::new(&w);
            let draws = 60_000;
            let mut counts = vec![0usize; n];
            for _ in 0..draws {
                counts[t.sample(rng)] += 1;
            }
            for i in 0..n {
                let want = w[i] / total;
                let got = counts[i] as f64 / draws as f64;
                // 5-sigma binomial bound
                let sigma = (want * (1.0 - want) / draws as f64).sqrt();
                ensure(
                    (got - want).abs() < 5.0 * sigma + 1e-3,
                    format!("cat {i}: want {want:.4} got {got:.4}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "weights must not all be zero")]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
