//! Declarative command-line parsing (offline substitute for `clap`, see
//! DESIGN.md §3).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! typed accessors with defaults, required arguments, and auto-generated
//! `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::LsspcaError;

/// Specification of one flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name as typed after `--`.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value substituted when the flag is absent.
    pub default: Option<String>,
    /// Boolean switch (present = true, takes no value).
    pub is_switch: bool,
    /// Parsing fails when a required flag is absent.
    pub required: bool,
}

/// Specification of a (sub)command.
#[derive(Clone, Debug, Default)]
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description shown in the top-level help.
    pub about: &'static str,
    /// Flags this command accepts.
    pub flags: Vec<FlagSpec>,
}

impl CommandSpec {
    /// Start a command spec with no flags.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        CommandSpec { name, about, flags: Vec::new() }
    }

    /// A flag taking a value, with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
            required: false,
        });
        self
    }

    /// A required flag taking a value.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: false, required: true });
        self
    }

    /// A boolean switch (present = true).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: true, required: false });
        self
    }

    fn find(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    fn usage(&self, prog: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "usage: {prog} {} [flags]\n\nflags:", self.name);
        for f in &self.flags {
            let meta = if f.is_switch {
                format!("--{}", f.name)
            } else {
                format!("--{} <v>", f.name)
            };
            let default = match (&f.default, f.required) {
                (Some(d), _) => format!(" [default: {d}]"),
                (None, true) => " [required]".to_string(),
                _ => String::new(),
            };
            let _ = writeln!(s, "  {meta:<26} {}{default}", f.help);
        }
        s
    }
}

/// Parsed arguments for one command.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Trailing positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Raw value of a flag, if present (or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of a flag that the spec guarantees exists (has a default).
    pub fn str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("flag --{name} missing (spec bug)"))
            .to_string()
    }

    /// Parse a flag's value into any `FromStr` type, with a
    /// flag-naming error message.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, LsspcaError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| LsspcaError::config(format!("missing required flag --{name}")))?;
        raw.parse::<T>()
            .map_err(|e| LsspcaError::config(format!("invalid value '{raw}' for --{name}: {e}")))
    }

    /// `parse::<usize>` convenience.
    pub fn usize(&self, name: &str) -> Result<usize, LsspcaError> {
        self.parse(name)
    }

    /// `parse::<f64>` convenience.
    pub fn f64(&self, name: &str) -> Result<f64, LsspcaError> {
        self.parse(name)
    }

    /// `parse::<u64>` convenience.
    pub fn u64(&self, name: &str) -> Result<u64, LsspcaError> {
        self.parse(name)
    }

    /// Whether a boolean switch was present.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// A multi-command CLI application.
#[derive(Debug, Default)]
pub struct App {
    /// Program name (argv\[0\] replacement in help text).
    pub prog: &'static str,
    /// One-line program description.
    pub about: &'static str,
    /// Registered subcommands.
    pub commands: Vec<CommandSpec>,
}

/// Result of parsing: the selected command name and its arguments.
#[derive(Debug)]
pub enum Parsed {
    /// A subcommand was selected, with its parsed arguments.
    Command(String, Args),
    /// `--help` or no args: the rendered help text to print.
    Help(String),
}

impl App {
    /// Start an application spec with no commands.
    pub fn new(prog: &'static str, about: &'static str) -> Self {
        App { prog, about, commands: Vec::new() }
    }

    /// Register a subcommand (builder style).
    pub fn command(mut self, spec: CommandSpec) -> Self {
        self.commands.push(spec);
        self
    }

    fn top_help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n\ncommands:", self.prog, self.about);
        for c in &self.commands {
            let _ = writeln!(s, "  {:<18} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nrun `{} <command> --help` for per-command flags", self.prog);
        s
    }

    /// Parse an argument vector (excluding argv\[0\]). Failures are
    /// [`LsspcaError::Config`] (exit code 2 in `main`).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, LsspcaError> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Ok(Parsed::Help(self.top_help()));
        }
        let cmd_name = &argv[0];
        let spec = self.commands.iter().find(|c| c.name == cmd_name).ok_or_else(|| {
            LsspcaError::config(format!("unknown command '{cmd_name}'\n\n{}", self.top_help()))
        })?;

        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Ok(Parsed::Help(spec.usage(self.prog)));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let flag = spec.find(&name).ok_or_else(|| {
                    LsspcaError::config(format!("unknown flag --{name} for '{cmd_name}'"))
                })?;
                if flag.is_switch {
                    if inline_val.is_some() {
                        return Err(LsspcaError::config(format!("switch --{name} takes no value")));
                    }
                    switches.push(name);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| {
                                LsspcaError::config(format!("flag --{name} expects a value"))
                            })?
                        }
                    };
                    values.insert(name, val);
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        // Fill defaults; enforce required.
        for f in &spec.flags {
            if f.is_switch {
                continue;
            }
            if !values.contains_key(f.name) {
                match (&f.default, f.required) {
                    (Some(d), _) => {
                        values.insert(f.name.to_string(), d.clone());
                    }
                    (None, true) => {
                        return Err(LsspcaError::config(format!(
                            "missing required flag --{}\n\n{}",
                            f.name,
                            spec.usage(self.prog)
                        )));
                    }
                    _ => {}
                }
            }
        }
        Ok(Parsed::Command(cmd_name.clone(), Args { values, switches, positional }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("lsspca", "sparse pca").command(
            CommandSpec::new("solve", "run solver")
                .opt("lambda", "0.5", "penalty")
                .opt("n", "100", "size")
                .req("input", "input path")
                .switch("verbose", "chatty"),
        )
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let p = app().parse(&sv(&["solve", "--input", "x.txt", "--lambda=0.9"])).unwrap();
        match p {
            Parsed::Command(name, args) => {
                assert_eq!(name, "solve");
                assert_eq!(args.f64("lambda").unwrap(), 0.9);
                assert_eq!(args.usize("n").unwrap(), 100);
                assert_eq!(args.str("input"), "x.txt");
                assert!(!args.switch("verbose"));
            }
            _ => panic!("expected command"),
        }
    }

    #[test]
    fn switch_and_positional() {
        let p = app()
            .parse(&sv(&["solve", "--input", "a", "--verbose", "pos1"]))
            .unwrap();
        if let Parsed::Command(_, args) = p {
            assert!(args.switch("verbose"));
            assert_eq!(args.positional, vec!["pos1"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn missing_required_errors() {
        let e = app().parse(&sv(&["solve"])).unwrap_err();
        assert!(matches!(e, LsspcaError::Config { .. }));
        assert!(e.to_string().contains("--input"));
    }

    #[test]
    fn unknown_flag_errors() {
        let e = app().parse(&sv(&["solve", "--bogus", "1"])).unwrap_err();
        assert!(e.to_string().contains("bogus"));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(app().parse(&sv(&["nope"])).is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&sv(&[])).unwrap(), Parsed::Help(_)));
        assert!(matches!(app().parse(&sv(&["solve", "--help"])).unwrap(), Parsed::Help(_)));
    }

    #[test]
    fn bad_value_reports_flag() {
        let p = app().parse(&sv(&["solve", "--input", "a", "--n", "abc"])).unwrap();
        if let Parsed::Command(_, args) = p {
            let e = args.usize("n").unwrap_err();
            assert!(e.to_string().contains("--n"));
        } else {
            panic!();
        }
    }
}
