//! # lsspca — Large-Scale Sparse Principal Component Analysis
//!
//! A production-grade reproduction of *"Large-Scale Sparse Principal
//! Component Analysis with Application to Text Data"* (Zhang & El Ghaoui,
//! NIPS 2011) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the coordinator: streaming corpus ingestion,
//!   sharded per-feature moment computation, *safe feature elimination*
//!   (Theorem 2.1), reduced covariance assembly, the *block coordinate
//!   ascent* DSPCA solver (Algorithm 1), baselines, deflation, and the
//!   λ-search driver. Pure Rust on the hot path; no Python at runtime.
//! - **Layer 2 (python/compile/model.py)** — the BCA sweep, Gram assembly
//!   and power iteration as JAX graphs, AOT-lowered once to HLO text.
//! - **Layer 1 (python/compile/kernels/)** — the box-constrained QP
//!   coordinate-descent hot spot as a Pallas kernel.
//!
//! The AOT artifacts are loaded at runtime through the PJRT C API (the
//! `xla` crate) by the `runtime` module (feature `xla`), and exposed
//! behind the [`engine::Engine`] trait next to the optimized native
//! implementation.
//!
//! ## Quick start
//!
//! Solve for one sparse principal component of a small covariance with
//! a planted sparse direction (this example runs as a doc-test):
//!
//! ```
//! use lsspca::prelude::*;
//!
//! let mut rng = Rng::seed_from(7);
//! let sigma = lsspca::corpus::spiked_covariance(40, 200, 4, 1.5, &mut rng);
//! let opts = BcaOptions::default();
//! let sol = lsspca::solver::bca::solve(&sigma, 0.5, &opts);
//! let pc = lsspca::solver::extract::leading_sparse_pc(&sol.z, 1e-6);
//! assert!(pc.cardinality() >= 1, "support = {:?}", pc.support);
//! ```
//!
//! For the full pipeline as a **staged, resumable session** — stream a
//! corpus once, then re-solve at many `(λ, K)` without re-reading it —
//! see [`session::Session`] and its typed [`session::SessionBuilder`]
//! (this is the primary library API; [`coordinator::Pipeline::run`] is
//! a thin one-shot wrapper over it):
//!
//! ```
//! use lsspca::session::{LambdaSpec, Session};
//!
//! let mut session = Session::builder()
//!     .synthetic("nytimes")
//!     .synth_size(300, 1200)
//!     .max_reduced(32)
//!     .bca_sweeps(4)
//!     .build()
//!     .unwrap();
//! session.stream().unwrap();                // pass 1, reused by every fit
//! let fit = session.fit(LambdaSpec::search(5, 2), 1).unwrap();
//! assert_eq!(fit.components.len(), 1);
//! ```
//!
//! Every fallible public API returns the structured [`LsspcaError`]
//! (match on `Config`/`Io`/`Corpus`/`Cache`/`Numeric`/`Serve`); attach
//! a [`session::Progress`] observer to watch stages stream. For the
//! covariance backends (dense / implicit / out-of-core) see [`covop`]
//! and [`cov_disk`]; ARCHITECTURE.md maps the whole system.

#![warn(missing_docs)]

pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod cov;
pub mod cov_disk;
pub mod covop;
pub mod data;
pub mod deadletter;
pub mod dist;
pub mod elim;
pub mod engine;
pub mod error;
pub mod incr;
pub mod jobstate;
pub mod kernels;
pub mod linalg;
pub mod logging;
pub mod model;
pub mod moments;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod score;
pub mod serve;
pub mod session;
pub mod solver;
pub mod stream;
pub mod util;

pub use crate::error::LsspcaError;

/// Convenience re-exports for typical use.
pub mod prelude {
    pub use crate::config::PipelineConfig;
    pub use crate::coordinator::{Pipeline, PipelineReport};
    pub use crate::cov_disk::DiskGramCov;
    pub use crate::covop::{CovOp, DenseCov, GramCov, MaskedCov};
    pub use crate::data::{CscMatrix, CsrMatrix, DocwordHeader, SymMat, TripletMatrix};
    pub use crate::elim::SafeElimination;
    pub use crate::engine::{Engine, NativeEngine};
    pub use crate::error::LsspcaError;
    pub use crate::kernels::{KernelMode, Tier};
    pub use crate::linalg::{power_iteration, JacobiEig};
    pub use crate::model::{Model, ModelPc};
    pub use crate::moments::FeatureMoments;
    pub use crate::score::{ScoreOptions, Scorer};
    pub use crate::serve::{Server, ServerBuilder, ServerHandle};
    pub use crate::session::{FitResult, LambdaSpec, Progress, Session, SessionBuilder, Stage};
    pub use crate::solver::bca::{BcaOptions, BcaSolution};
    pub use crate::solver::extract::SparsePc;
    pub use crate::util::rng::Rng;
}
