//! Covariance operators — the abstraction that lets the solver stack run
//! without ever committing to a dense n̂ × n̂ matrix.
//!
//! The paper's scaling story rests on two facts: (i) safe elimination
//! (Thm 2.1) shrinks the feature set *per λ*, and (ii) for text data the
//! covariance is available *implicitly* as `Σ = AᵀA/m − μμᵀ` from a sparse
//! term matrix. Algorithm 1 itself only ever touches Σ through four
//! operations — a diagonal read, a row gather (`Σ_j`, the box center of
//! the column QP), a matvec, and a quadratic form. [`CovOp`] names exactly
//! those operations, and everything downstream of covariance assembly
//! (`solver/bca`, `solver/lambda`, `solver/path`, `solver/deflate`,
//! `engine`, `coordinator`) is generic over it.
//!
//! Implementations:
//!
//! - [`DenseCov`] — wraps the existing [`SymMat`]; every method delegates
//!   to the dense kernels, so every *solve* (BCA, λ-search probe, masked
//!   view) is **bitwise identical** to the pre-operator pipeline (pinned
//!   by `rust/tests/perf_equivalence.rs`). Across *components*, the
//!   pipeline now deflates via rank-K corrections instead of destructive
//!   dense edits, which reassociates the same arithmetic — PCs after the
//!   first agree with the historical pipeline to ~1e-9, not bitwise.
//!   `SymMat` itself also implements [`CovOp`], so existing call sites
//!   keep compiling unchanged.
//! - [`GramCov`] — the implicit centered-Gram operator over a reduced
//!   CSR/CSC pair of kept-feature columns plus per-feature means. Memory
//!   is O(nnz + n̂) plus a bounded row cache (`solver.row_cache_mb`), so
//!   n̂ can reach tens of thousands without the O(n̂²) dense matrix ever
//!   existing.
//! - [`crate::cov_disk::DiskGramCov`] — the out-of-core twin of
//!   [`GramCov`]: the same operator streamed from an on-disk shard cache
//!   under a configured memory budget, with **bitwise-identical** results
//!   (same summation orders; see `cov_disk`).
//! - [`MaskedCov`] — a zero-copy principal-submatrix view: the per-λ
//!   nested-elimination mask the λ-search solves on (high-λ probes see
//!   only their own Thm-2.1 survivors of one shared superset operator).
//! - [`crate::solver::deflate::DeflatedCov`] — a composable rank-K
//!   correction stacked on any base operator (deflation without
//!   destructive dense edits).
//!
//! ## Memory model and determinism
//!
//! Operators are `Send + Sync` so λ-search probes and path grid points can
//! share one operator across worker threads. [`GramCov`]'s row cache is a
//! `Mutex`-guarded LRU keyed by row index; caching never changes a value
//! (rows are recomputed by the same deterministic kernel on a miss), so
//! results are identical for any cache size or thread count.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::data::sparse::{CscMatrix, CsrMatrix};
use crate::data::SymMat;

// ---------------------------------------------------------------------------
// The traits
// ---------------------------------------------------------------------------

/// Abstract access to a symmetric covariance operator of order `n`.
///
/// The required methods are the four operations Algorithm 1 needs; the
/// provided methods (`row_gather`, `frob_with`, `materialize`) have
/// generic implementations that implementors may shortcut.
///
/// # Example: one matvec, two backends
///
/// The implicit Gram operator and its densified counterpart agree:
///
/// ```
/// use lsspca::covop::{CovOp, GramCov};
/// use lsspca::data::TripletMatrix;
///
/// // A 3-document × 2-feature term matrix.
/// let mut t = TripletMatrix::new(3, 2);
/// t.push(0, 0, 1.0);
/// t.push(1, 0, 2.0);
/// t.push(1, 1, 1.0);
/// let gram = GramCov::new(t.to_csr(), 3, 4); // m = 3 docs, 4 MiB cache
/// let dense = gram.materialize_full();       // Σ as an explicit matrix
///
/// let x = [1.0, -0.5];
/// let (mut y_gram, mut y_dense) = (vec![0.0; 2], vec![0.0; 2]);
/// gram.matvec(&x, &mut y_gram);
/// CovOp::matvec(&dense, &x, &mut y_dense);
/// for (a, b) in y_gram.iter().zip(&y_dense) {
///     assert!((a - b).abs() < 1e-12);
/// }
/// ```
pub trait CovOp: Send + Sync {
    /// Operator order n̂.
    fn n(&self) -> usize;

    /// Diagonal entry `Σ_jj` (feature variance; Thm 2.1's test quantity).
    fn diag(&self, j: usize) -> f64;

    /// Gather row `j` of Σ into `out` (length `n`).
    fn row_into(&self, j: usize, out: &mut [f64]);

    /// Matrix–vector product `y = Σ x`.
    fn matvec(&self, x: &[f64], y: &mut [f64]);

    /// Quadratic form `xᵀ Σ x` (explained variance of a loading vector).
    fn quad_form(&self, x: &[f64]) -> f64 {
        let mut y = vec![0.0; self.n()];
        self.matvec(x, &mut y);
        crate::linalg::vec::dot(x, &y)
    }

    /// Gather the entries `Σ[j, idx[k]]` into `out` (length `idx.len()`)
    /// — the masked-view row kernel. The default gathers the full row
    /// and picks; dense and cached implementations avoid the temporary.
    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        let mut row = vec![0.0; self.n()];
        self.row_into(j, &mut row);
        for (o, &i) in out.iter_mut().zip(idx) {
            *o = row[i];
        }
    }

    /// Frobenius inner product `⟨Σ, X⟩ = Σᵢⱼ Σᵢⱼ Xᵢⱼ` with a dense `X`
    /// (the `Tr ΣX` term of the primal objective).
    ///
    /// The default accumulates in flat row-major order with a single
    /// accumulator — the exact summation order of [`SymMat::frob_dot`] —
    /// so a masked dense view reproduces the materialized-submatrix
    /// objective bitwise.
    fn frob_with(&self, x: &SymMat) -> f64 {
        let n = self.n();
        assert_eq!(x.n(), n);
        let mut row = vec![0.0; n];
        let mut acc = 0.0;
        for i in 0..n {
            self.row_into(i, &mut row);
            let xi = x.row(i);
            for j in 0..n {
                acc += row[j] * xi[j];
            }
        }
        acc
    }

    /// Materialize the principal submatrix on `idx` as a dense matrix
    /// (used by the dual certificate and the XLA engine, which need an
    /// explicit matrix; never called on the GramCov hot path).
    fn materialize(&self, idx: &[usize]) -> SymMat {
        let k = idx.len();
        let mut m = SymMat::zeros(k);
        let mut buf = vec![0.0; k];
        for a in 0..k {
            self.row_gather(idx[a], idx, &mut buf);
            for b in a..k {
                m.set(a, b, buf[b]);
            }
        }
        m
    }

    /// Materialize the whole operator densely.
    fn materialize_full(&self) -> SymMat {
        let idx: Vec<usize> = (0..self.n()).collect();
        self.materialize(&idx)
    }

    /// The dense backing matrix, if this operator is one (fast path for
    /// engines that ship Σ to an accelerator artifact).
    fn as_dense(&self) -> Option<&SymMat> {
        None
    }
}

/// Contiguous dense row access — the box-QP's requirement on its matrix.
///
/// The QP of Algorithm 1 step 4 runs on the solver *iterate* `X` (always
/// dense), not on Σ; its inner loop reads whole rows once per coordinate
/// update and must not pay a gather. This trait spells out that contract
/// so `solver/qp` is generic without giving up the hot path: for
/// [`SymMat`] it monomorphizes to exactly the pre-refactor code.
pub trait DenseRows {
    /// Matrix order.
    fn n(&self) -> usize;

    /// Contiguous row `i` (= column `i` by symmetry).
    fn row(&self, i: usize) -> &[f64];

    /// `y = A x` via per-row dots (identical order to [`SymMat::matvec`]:
    /// both route every row through [`crate::kernels::dot`], so the two
    /// stay bitwise-locked on every dispatch tier).
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = crate::kernels::dot(self.row(i), x);
        }
    }
}

impl DenseRows for SymMat {
    fn n(&self) -> usize {
        SymMat::n(self)
    }

    fn row(&self, i: usize) -> &[f64] {
        SymMat::row(self, i)
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        SymMat::matvec(self, x, y)
    }
}

// ---------------------------------------------------------------------------
// Dense implementations
// ---------------------------------------------------------------------------

impl CovOp for SymMat {
    fn n(&self) -> usize {
        SymMat::n(self)
    }

    fn diag(&self, j: usize) -> f64 {
        self.get(j, j)
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        out.copy_from_slice(self.row(j));
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        SymMat::matvec(self, x, y)
    }

    fn quad_form(&self, x: &[f64]) -> f64 {
        SymMat::quad_form(self, x)
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        let row = self.row(j);
        for (o, &i) in out.iter_mut().zip(idx) {
            *o = row[i];
        }
    }

    fn frob_with(&self, x: &SymMat) -> f64 {
        self.frob_dot(x)
    }

    fn materialize(&self, idx: &[usize]) -> SymMat {
        self.submatrix(idx)
    }

    fn as_dense(&self) -> Option<&SymMat> {
        Some(self)
    }
}

/// The dense covariance backend: a [`SymMat`] behind the operator
/// interface. Every method forwards to the matrix's own [`CovOp`] impl,
/// so a solve through `DenseCov` is **bitwise identical** to a solve on
/// the wrapped matrix — and a future `CovOp` method optimized for
/// `SymMat` is picked up here automatically.
#[derive(Clone, Debug)]
pub struct DenseCov(pub SymMat);

impl DenseCov {
    /// Wrap an assembled covariance matrix.
    pub fn new(sigma: SymMat) -> DenseCov {
        DenseCov(sigma)
    }

    /// The wrapped matrix.
    pub fn inner(&self) -> &SymMat {
        &self.0
    }
}

impl CovOp for DenseCov {
    fn n(&self) -> usize {
        CovOp::n(&self.0)
    }

    fn diag(&self, j: usize) -> f64 {
        CovOp::diag(&self.0, j)
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        CovOp::row_into(&self.0, j, out)
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        CovOp::matvec(&self.0, x, y)
    }

    fn quad_form(&self, x: &[f64]) -> f64 {
        CovOp::quad_form(&self.0, x)
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        CovOp::row_gather(&self.0, j, idx, out)
    }

    fn frob_with(&self, x: &SymMat) -> f64 {
        CovOp::frob_with(&self.0, x)
    }

    fn materialize(&self, idx: &[usize]) -> SymMat {
        CovOp::materialize(&self.0, idx)
    }

    fn as_dense(&self) -> Option<&SymMat> {
        Some(&self.0)
    }
}

// ---------------------------------------------------------------------------
// Masked view — per-λ nested elimination
// ---------------------------------------------------------------------------

/// Zero-copy principal-submatrix view of a base operator.
///
/// This is the per-λ nested-elimination mask: a λ-search probe applies
/// Thm 2.1 at *its own* λ and solves on the survivor subset of one shared
/// superset operator, instead of materializing `Σ.submatrix(kept)` per
/// probe. For a dense base the gathered values are the identical f64s the
/// submatrix would contain, so the solve is bitwise equal to the
/// materialized one (pinned by `prop_masked_solve_matches_submatrix`).
pub struct MaskedCov<'a, C: CovOp + ?Sized> {
    base: &'a C,
    idx: Vec<usize>,
}

impl<'a, C: CovOp + ?Sized> MaskedCov<'a, C> {
    /// View `base` restricted to the (not necessarily sorted) indices
    /// `idx` — typically `SafeElimination::kept` at a probe λ.
    pub fn new(base: &'a C, idx: Vec<usize>) -> MaskedCov<'a, C> {
        let n = base.n();
        assert!(idx.iter().all(|&i| i < n), "mask index out of range");
        MaskedCov { base, idx }
    }

    /// The masked (original-space) indices.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }
}

impl<C: CovOp + ?Sized> CovOp for MaskedCov<'_, C> {
    fn n(&self) -> usize {
        self.idx.len()
    }

    fn diag(&self, j: usize) -> f64 {
        self.base.diag(self.idx[j])
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        self.base.row_gather(self.idx[j], &self.idx, out);
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let k = self.idx.len();
        assert_eq!(x.len(), k);
        assert_eq!(y.len(), k);
        let mut row = vec![0.0; k];
        for (a, yi) in y.iter_mut().enumerate() {
            self.base.row_gather(self.idx[a], &self.idx, &mut row);
            *yi = crate::linalg::vec::dot(&row, x);
        }
    }

    fn quad_form(&self, x: &[f64]) -> f64 {
        let mut y = vec![0.0; self.idx.len()];
        self.matvec(x, &mut y);
        crate::linalg::vec::dot(x, &y)
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        let mapped: Vec<usize> = idx.iter().map(|&i| self.idx[i]).collect();
        self.base.row_gather(self.idx[j], &mapped, out);
    }
}

// ---------------------------------------------------------------------------
// Implicit centered Gram operator
// ---------------------------------------------------------------------------

/// Least-recently-used cache of gathered rows (interior state; values are
/// recomputed deterministically on a miss, so the cache never changes a
/// result — only wall time). Shared by [`GramCov`] and the out-of-core
/// [`crate::cov_disk::DiskGramCov`].
pub(crate) struct RowCache {
    rows: HashMap<usize, (u64, Vec<f64>)>,
    clock: u64,
    pub(crate) cap_rows: usize,
    pub(crate) hits: u64,
    pub(crate) misses: u64,
}

impl RowCache {
    pub(crate) fn new(cap_rows: usize) -> RowCache {
        RowCache { rows: HashMap::new(), clock: 0, cap_rows, hits: 0, misses: 0 }
    }

    /// Copy a cached row's entries at `idx` into `out` (`None` = whole
    /// row, served with one `copy_from_slice`); `false` on miss.
    pub(crate) fn gather(&mut self, j: usize, idx: Option<&[usize]>, out: &mut [f64]) -> bool {
        self.clock += 1;
        match self.rows.get_mut(&j) {
            Some((stamp, row)) => {
                *stamp = self.clock;
                self.hits += 1;
                match idx {
                    Some(idx) => {
                        for (o, &i) in out.iter_mut().zip(idx) {
                            *o = row[i];
                        }
                    }
                    None => out.copy_from_slice(row),
                }
                true
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    pub(crate) fn insert(&mut self, j: usize, row: Vec<f64>) {
        if self.cap_rows == 0 {
            return;
        }
        if self.rows.len() >= self.cap_rows && !self.rows.contains_key(&j) {
            // Evict the least-recently-used row (O(len) scan; the scan is
            // orders of magnitude cheaper than the sparse row gather a
            // miss costs, so a fancier structure buys nothing here).
            let victim = self
                .rows
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(&k, _)| k);
            if let Some(v) = victim {
                self.rows.remove(&v);
            }
        }
        self.clock += 1;
        let stamped = (self.clock, row);
        // A concurrent gather may have raced the same row in; keep the
        // existing copy (values are identical by determinism).
        self.rows.entry(j).or_insert(stamped);
    }
}

/// Per-feature means `μ = (Aᵀ1)/m` and centered diagonal `Σ_jj` of a
/// reduced term matrix — the **single** definition of these folds,
/// shared by [`GramCov::new`] and the shard-cache writer
/// ([`crate::data::shardcache::write`]) so the in-memory and on-disk
/// backends serve identical bits by construction. The mean accumulates
/// in CSR row-major order; the diagonal via per-column sums of squares.
pub(crate) fn reduced_means_and_diag(csr: &CsrMatrix, total_docs: u64) -> (Vec<f64>, Vec<f64>) {
    let nhat = csr.cols;
    let m = total_docs.max(1) as f64;
    let mut sums = vec![0.0; nhat];
    for r in 0..csr.rows {
        for (c, v) in csr.row(r) {
            sums[c] += v;
        }
    }
    let mean: Vec<f64> = sums.iter().map(|&s| s / m).collect();
    let csc = csr.to_csc();
    let diag: Vec<f64> = (0..nhat)
        .map(|j| {
            let (_, ss) = csc.col_moments(j);
            ss / m - mean[j] * mean[j]
        })
        .collect();
    (mean, diag)
}

/// Rows a `cache_mb`-MiB Σ-row cache holds at order `nhat` (0 disables
/// caching; at least one row otherwise) — shared by both implicit
/// backends so their cache behavior matches.
pub(crate) fn row_cache_cap(cache_mb: usize, nhat: usize) -> usize {
    if cache_mb == 0 {
        0
    } else {
        ((cache_mb * 1024 * 1024) / (8 * nhat.max(1))).max(1)
    }
}

/// The shared cached-row-gather protocol of both implicit backends:
/// serve picks (or the whole row when `idx` is `None`) from the cache,
/// computing via `compute_row` and inserting on a miss. Row computation
/// happens **outside** the lock so concurrent probes do not serialize
/// on row builds; a racing insert of the same row is benign because
/// rows are deterministic.
pub(crate) fn cached_gather_with(
    cache: &Mutex<RowCache>,
    nhat: usize,
    j: usize,
    idx: Option<&[usize]>,
    out: &mut [f64],
    compute_row: impl Fn(usize, &mut [f64]),
) {
    let caching = {
        let mut cache = cache.lock().unwrap();
        if cache.cap_rows > 0 && cache.gather(j, idx, out) {
            return;
        }
        cache.cap_rows > 0
    };
    match idx {
        Some(idx) => {
            let mut row = vec![0.0; nhat];
            compute_row(j, &mut row);
            for (o, &i) in out.iter_mut().zip(idx) {
                *o = row[i];
            }
            if caching {
                cache.lock().unwrap().insert(j, row);
            }
        }
        None => {
            // Full-row request: compute straight into the caller's
            // buffer, cloning only if it is worth caching.
            compute_row(j, out);
            if caching {
                cache.lock().unwrap().insert(j, out.to_vec());
            }
        }
    }
}

/// Implicit centered covariance of a reduced sparse term matrix:
///
/// ```text
/// Σ_ab = (AᵀA)_ab / m  −  μ_a μ_b,     μ = (Aᵀ1) / m
/// ```
///
/// where `A` is the m × n̂ matrix of kept-feature counts (documents with
/// no kept words contribute only to `m`). Rows of Σ are *gathered on
/// demand* from the CSC/CSR pair — `O(Σ_{d ∋ j} nnz_d)` per row — and
/// held in a bounded LRU cache; the full n̂ × n̂ matrix is never formed.
///
/// Entries match [`crate::cov::CovAccum::finalize`] up to FP summation
/// order (the streaming accumulator folds documents in worker order, this
/// operator in sorted document order — both population-convention).
pub struct GramCov {
    csr: CsrMatrix,
    csc: CscMatrix,
    /// Per-feature mean `μ_j` (over all `m` documents).
    mean: Vec<f64>,
    /// Precomputed diagonal `Σ_jj` (Thm 2.1 reads it constantly).
    diag: Vec<f64>,
    /// Document count m, including documents with no kept features.
    m_docs: f64,
    cache: Mutex<RowCache>,
}

impl GramCov {
    /// Build from a reduced CSR (rows = documents that contain at least
    /// one kept feature, cols = kept features in elimination order).
    /// `total_docs` is the full corpus size m (the centering denominator);
    /// `cache_mb` bounds the row cache (0 disables caching).
    pub fn new(csr: CsrMatrix, total_docs: u64, cache_mb: usize) -> GramCov {
        let nhat = csr.cols;
        let m = total_docs.max(1) as f64;
        let (mean, diag) = reduced_means_and_diag(&csr, total_docs);
        let csc = csr.to_csc();
        let cap_rows = row_cache_cap(cache_mb, nhat);
        GramCov {
            csr,
            csc,
            mean,
            diag,
            m_docs: m,
            cache: Mutex::new(RowCache::new(cap_rows)),
        }
    }

    /// Stored nonzeros of the reduced term matrix.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// `(cache hits, cache misses)` so far — capacity-planning telemetry
    /// for the `row_cache_mb` knob.
    pub fn cache_stats(&self) -> (u64, u64) {
        let c = self.cache.lock().unwrap();
        (c.hits, c.misses)
    }

    /// Rows the cache can hold under the configured budget.
    pub fn cache_capacity_rows(&self) -> usize {
        self.cache.lock().unwrap().cap_rows
    }

    /// Compute row `j` of Σ from the sparse factors:
    /// `out[k] = (Σ_{d ∋ j} A_dj A_dk)/m − μ_j μ_k`.
    fn compute_row(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.csr.cols);
        out.fill(0.0);
        for (d, aj) in self.csc.col(j) {
            for (k, ak) in self.csr.row(d) {
                out[k] += aj * ak;
            }
        }
        let inv_m = 1.0 / self.m_docs;
        let mu_j = self.mean[j];
        for (o, &mu_k) in out.iter_mut().zip(&self.mean) {
            *o = *o * inv_m - mu_j * mu_k;
        }
    }

    /// Gather via the cache — the shared [`cached_gather_with`]
    /// protocol with this backend's sparse row kernel.
    fn cached_gather(&self, j: usize, idx: Option<&[usize]>, out: &mut [f64]) {
        cached_gather_with(&self.cache, self.csr.cols, j, idx, out, |j, row| {
            self.compute_row(j, row)
        });
    }

    /// Forward Gram half `ax = A x`, choosing the sweep by probe
    /// sparsity: a handful of active columns (λ-search quad forms,
    /// deflation corrections, masked probes) goes through the CSC
    /// active-column scatter, dense `x` through the streaming row
    /// accumulate. Both orders are bitwise identical
    /// ([`CscMatrix::scatter_matvec_into`]), so the threshold is purely
    /// a performance choice.
    fn forward_ax(&self, x: &[f64], ax: &mut [f64]) {
        let active = x.iter().filter(|v| **v != 0.0).count();
        if active * 8 <= self.csr.cols {
            self.csc.scatter_matvec_into(x, ax);
        } else {
            self.csr.matvec_into(x, ax);
        }
    }
}

impl CovOp for GramCov {
    fn n(&self) -> usize {
        self.csr.cols
    }

    fn diag(&self, j: usize) -> f64 {
        self.diag[j]
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        self.cached_gather(j, None, out);
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        self.cached_gather(j, Some(idx), out);
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.csr.cols);
        // y = Aᵀ(Ax)/m − μ(μᵀx): sparsity-aware forward half, shared
        // transpose scatter, then centering — no dense Σ.
        let mut ax = vec![0.0; self.csr.rows];
        self.forward_ax(x, &mut ax);
        self.csr.t_matvec_into(&ax, y);
        let inv_m = 1.0 / self.m_docs;
        let mux = crate::linalg::vec::dot(&self.mean, x);
        for (yk, &mu_k) in y.iter_mut().zip(&self.mean) {
            *yk = *yk * inv_m - mu_k * mux;
        }
    }

    fn quad_form(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.csr.cols);
        // xᵀΣx = ‖Ax‖²/m − (μᵀx)². ‖Ax‖² runs through the dispatched
        // dot (fixed 4-lane reduction — the order the out-of-core twin
        // replays bitwise).
        let mut ax = vec![0.0; self.csr.rows];
        self.forward_ax(x, &mut ax);
        let ssq = crate::linalg::vec::dot(&ax, &ax);
        let mux = crate::linalg::vec::dot(&self.mean, x);
        ssq / self.m_docs - mux * mux
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cov::covariance_from_csr;
    use crate::data::TripletMatrix;
    use crate::util::check::{close, property};
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize) -> CsrMatrix {
        let mut t = TripletMatrix::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.bool(0.4) {
                    t.push(r, c, (1 + rng.below(5)) as f64);
                }
            }
        }
        t.to_csr()
    }

    #[test]
    fn dense_cov_is_bitwise_the_matrix() {
        let mut rng = Rng::seed_from(31);
        let n = 9;
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, &mut rng);
        let op = DenseCov::new(sigma.clone());
        let mut row = vec![0.0; n];
        for j in 0..n {
            assert_eq!(CovOp::diag(&op, j), sigma.get(j, j));
            op.row_into(j, &mut row);
            assert_eq!(row.as_slice(), sigma.row(j));
        }
        let x = rng.gauss_vec(n);
        let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
        CovOp::matvec(&op, &x, &mut y1);
        SymMat::matvec(&sigma, &x, &mut y2);
        assert_eq!(y1, y2);
        assert_eq!(CovOp::quad_form(&op, &x).to_bits(), sigma.quad_form(&x).to_bits());
        let z = SymMat::random_psd(n, n + 2, 0.0, &mut rng);
        assert_eq!(op.frob_with(&z).to_bits(), sigma.frob_dot(&z).to_bits());
    }

    #[test]
    fn prop_gram_matches_dense_covariance() {
        property("GramCov == covariance_from_csr entrywise", 15, |rng| {
            let rows = rng.range(3, 40);
            let cols = rng.range(2, 12);
            let csr = random_csr(rng, rows, cols);
            let kept: Vec<usize> = (0..cols).collect();
            let dense = covariance_from_csr(&csr, &kept);
            let gram = GramCov::new(csr, rows as u64, 4);
            let mut row = vec![0.0; cols];
            for j in 0..cols {
                close(CovOp::diag(&gram, j), dense.get(j, j), 1e-10)?;
                gram.row_into(j, &mut row);
                for k in 0..cols {
                    close(row[k], dense.get(j, k), 1e-10)?;
                }
            }
            // matvec + quad form against the dense reference
            let x: Vec<f64> = (0..cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let (mut yg, mut yd) = (vec![0.0; cols], vec![0.0; cols]);
            CovOp::matvec(&gram, &x, &mut yg);
            SymMat::matvec(&dense, &x, &mut yd);
            for k in 0..cols {
                close(yg[k], yd[k], 1e-9)?;
            }
            close(CovOp::quad_form(&gram, &x), dense.quad_form(&x), 1e-9)?;
            Ok(())
        });
    }

    #[test]
    fn gram_rows_symmetric_and_deterministic() {
        let mut rng = Rng::seed_from(33);
        let csr = random_csr(&mut rng, 60, 8);
        let gram = GramCov::new(csr, 60, 1);
        let (mut ra, mut rb) = (vec![0.0; 8], vec![0.0; 8]);
        for a in 0..8 {
            gram.row_into(a, &mut ra);
            for b in 0..8 {
                gram.row_into(b, &mut rb);
                assert_eq!(ra[b].to_bits(), rb[a].to_bits(), "Σ must be exactly symmetric");
            }
            // a second gather (now cached) returns the same bits
            let mut again = vec![0.0; 8];
            gram.row_into(a, &mut again);
            assert_eq!(ra, again);
        }
    }

    #[test]
    fn gram_counts_empty_documents_in_m() {
        // Two docs share a feature; a third doc has no kept features but
        // must still shrink the mean (m = 3, not 2).
        let mut t = TripletMatrix::new(2, 1);
        t.push(0, 0, 1.0);
        t.push(1, 0, 1.0);
        let gram = GramCov::new(t.to_csr(), 3, 1);
        // μ = 2/3, Σ_00 = (1+1)/3 − (2/3)² = 2/3 − 4/9 = 2/9
        close(CovOp::diag(&gram, 0), 2.0 / 9.0, 1e-12).unwrap();
    }

    #[test]
    fn row_cache_respects_budget_and_reports_stats() {
        let mut rng = Rng::seed_from(34);
        // 1 MiB budget over 4096-entry rows → 32 rows.
        let csr = random_csr(&mut rng, 30, 16);
        let gram = GramCov::new(csr, 30, 1);
        let cap = gram.cache_capacity_rows();
        assert_eq!(cap, 1024 * 1024 / (8 * 16));
        let mut out = vec![0.0; 16];
        gram.row_into(3, &mut out);
        gram.row_into(3, &mut out);
        let (hits, misses) = gram.cache_stats();
        assert_eq!((hits, misses), (1, 1));
        // cache disabled: still correct, never cached
        let csr2 = random_csr(&mut rng, 30, 16);
        let g0 = GramCov::new(csr2, 30, 0);
        assert_eq!(g0.cache_capacity_rows(), 0);
        g0.row_into(2, &mut out);
        let (h, _) = g0.cache_stats();
        assert_eq!(h, 0);
    }

    #[test]
    fn masked_view_equals_submatrix() {
        let mut rng = Rng::seed_from(35);
        let n = 10;
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, &mut rng);
        let idx = vec![7, 1, 4, 2];
        let masked = MaskedCov::new(&sigma, idx.clone());
        let sub = sigma.submatrix(&idx);
        let k = idx.len();
        let mut row = vec![0.0; k];
        for a in 0..k {
            assert_eq!(CovOp::diag(&masked, a).to_bits(), sub.get(a, a).to_bits());
            masked.row_into(a, &mut row);
            assert_eq!(row.as_slice(), sub.row(a), "masked row must pick identical f64s");
        }
        // frob_with reproduces the dense fold bitwise
        let x = SymMat::random_psd(k, k + 2, 0.0, &mut rng);
        assert_eq!(masked.frob_with(&x).to_bits(), sub.frob_dot(&x).to_bits());
        // materialize roundtrip
        let mat = masked.materialize_full();
        assert_eq!(mat.as_slice(), sub.as_slice());
    }

    #[test]
    fn masked_over_gram_composes() {
        let mut rng = Rng::seed_from(36);
        let csr = random_csr(&mut rng, 50, 9);
        let kept: Vec<usize> = (0..9).collect();
        let dense = covariance_from_csr(&csr, &kept);
        let gram = GramCov::new(csr, 50, 1);
        let idx = vec![8, 0, 5];
        let mg = MaskedCov::new(&gram, idx.clone());
        let sub = dense.submatrix(&idx);
        let mut row = vec![0.0; 3];
        for a in 0..3 {
            mg.row_into(a, &mut row);
            for b in 0..3 {
                close(row[b], sub.get(a, b), 1e-10).unwrap();
            }
        }
    }
}
