//! Generalized power method for sparse PCA (Journée, Nesterov, Richtárik &
//! Sepulchre [10]) — the strongest non-convex baseline in the paper's
//! related work.
//!
//! For the ℓ1-penalized variant, the iteration is a soft-thresholded power
//! step on the *data* side; on a covariance Σ = AᵀA it reduces to
//!
//! ```text
//! x ← Σ z / ‖Σ z‖,   z_i = sign((Σx)_i)·(|(Σx)_i| − γ)₊ (then normalize)
//! ```
//!
//! i.e. alternating maximization of `zᵀΣx − γ‖z‖₁` over unit `x, z`. Fast
//! (O(n²) per iteration) but non-convex: converges to a local optimum that
//! depends on the start — which is exactly why the paper prefers the
//! convex DSPCA relaxation (see the ablation bench A5).

use crate::data::SymMat;
use crate::linalg::vec::{normalize, norm2};
use crate::solver::extract::SparsePc;
use crate::util::rng::Rng;

/// Options for the generalized power method.
#[derive(Clone, Copy, Debug)]
pub struct GPowerOptions {
    /// Maximum power iterations per restart.
    pub max_iters: usize,
    /// Convergence tolerance on the iterate change.
    pub tol: f64,
    /// Restarts from random unit vectors (keep the best objective).
    pub restarts: usize,
}

impl Default for GPowerOptions {
    fn default() -> Self {
        GPowerOptions { max_iters: 500, tol: 1e-10, restarts: 4 }
    }
}

fn soft_threshold(v: &mut [f64], gamma: f64) {
    for x in v.iter_mut() {
        let a = x.abs() - gamma;
        *x = if a > 0.0 { a * x.signum() } else { 0.0 };
    }
}

/// One run from a given start; returns the (locally optimal) direction.
fn run_from(sigma: &SymMat, gamma: f64, x0: &[f64], opts: &GPowerOptions) -> Vec<f64> {
    let n = sigma.n();
    let mut x = x0.to_vec();
    normalize(&mut x);
    let mut sx = vec![0.0; n];
    for _ in 0..opts.max_iters {
        sigma.matvec(&x, &mut sx);
        soft_threshold(&mut sx, gamma);
        if norm2(&sx) <= 1e-300 {
            // γ killed everything: the trivial local optimum
            return vec![0.0; n];
        }
        normalize(&mut sx);
        let delta = crate::linalg::vec::max_abs_diff(&sx, &x);
        std::mem::swap(&mut x, &mut sx);
        if delta < opts.tol {
            break;
        }
    }
    x
}

/// Penalized objective `xᵀΣx` restricted to the support γ leaves alive —
/// used to pick the best restart.
fn objective(sigma: &SymMat, x: &[f64]) -> f64 {
    sigma.quad_form(x)
}

/// Run with restarts; γ plays the role of the sparsity penalty (larger →
/// sparser, like λ in DSPCA).
pub fn solve(sigma: &SymMat, gamma: f64, opts: &GPowerOptions, rng: &mut Rng) -> SparsePc {
    let n = sigma.n();
    let mut best: Option<(f64, Vec<f64>)> = None;
    for r in 0..opts.restarts.max(1) {
        let x0 = if r == 0 {
            // deterministic first start: the max-variance coordinate
            let mut x0 = vec![0.0; n];
            let jmax = (0..n).max_by(|&a, &b| {
                sigma.get(a, a).partial_cmp(&sigma.get(b, b)).unwrap()
            });
            x0[jmax.unwrap_or(0)] = 1.0;
            x0
        } else {
            rng.gauss_vec(n)
        };
        let x = run_from(sigma, gamma, &x0, opts);
        let obj = objective(sigma, &x);
        // (match, not Option::is_none_or — that is post-MSRV)
        let improves = match &best {
            Some((b, _)) => obj > *b,
            None => true,
        };
        if improves {
            best = Some((obj, x));
        }
    }
    let (_, mut v) = best.unwrap();
    let mut support: Vec<usize> = (0..n).filter(|&i| v[i] != 0.0).collect();
    support.sort_by(|&a, &b| v[b].abs().partial_cmp(&v[a].abs()).unwrap());
    if let Some(&lead) = support.first() {
        if v[lead] < 0.0 {
            for x in v.iter_mut() {
                *x = -*x;
            }
        }
    }
    SparsePc { vector: v, support, z_eigenvalue: f64::NAN }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::spiked_covariance_with_u;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn gamma_zero_is_power_iteration() {
        let mut rng = Rng::seed_from(201);
        let sigma = SymMat::random_psd(10, 30, 0.1, &mut rng);
        let pc = solve(&sigma, 0.0, &GPowerOptions::default(), &mut rng);
        let eig = crate::linalg::eig::JacobiEig::new(&sigma);
        close(sigma.quad_form(&pc.vector), eig.lambda_max(), 1e-6).unwrap();
    }

    #[test]
    fn prop_sparsity_increases_with_gamma() {
        property("gpower: cardinality non-increasing in γ (coarsely)", 8, |rng| {
            let n = rng.range(6, 16);
            let sigma = SymMat::random_psd(n, 2 * n, 0.05, rng);
            let sx_scale = (0..n).map(|i| sigma.get(i, i)).fold(0.0f64, f64::max);
            let lo = solve(&sigma, 0.01 * sx_scale, &GPowerOptions::default(), rng);
            let hi = solve(&sigma, 0.5 * sx_scale, &GPowerOptions::default(), rng);
            ensure(
                hi.cardinality() <= lo.cardinality() + 1,
                format!("card grew: {} → {}", lo.cardinality(), hi.cardinality()),
            )
        });
    }

    #[test]
    fn recovers_strong_spike() {
        let mut rng = Rng::seed_from(202);
        let (sigma, u) = spiked_covariance_with_u(30, 120, 4, 6.0, &mut rng);
        let gamma = 0.35;
        let pc = solve(&sigma, gamma, &GPowerOptions::default(), &mut rng);
        let planted = crate::linalg::vec::support(&u, 1e-9);
        let hits = pc.support.iter().filter(|i| planted.contains(i)).count();
        assert!(hits >= 3, "support {:?} planted {planted:?}", pc.support);
    }

    #[test]
    fn huge_gamma_gives_empty_or_singleton() {
        let mut rng = Rng::seed_from(203);
        let sigma = SymMat::random_psd(8, 20, 0.1, &mut rng);
        let pc = solve(&sigma, 1e6, &GPowerOptions::default(), &mut rng);
        assert!(pc.cardinality() <= 1);
    }
}
