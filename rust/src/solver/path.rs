//! Regularization-path computation: DSPCA solved over a λ grid, with
//! per-λ safe elimination — the library API behind `examples/
//! lambda_explorer.rs` and the cardinality/variance trade-off analyses.

use crate::covop::{CovOp, MaskedCov};
use crate::elim::SafeElimination;
use crate::solver::bca::{self, BcaOptions};
use crate::solver::extract::{leading_sparse_pc, SparsePc};

/// One point on the path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    /// Grid λ.
    pub lambda: f64,
    /// Surviving features after the Thm 2.1 test at this λ.
    pub survivors: usize,
    /// Extracted sparse PC at this λ.
    pub pc: SparsePc,
    /// Problem-(1) objective at this λ.
    pub phi: f64,
    /// Explained variance `xᵀΣx` of the extracted PC on the input Σ.
    pub explained_variance: f64,
    /// Wall seconds for this grid point's solve.
    pub solve_seconds: f64,
}

/// Options for the path sweep.
#[derive(Clone, Copy, Debug)]
pub struct PathOptions {
    /// Number of λ grid points (log-spaced over (0, max Σ_ii)).
    pub points: usize,
    /// Smallest λ as a fraction of max Σ_ii.
    pub min_frac: f64,
    /// Inner-solver options shared by every grid point.
    pub bca: BcaOptions,
    /// Loading truncation tolerance for cardinality measurement.
    pub extract_tol: f64,
    /// Worker threads solving grid points concurrently (0 = auto,
    /// 1 = serial). Every point is independent (per-λ safe elimination +
    /// its own BCA solve), so the output is identical for any value.
    pub threads: usize,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions {
            points: 12,
            min_frac: 1e-3,
            bca: BcaOptions { max_sweeps: 12, track_history: false, ..Default::default() },
            extract_tol: 1e-3,
            threads: 1,
        }
    }
}

/// Compute the path, largest λ first (sparsest end first — each point
/// applies safe elimination independently so the big-λ points are cheap).
/// Points are solved on `opts.threads` workers; the λ grid and the output
/// order are fixed up front, so results do not depend on the thread count.
pub fn compute<C: CovOp + ?Sized>(sigma: &C, opts: &PathOptions) -> Vec<PathPoint> {
    let n = sigma.n();
    assert!(n > 0 && opts.points >= 2);
    let diags: Vec<f64> = (0..n).map(|i| sigma.diag(i)).collect();
    let max_diag = diags.iter().cloned().fold(0.0f64, f64::max);
    let lo = (max_diag * opts.min_frac).max(1e-300);
    let hi = max_diag * 0.999;
    let ratio = (hi / lo).powf(1.0 / (opts.points - 1) as f64);
    let mut lambdas = Vec::with_capacity(opts.points);
    let mut lambda = hi;
    for _ in 0..opts.points {
        lambdas.push(lambda);
        lambda /= ratio;
    }
    crate::util::parallel::par_map_indexed(opts.threads, lambdas.len(), |k| {
        let lambda = lambdas[k];
        let t = crate::util::timer::Timer::start();
        let elim = SafeElimination::apply(&diags, lambda, None);
        if elim.reduced() == 0 {
            PathPoint {
                lambda,
                survivors: 0,
                pc: SparsePc { vector: vec![0.0; n], support: Vec::new(), z_eigenvalue: 0.0 },
                phi: 0.0,
                explained_variance: 0.0,
                solve_seconds: t.secs(),
            }
        } else {
            // Per-λ masked view: the grid point's Thm-2.1 survivors, no
            // materialized submatrix (the big-λ end stays cheap even on
            // an implicit-Gram operator).
            let sub = MaskedCov::new(sigma, elim.kept.clone());
            let sol = bca::solve(&sub, lambda, &opts.bca);
            let pc = leading_sparse_pc(&sol.z, opts.extract_tol).mapped(&elim.kept, n);
            let explained = sigma.quad_form(&pc.vector);
            PathPoint {
                lambda,
                survivors: elim.reduced(),
                phi: sol.phi,
                explained_variance: explained,
                pc,
                solve_seconds: t.secs(),
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::spiked_covariance_with_u;
    use crate::data::SymMat;
    use crate::util::check::{ensure, property};
    use crate::util::rng::Rng;

    #[test]
    fn prop_path_monotonicity() {
        property("path: survivors/φ non-increasing in λ", 6, |rng| {
            let n = rng.range(6, 18);
            let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
            let path = compute(&sigma, &PathOptions { points: 8, ..Default::default() });
            // path is sparsest-first (λ descending)
            for w in path.windows(2) {
                ensure(w[0].lambda > w[1].lambda, "λ must descend")?;
                ensure(w[0].survivors <= w[1].survivors, "survivors must grow as λ falls")?;
                ensure(
                    w[0].phi <= w[1].phi + 1e-6 * (1.0 + w[1].phi.abs()),
                    format!("φ must grow as λ falls: {} → {}", w[0].phi, w[1].phi),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_end_approaches_lambda_max() {
        let mut rng = Rng::seed_from(241);
        let (sigma, _) = spiked_covariance_with_u(15, 60, 3, 4.0, &mut rng);
        let path = compute(
            &sigma,
            &PathOptions {
                points: 10,
                min_frac: 1e-4,
                bca: BcaOptions { max_sweeps: 40, ..Default::default() },
                ..Default::default()
            },
        );
        let eig = crate::linalg::eig::JacobiEig::new(&sigma);
        let last = path.last().unwrap();
        assert!(
            (last.explained_variance - eig.lambda_max()).abs() < 0.05 * eig.lambda_max(),
            "dense-end explained {} vs λmax {}",
            last.explained_variance,
            eig.lambda_max()
        );
    }

    #[test]
    fn supports_nest_coarsely_along_path() {
        // Sparse PCA supports are not strictly nested in general, but on a
        // strong spike the sparse end must be contained in the dense end.
        let mut rng = Rng::seed_from(242);
        let (sigma, u) = spiked_covariance_with_u(20, 80, 4, 8.0, &mut rng);
        let path = compute(&sigma, &PathOptions { points: 9, ..Default::default() });
        let planted = crate::linalg::vec::support(&u, 1e-9);
        for p in path.iter().filter(|p| (1..=4).contains(&p.pc.cardinality())) {
            let hits = p.pc.support.iter().filter(|i| planted.contains(i)).count();
            assert!(
                hits * 2 >= p.pc.cardinality(),
                "λ={}: support {:?} vs planted {planted:?}",
                p.lambda,
                p.pc.support
            );
        }
    }
}
