//! Dual optimality certificates for DSPCA.
//!
//! Problem (1)'s dual is `min λmax(Σ + U)` over `‖U‖∞ ≤ λ`, so ANY
//! feasible `U` certifies `φ ≤ λmax(Σ + U)`. Given a primal candidate `Z`
//! we build `U` from the subgradient structure of `−λ‖Z‖₁`:
//!
//! ```text
//! U_ij = −λ·sign(Z_ij)      where Z_ij ≠ 0
//! U_ij = clamp(candidate)   elsewhere (free to shrink λmax)
//! ```
//!
//! using `−λ·sign` on the off-support too (a simple feasible completion).
//! The resulting *duality gap* `λmax(Σ+U) − (TrΣZ − λ‖Z‖₁)` bounds the
//! suboptimality of the solver's answer — this is what lets the pipeline
//! *prove* how good a BCA solution is without trusting the solver.

use crate::data::SymMat;
use crate::linalg::eig::JacobiEig;

/// A certificate: dual-feasible `U`, its bound, and the gap vs a primal value.
#[derive(Clone, Debug)]
pub struct Certificate {
    /// Upper bound `λmax(Σ + U)` from the dual-feasible point.
    pub upper_bound: f64,
    /// Primal value `Tr ΣZ − λ‖Z‖₁` of the certified candidate.
    pub primal: f64,
    /// `upper_bound − primal ≥ 0` (up to eig tolerance).
    pub gap: f64,
}

/// Build a certificate for a trace-1 PSD candidate `Z`, tightening the
/// dual point with `tighten_steps` projected-subgradient steps on
/// `λmax(Σ+U)` (subgradient = vvᵀ for the top eigenvector v; projection =
/// clamp to the box). Every iterate is dual-feasible, so the best bound
/// seen is always valid — more steps only improve it.
pub fn certify_steps(sigma: &SymMat, z: &SymMat, lambda: f64, tighten_steps: usize) -> Certificate {
    let n = sigma.n();
    assert_eq!(z.n(), n);
    // Start: U = −λ sign(Z), completed with −λ sign(Σ) off-support.
    let mut u = SymMat::from_fn(n, |i, j| {
        let zij = z.get(i, j);
        if zij != 0.0 {
            -lambda * zij.signum()
        } else {
            -lambda * sigma.get(i, j).signum()
        }
    });
    let primal = sigma.frob_dot(z) - lambda * z.l1_norm();
    let mut best = f64::INFINITY;
    for k in 0..=tighten_steps {
        let m = SymMat::from_fn(n, |i, j| sigma.get(i, j) + u.get(i, j));
        let eig = JacobiEig::new(&m);
        best = best.min(eig.lambda_max());
        if k == tighten_steps || best - primal <= 1e-12 * (1.0 + primal.abs()) {
            break;
        }
        // U ← P_box(U − step·vvᵀ), diminishing step scaled by λ.
        let v = eig.vector(0);
        let step = 2.0 * lambda / (1.0 + k as f64).sqrt();
        for i in 0..n {
            for j in i..n {
                let w = (u.get(i, j) - step * v[i] * v[j]).clamp(-lambda, lambda);
                u.set(i, j, w);
            }
        }
    }
    Certificate { upper_bound: best, primal, gap: best - primal }
}

/// Certificate with the default tightening budget.
pub fn certify(sigma: &SymMat, z: &SymMat, lambda: f64) -> Certificate {
    certify_steps(sigma, z, lambda, 40)
}

impl Certificate {
    /// Relative gap, safe for zero primal.
    pub fn relative_gap(&self) -> f64 {
        self.gap / (1.0 + self.primal.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::bca::{self, BcaOptions};
    use crate::util::check::{ensure, property};

    #[test]
    fn prop_gap_nonnegative_for_any_feasible_z() {
        property("certificate: gap ≥ 0 for random feasible Z", 15, |rng| {
            let n = rng.range(2, 10);
            let sigma = SymMat::random_psd(n, n + 3, 0.1, rng);
            // random trace-1 PSD candidate
            let mut z = SymMat::random_psd(n, n + 2, 1e-6, rng);
            let tr = z.trace();
            crate::linalg::vec::scale(1.0 / tr, z.as_mut_slice());
            let lambda = rng.range_f64(0.0, 1.0);
            let cert = certify(&sigma, &z, lambda);
            ensure(
                cert.gap >= -1e-7 * (1.0 + cert.upper_bound.abs()),
                format!("negative gap {}", cert.gap),
            )
        });
    }

    #[test]
    fn bca_solution_has_small_gap() {
        property("certificate: converged BCA gap is small", 6, |rng| {
            let n = rng.range(4, 10);
            let sigma = SymMat::random_psd(n, 3 * n, 0.2, rng);
            let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
            let lambda = 0.4 * min_diag;
            let sol = bca::solve(
                &sigma,
                lambda,
                &BcaOptions { max_sweeps: 80, epsilon: 1e-5, tol: 1e-12, ..Default::default() },
            );
            let cert = certify(&sigma, &sol.z, lambda);
            ensure(
                cert.relative_gap() < 0.2,
                format!(
                    "gap too large: primal {} upper {} (rel {})",
                    cert.primal,
                    cert.upper_bound,
                    cert.relative_gap()
                ),
            )
        });
    }

    #[test]
    fn gap_detects_bad_candidate() {
        // A deliberately bad Z (mass on the min-variance coordinate) must
        // show a much larger gap than the solver's answer.
        let mut rng = crate::util::rng::Rng::seed_from(231);
        let sigma = SymMat::from_fn(4, |i, j| if i == j { [5.0, 1.0, 0.4, 3.0][i] } else { 0.0 });
        let _ = &mut rng;
        let mut bad = SymMat::zeros(4);
        bad.set(2, 2, 1.0); // worst coordinate
        let lambda = 0.2;
        let cert_bad = certify(&sigma, &bad, lambda);
        let sol = bca::solve(&sigma, lambda, &BcaOptions::default());
        let cert_good = certify(&sigma, &sol.z, lambda);
        assert!(cert_bad.gap > 10.0 * cert_good.gap.max(1e-6), "{} vs {}", cert_bad.gap, cert_good.gap);
    }
}
