//! Algorithm 1 — the paper's block coordinate ascent DSPCA solver.
//!
//! Solves the augmented-Lagrangian form (6) of the DSPCA relaxation (1):
//!
//! ```text
//! max_X  Tr ΣX − λ‖X‖₁ − ½(Tr X)² + β log det X,   X ≻ 0
//! ```
//!
//! by cycling over row/column pairs. Updating row/column `j` (with the
//! `(n−1)`-minor `Y = X_{\j\j}` fixed) reduces, through the dual derivation
//! in §3 of the paper, to:
//!
//! 1. the box-QP (11) `R² = min_u uᵀYu, ‖u − Σ_j‖∞ ≤ λ`   → [`qp`],
//! 2. the 1-D problem in τ (cubic optimality condition)      → [`tau`],
//! 3. the write-back `X_j ← Yu/τ`, `X_jj ← Σ_jj − λ − Tr Y + τ` (8)–(9).
//!
//! A full sweep costs O(n²) per column → O(n³); the paper fixes the number
//! of sweeps K (typically 5), giving O(Kn³) overall — the headline
//! complexity improvement over the O(n⁴√log n) first-order method.
//!
//! An optimal solution of (1) is recovered as `Z* = X*/Tr X*`.
//!
//! Hot-path notes (§Perf): the minor `Y` is never materialized — the QP
//! runs masked on full rows with `u[j] ≡ 0`, and its incrementally
//! maintained `w = Yu` *is* the write-back vector, so step 3 is free.
//!
//! The solver reads Σ only through the [`CovOp`] operator interface
//! (diagonal, row gather, Frobenius product) — the iterate `X` stays a
//! dense [`SymMat`], but Σ may be dense, an implicit Gram operator, a
//! masked elimination view, or a deflated composition. For a dense Σ the
//! generic code monomorphizes to the pre-operator implementation and the
//! results are bitwise unchanged (pinned by `perf_equivalence`).

use crate::covop::CovOp;
use crate::data::SymMat;
use crate::solver::qp::{self, QpOptions};
use crate::solver::tau::{self, TauOptions};
use crate::util::timer::Timer;

/// Options for the BCA solver.
#[derive(Clone, Copy, Debug)]
pub struct BcaOptions {
    /// Maximum full sweeps over all columns (paper: K ≈ 5).
    pub max_sweeps: usize,
    /// Early exit when the largest entry change in a sweep falls below
    /// `tol · (1 + max|X|)`.
    pub tol: f64,
    /// Barrier ε; the barrier weight is `β = ε / n` (ε-suboptimality).
    pub epsilon: f64,
    /// Inner QP options.
    pub qp: QpOptions,
    /// τ solve options.
    pub tau: TauOptions,
    /// Record the problem-(1) objective after every sweep (cheap, O(n²)).
    pub track_history: bool,
}

impl Default for BcaOptions {
    fn default() -> Self {
        BcaOptions {
            max_sweeps: 20,
            tol: 1e-8,
            epsilon: 1e-3,
            qp: QpOptions::default(),
            tau: TauOptions::default(),
            track_history: true,
        }
    }
}

impl BcaOptions {
    /// The paper's fixed-K preset.
    pub fn fixed_sweeps(k: usize) -> BcaOptions {
        BcaOptions { max_sweeps: k, tol: 0.0, ..Default::default() }
    }
}

/// One history sample.
#[derive(Clone, Copy, Debug)]
pub struct HistoryPoint {
    /// Sweep index (1-based).
    pub sweep: usize,
    /// Problem-(1) objective of the normalized iterate `Z = X/TrX`.
    pub objective: f64,
    /// Seconds since solve start.
    pub seconds: f64,
}

/// Solver output.
#[derive(Clone, Debug)]
pub struct BcaSolution {
    /// Final iterate of the barrier problem (6).
    pub x: SymMat,
    /// Normalized solution `Z = X / Tr X` of problem (1).
    pub z: SymMat,
    /// Problem-(1) objective `Tr ΣZ − λ‖Z‖₁` at `Z`.
    pub phi: f64,
    /// Sweeps performed.
    pub sweeps: usize,
    /// Largest entry change in the final sweep.
    pub final_delta: f64,
    /// Per-sweep objective trace (if tracked).
    pub history: Vec<HistoryPoint>,
    /// Total solve seconds.
    pub seconds: f64,
}

/// Reusable buffers for one sweep (avoid allocation in the hot loop).
/// This is the *reference* (cold-start) path; the hot path uses
/// [`SolverWorkspace`].
pub struct SweepBuffers {
    u: Vec<f64>,
    w: Vec<f64>,
    center: Vec<f64>,
    radius: Vec<f64>,
}

impl SweepBuffers {
    /// Buffers for problem size `n`.
    pub fn new(n: usize) -> SweepBuffers {
        SweepBuffers {
            u: Vec::with_capacity(n),
            w: Vec::with_capacity(n),
            center: vec![0.0; n],
            radius: vec![0.0; n],
        }
    }

    /// Problem size these buffers were sized for.
    pub fn capacity(&self) -> usize {
        self.center.len()
    }
}

/// Persistent solver workspace — the warm-started hot path (see
/// EXPERIMENTS.md §Perf).
///
/// Besides the per-sweep scratch of [`SweepBuffers`], it caches every
/// column's previous box-QP solution (`n × n` f64 — ~2 MiB at n = 512) so
/// each `update_column` warm-starts [`qp::solve_masked_warm`] from where
/// the same column converged last sweep. The box center (`Σ_j`) and radius
/// (λ) never change between sweeps, only the minor `Y = X_{\j\j}` drifts,
/// so the cached point is always feasible and usually one verification
/// sweep from optimal once BCA starts converging.
///
/// # Example: hot path vs reference, same optimum
///
/// [`solve`] drives this workspace; the cold-start [`solve_reference`]
/// must land on the same fixed point (the subproblems are convex):
///
/// ```
/// use lsspca::prelude::*;
///
/// let mut rng = Rng::seed_from(3);
/// let sigma = lsspca::corpus::spiked_covariance(24, 80, 3, 2.0, &mut rng);
/// let opts = BcaOptions::default();
/// let hot = lsspca::solver::bca::solve(&sigma, 0.4, &opts);
/// let cold = lsspca::solver::bca::solve_reference(&sigma, 0.4, &opts);
/// assert!((hot.phi - cold.phi).abs() < 1e-6);
/// ```
pub struct SolverWorkspace {
    n: usize,
    u: Vec<f64>,
    w: Vec<f64>,
    center: Vec<f64>,
    radius: Vec<f64>,
    active: Vec<usize>,
    /// Row `j` holds column `j`'s last QP solution (valid iff `visited[j]`).
    prev: Vec<f64>,
    visited: Vec<bool>,
}

impl SolverWorkspace {
    /// Workspace for problem size `n` (allocates the n × n warm-start
    /// cache once; reuse it across sweeps and solves).
    pub fn new(n: usize) -> SolverWorkspace {
        SolverWorkspace {
            n,
            u: Vec::with_capacity(n),
            w: Vec::with_capacity(n),
            center: vec![0.0; n],
            radius: vec![0.0; n],
            active: Vec::with_capacity(n),
            prev: vec![0.0; n * n],
            visited: vec![false; n],
        }
    }

    /// Problem size this workspace serves.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Forget all cached solutions (e.g. when λ or Σ changes between
    /// solves on a reused engine).
    pub fn reset(&mut self) {
        self.visited.fill(false);
    }
}

/// Fill the column-update box of step 4: `center = Σ_j` with the
/// diagonal entry zeroed, uniform radius λ, coordinate `j` pinned.
fn fill_box<C: CovOp + ?Sized>(
    sigma: &C,
    lambda: f64,
    j: usize,
    center: &mut [f64],
    radius: &mut [f64],
) {
    sigma.row_into(j, center);
    center[j] = 0.0;
    for r in radius.iter_mut() {
        *r = lambda;
    }
    radius[j] = 0.0;
}

/// Steps 5–6 shared by the reference and workspace paths: solve the 1-D
/// τ problem and write column `j` back from `w = Yu`. Returns the largest
/// entry change.
#[allow(clippy::too_many_arguments)]
fn write_back_column<C: CovOp + ?Sized>(
    x: &mut SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
    j: usize,
    t: f64,
    r_squared: f64,
    w: &[f64],
    opts: &BcaOptions,
) -> f64 {
    let n = x.n();
    // 1-D τ problem with c = Σ_jj − λ − t.
    let c = sigma.diag(j) - lambda - t;
    let tau_star = tau::solve(r_squared, beta, c, opts.tau);
    // Write-back: y = (1/τ)·Yu — w already holds Yu for i ≠ j.
    let inv_tau = 1.0 / tau_star;
    let mut max_delta = 0.0f64;
    for i in 0..n {
        if i == j {
            continue;
        }
        let new = w[i] * inv_tau;
        let delta = (new - x.get(i, j)).abs();
        if delta > max_delta {
            max_delta = delta;
        }
        x.set(i, j, new);
    }
    let new_diag = c + tau_star;
    max_delta = max_delta.max((new_diag - x.get(j, j)).abs());
    x.set(j, j, new_diag);
    max_delta
}

/// Warm-started, active-set variant of [`update_column`] (identical
/// fixed point; the QP is convex, so start and iteration order do not
/// change the optimum — pinned by the workspace-equivalence tests).
pub fn update_column_ws<C: CovOp + ?Sized>(
    x: &mut SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
    j: usize,
    opts: &BcaOptions,
    ws: &mut SolverWorkspace,
) -> f64 {
    let n = x.n();
    debug_assert_eq!(ws.n, n);
    let t = x.trace() - x.get(j, j); // Tr Y
    fill_box(sigma, lambda, j, &mut ws.center, &mut ws.radius);
    let warm = if ws.visited[j] { Some(&ws.prev[j * n..(j + 1) * n]) } else { None };
    let sol = qp::solve_masked_warm(
        &*x,
        &ws.center,
        &ws.radius,
        Some(j),
        opts.qp,
        warm,
        &mut ws.u,
        &mut ws.w,
        &mut ws.active,
    );
    ws.prev[j * n..(j + 1) * n].copy_from_slice(&ws.u);
    ws.visited[j] = true;
    write_back_column(x, sigma, lambda, beta, j, t, sol.r_squared, &ws.w, opts)
}

/// One full warm-started sweep over all columns.
pub fn sweep_ws<C: CovOp + ?Sized>(
    x: &mut SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
    opts: &BcaOptions,
    ws: &mut SolverWorkspace,
) -> f64 {
    let n = x.n();
    let mut max_delta = 0.0f64;
    for j in 0..n {
        let d = update_column_ws(x, sigma, lambda, beta, j, opts, ws);
        if d > max_delta {
            max_delta = d;
        }
    }
    max_delta
}

/// The problem-(1) objective of the normalized iterate.
pub fn primal_objective<C: CovOp + ?Sized>(x: &SymMat, sigma: &C, lambda: f64) -> f64 {
    let tr = x.trace();
    if tr <= 0.0 {
        return f64::NEG_INFINITY;
    }
    (sigma.frob_with(x) - lambda * x.l1_norm()) / tr
}

/// The barrier objective (6) (O(n³) — used by tests/monitoring only).
pub fn barrier_objective<C: CovOp + ?Sized>(
    x: &SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
) -> Option<f64> {
    let l = crate::linalg::chol::cholesky(x, 0.0)?;
    let n = x.n();
    let mut logdet = 0.0;
    for i in 0..n {
        logdet += l[i * n + i].ln();
    }
    logdet *= 2.0;
    let tr = x.trace();
    Some(sigma.frob_with(x) - lambda * x.l1_norm() - 0.5 * tr * tr + beta * logdet)
}

/// Update one row/column `j` of `X` in place (steps 4–6 of Algorithm 1).
/// Returns the largest entry change.
pub fn update_column<C: CovOp + ?Sized>(
    x: &mut SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
    j: usize,
    opts: &BcaOptions,
    buf: &mut SweepBuffers,
) -> f64 {
    let t = x.trace() - x.get(j, j); // Tr Y
    fill_box(sigma, lambda, j, &mut buf.center, &mut buf.radius);
    let sol = qp::solve_masked(
        &*x,
        &buf.center,
        &buf.radius,
        Some(j),
        opts.qp,
        &mut buf.u,
        &mut buf.w,
    );
    write_back_column(x, sigma, lambda, beta, j, t, sol.r_squared, &buf.w, opts)
}

/// One full sweep over all columns. Returns the largest entry change.
pub fn sweep<C: CovOp + ?Sized>(
    x: &mut SymMat,
    sigma: &C,
    lambda: f64,
    beta: f64,
    opts: &BcaOptions,
    buf: &mut SweepBuffers,
) -> f64 {
    let n = x.n();
    let mut max_delta = 0.0f64;
    for j in 0..n {
        let d = update_column(x, sigma, lambda, beta, j, opts, buf);
        if d > max_delta {
            max_delta = d;
        }
    }
    max_delta
}

/// Solve DSPCA by block coordinate ascent starting from `X⁰ = I`, on the
/// warm-started/active-set hot path. Works on any covariance operator
/// (dense, implicit Gram, masked, deflated).
pub fn solve<C: CovOp + ?Sized>(sigma: &C, lambda: f64, opts: &BcaOptions) -> BcaSolution {
    let mut ws = SolverWorkspace::new(sigma.n());
    solve_with(sigma, lambda, opts, |x, o| {
        let beta = o.epsilon / x.n() as f64;
        Ok(sweep_ws(x, sigma, lambda, beta, o, &mut ws))
    })
    .expect("native sweep cannot fail")
}

/// Reference solve on the cold-start path (every QP starts from the box
/// center, every sweep touches every coordinate). Used by the equivalence
/// tests and as the baseline the `bench` subcommand measures speedups
/// against.
pub fn solve_reference<C: CovOp + ?Sized>(
    sigma: &C,
    lambda: f64,
    opts: &BcaOptions,
) -> BcaSolution {
    let mut buf = SweepBuffers::new(sigma.n());
    solve_with(sigma, lambda, opts, |x, o| {
        let beta = o.epsilon / x.n() as f64;
        Ok(sweep(x, sigma, lambda, beta, o, &mut buf))
    })
    .expect("native sweep cannot fail")
}

/// Generic driver: run Algorithm 1's outer loop with a pluggable sweep
/// implementation (native here; the AOT/XLA engine plugs in through this,
/// so both paths share convergence logic and history tracking).
pub fn solve_with<C: CovOp + ?Sized, F>(
    sigma: &C,
    lambda: f64,
    opts: &BcaOptions,
    mut sweep_fn: F,
) -> Result<BcaSolution, crate::error::LsspcaError>
where
    F: FnMut(&mut SymMat, &BcaOptions) -> Result<f64, crate::error::LsspcaError>,
{
    let n = sigma.n();
    assert!(n > 0, "empty covariance");
    let min_diag = (0..n).map(|i| sigma.diag(i)).fold(f64::INFINITY, f64::min);
    if lambda >= min_diag {
        // Thm 2.1: such features should have been eliminated; the
        // derivation of (5) assumed λ < min Σ_ii. Proceed (the barrier
        // keeps the iteration well-defined) but warn.
        crate::warn_!(
            "BCA called with λ={lambda} ≥ min Σ_ii={min_diag}; run safe elimination first"
        );
    }
    let timer = Timer::start();
    let mut x = SymMat::identity(n);
    let mut history = Vec::new();
    let mut final_delta = f64::INFINITY;
    let mut sweeps = 0;
    for k in 0..opts.max_sweeps {
        final_delta = sweep_fn(&mut x, opts)?;
        sweeps = k + 1;
        if opts.track_history {
            history.push(HistoryPoint {
                sweep: sweeps,
                objective: primal_objective(&x, sigma, lambda),
                seconds: timer.secs(),
            });
        }
        let scale = 1.0 + x.as_slice().iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        if final_delta <= opts.tol * scale {
            break;
        }
    }
    let tr = x.trace();
    let mut z = x.clone();
    if tr > 0.0 {
        crate::linalg::vec::scale(1.0 / tr, z.as_mut_slice());
    }
    let phi = primal_objective(&x, sigma, lambda);
    Ok(BcaSolution {
        x,
        z,
        phi,
        sweeps,
        final_delta,
        history,
        seconds: timer.secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::{gaussian_factor_cov, spiked_covariance_with_u};
    use crate::linalg::chol::is_psd;
    use crate::util::check::{close, ensure, property};
    use crate::util::rng::Rng;

    fn small_opts() -> BcaOptions {
        BcaOptions { max_sweeps: 30, ..Default::default() }
    }

    #[test]
    fn diagonal_sigma_closed_form() {
        // For diagonal Σ and λ < min Σ_ii, problem (1)'s optimum puts all
        // mass on the largest diagonal entry: φ = max_i Σ_ii − λ.
        let sigma = SymMat::from_fn(4, |i, j| if i == j { [4.0, 1.0, 2.5, 0.9][i] } else { 0.0 });
        let sol = solve(&sigma, 0.5, &small_opts());
        assert!((sol.phi - 3.5).abs() < 1e-3, "phi={}", sol.phi);
        // Z concentrates on coordinate 0
        assert!(sol.z.get(0, 0) > 0.99);
    }

    #[test]
    fn prop_barrier_objective_monotone_per_column() {
        property("BCA column update never decreases barrier objective", 10, |rng| {
            let n = rng.range(2, 9);
            let sigma = SymMat::random_psd(n, n + 4, 0.2, rng);
            let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
            let lambda = rng.range_f64(0.0, 0.9) * min_diag;
            let opts = small_opts();
            let beta = opts.epsilon / n as f64;
            let mut x = SymMat::identity(n);
            let mut buf = SweepBuffers::new(n);
            let mut prev = barrier_objective(&x, &sigma, lambda, beta).ok_or("X0 not PD")?;
            for _ in 0..2 {
                for j in 0..n {
                    update_column(&mut x, &sigma, lambda, beta, j, &opts, &mut buf);
                    let cur = barrier_objective(&x, &sigma, lambda, beta)
                        .ok_or("iterate left the PD cone")?;
                    ensure(
                        cur >= prev - 1e-7 * (1.0 + prev.abs()),
                        format!("objective dropped: {prev} → {cur} (col {j})"),
                    )?;
                    prev = cur;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_iterates_stay_pd_and_symmetric() {
        property("BCA keeps X ≻ 0 and symmetric", 10, |rng| {
            let n = rng.range(2, 10);
            let sigma = SymMat::random_psd(n, n + 3, 0.2, rng);
            let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
            let lambda = rng.range_f64(0.1, 0.8) * min_diag;
            let sol = solve(&sigma, lambda, &small_opts());
            ensure(sol.x.asymmetry() < 1e-9, "X must stay symmetric")?;
            ensure(is_psd(&sol.x, 1e-10), "X must stay PSD")?;
            ensure(sol.phi.is_finite(), "objective finite")?;
            Ok(())
        });
    }

    #[test]
    fn prop_history_monotone_over_sweeps() {
        property("primal objective increases sweep over sweep", 8, |rng| {
            let n = rng.range(3, 12);
            let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
            let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
            let sol = solve(&sigma, 0.5 * min_diag, &small_opts());
            // The *barrier* objective is exactly monotone (tested above);
            // the normalized problem-(1) objective tracked in history can
            // wiggle at the last digits near convergence — allow FP slack.
            for w in sol.history.windows(2) {
                ensure(
                    w[1].objective >= w[0].objective - 1e-4 * (1.0 + w[0].objective.abs()),
                    format!("history not monotone: {} → {}", w[0].objective, w[1].objective),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn lambda_zero_recovers_pca() {
        // With λ = 0, problem (1) is plain PCA: φ = λ_max(Σ).
        let mut rng = Rng::seed_from(91);
        let sigma = SymMat::random_psd(8, 20, 0.1, &mut rng);
        let eig = crate::linalg::eig::JacobiEig::new(&sigma);
        let sol = solve(&sigma, 0.0, &BcaOptions { max_sweeps: 60, epsilon: 1e-5, ..Default::default() });
        close(sol.phi, eig.lambda_max(), 2e-3).unwrap();
    }

    #[test]
    fn large_lambda_gives_sparse_solution() {
        let mut rng = Rng::seed_from(92);
        let (sigma, u) = spiked_covariance_with_u(20, 60, 3, 4.0, &mut rng);
        // λ just below the spike coordinates' variances kills the rest.
        let lam = {
            let mut diags: Vec<f64> = (0..20).map(|i| sigma.get(i, i)).collect();
            diags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            diags[4] * 1.01
        };
        let sol = solve(&sigma, lam, &small_opts());
        let pc = crate::solver::extract::leading_sparse_pc(&sol.z, 1e-3);
        ensure(pc.support.len() <= 6, format!("support {:?}", pc.support)).unwrap();
        // support should overlap the planted spike
        let planted = crate::linalg::vec::support(&u, 1e-9);
        let hits = pc.support.iter().filter(|i| planted.contains(i)).count();
        assert!(hits >= 2, "support {:?} vs planted {:?}", pc.support, planted);
    }

    #[test]
    fn fixed_sweeps_runs_exactly_k() {
        let mut rng = Rng::seed_from(93);
        let sigma = gaussian_factor_cov(6, 12, &mut rng);
        let sol = solve(&sigma, 0.01, &BcaOptions::fixed_sweeps(3));
        assert_eq!(sol.sweeps, 3);
        assert_eq!(sol.history.len(), 3);
    }
}
