//! Solvers: the paper's block coordinate ascent DSPCA algorithm
//! (Algorithm 1) with its two sub-problems, plus every baseline the
//! evaluation compares against.
//!
//! | module | paper reference |
//! |---|---|
//! | [`qp`] | the box-constrained QP (11) with closed-form update (13) |
//! | [`tau`] | the 1-D τ problem (cubic optimality condition) |
//! | [`bca`] | Algorithm 1 — block coordinate ascent, O(K n³) |
//! | [`first_order`] | the O(n⁴√log n) first-order DSPCA method of [1] (Fig 1 baseline) |
//! | [`greedy`] | forward greedy selection (Moghaddam [5] / d'Aspremont [6] baseline) |
//! | [`gpower`] | generalized power method (Journée et al. [10] baseline) |
//! | [`spca_zou`] | SPCA via alternating elastic net (Zou et al. [8] baseline) |
//! | [`certificate`] | dual-feasible optimality certificates (gap bounds) |
//! | [`path`] | λ regularization path with per-λ safe elimination |
//! | [`pca`] | plain PCA via power iteration (the O(n²) comparison point) |
//! | [`threshold`] | simple thresholding baseline (Cadima–Jolliffe [4]) |
//! | [`deflate`] | deflation schemes for extracting multiple PCs |
//! | [`lambda`] | λ-search for a target cardinality (§4's "coarse range of λ") |
//! | [`extract`] | recover the sparse PC from the SDP solution `X*` |

pub mod bca;
pub mod certificate;
pub mod deflate;
pub mod extract;
pub mod first_order;
pub mod gpower;
pub mod greedy;
pub mod lambda;
pub mod path;
pub mod pca;
pub mod qp;
pub mod spca_zou;
pub mod tau;
pub mod threshold;
