//! The 1-D sub-problem of Algorithm 1, step 5:
//!
//! ```text
//! min_{τ > 0}  R²/τ − β log τ + ½ (c + τ)²
//! ```
//!
//! with `c = Σ_jj − λ − t`. The stationarity condition
//!
//! ```text
//! −R²/τ² − β/τ + (c + τ) = 0   ⟺   τ³ + cτ² − βτ − R² = 0
//! ```
//!
//! has a *unique* positive root: the derivative `g(τ) = −R²/τ² − β/τ + c + τ`
//! is strictly increasing on τ > 0 (g′ = 2R²/τ³ + β/τ² + 1 > 0), tends to
//! −∞ at 0⁺ and +∞ at ∞. We bracket it and run safeguarded
//! Newton-bisection. The paper offers bisection or solving the degree-3
//! polynomial; this hybrid does both at once (Newton steps = cubic-solving,
//! the bracket keeps it safe).
//!
//! At the root, the new diagonal element `x = c + τ = β/τ + R²/τ² > 0` —
//! the barrier automatically keeps `X ≻ 0`.

/// Options for the τ solve.
#[derive(Clone, Copy, Debug)]
pub struct TauOptions {
    /// Newton convergence tolerance on τ.
    pub tol: f64,
    /// Maximum Newton iterations.
    pub max_iters: usize,
}

impl Default for TauOptions {
    fn default() -> Self {
        TauOptions { tol: 1e-13, max_iters: 200 }
    }
}

/// Derivative g(τ) of the objective.
#[inline]
fn g(tau: f64, r2: f64, beta: f64, c: f64) -> f64 {
    -r2 / (tau * tau) - beta / tau + c + tau
}

/// Solve for the unique positive root. Requires `beta > 0` (the barrier)
/// or `r2 > 0`; when both are zero the problem degenerates to
/// `min ½(c+τ)²`, whose minimizer over τ>0 is `max(−c, 0⁺)` — we return
/// a tiny positive τ in that case.
pub fn solve(r2: f64, beta: f64, c: f64, opts: TauOptions) -> f64 {
    debug_assert!(r2 >= 0.0, "R² must be non-negative");
    debug_assert!(beta >= 0.0, "β must be non-negative");
    if r2 <= 0.0 && beta <= 0.0 {
        return (-c).max(1e-300);
    }
    // Bracket: g(lo) < 0 < g(hi).
    let mut hi = 1.0f64.max(-c) + beta + r2.sqrt() + 1.0;
    while g(hi, r2, beta, c) < 0.0 {
        hi *= 2.0;
    }
    let mut lo = hi.min(1e-3);
    while g(lo, r2, beta, c) > 0.0 {
        lo *= 0.5;
        if lo < 1e-300 {
            break;
        }
    }
    // Safeguarded Newton. Return `tau` the moment its residual is inside
    // tolerance — checking *before* moving, so a converged iterate is never
    // replaced by a bisection midpoint (the subtle bug the τ property test
    // caught: at g(τ)=0 the Newton step equals lo and the fallback midpoint
    // would otherwise be returned).
    let mut tau = 0.5 * (lo + hi);
    for _ in 0..opts.max_iters {
        let val = g(tau, r2, beta, c);
        if val.abs() <= opts.tol * (1.0 + c.abs()) {
            return tau;
        }
        if val > 0.0 {
            hi = tau;
        } else {
            lo = tau;
        }
        let deriv = 2.0 * r2 / (tau * tau * tau) + beta / (tau * tau) + 1.0;
        let newton = tau - val / deriv;
        tau = if newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
        if (hi - lo) <= opts.tol * (1.0 + tau.abs()) {
            break;
        }
    }
    tau
}

/// Objective value at τ (for tests).
pub fn objective(tau: f64, r2: f64, beta: f64, c: f64) -> f64 {
    r2 / tau - beta * tau.ln() + 0.5 * (c + tau) * (c + tau)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{ensure, property};

    #[test]
    fn known_root() {
        // τ³ + cτ² − βτ − R² with τ=1, c=0, β=0.5 → R² = 1 − 0.5 = 0.5
        let tau = solve(0.5, 0.5, 0.0, TauOptions::default());
        assert!((tau - 1.0).abs() < 1e-10, "tau={tau}");
    }

    #[test]
    fn prop_root_is_stationary_and_minimal() {
        property("τ: stationarity + local optimality + x>0", 50, |rng| {
            let r2 = rng.range_f64(0.0, 10.0);
            let beta = rng.range_f64(1e-8, 0.5);
            let c = rng.range_f64(-10.0, 10.0);
            let tau = solve(r2, beta, c, TauOptions::default());
            ensure(tau > 0.0, "τ must be positive")?;
            let val = g(tau, r2, beta, c);
            ensure(
                val.abs() < 1e-6 * (1.0 + c.abs() + r2),
                format!("g(τ*)={val} not ~0 (τ={tau})"),
            )?;
            // objective at τ* below neighbors
            let f0 = objective(tau, r2, beta, c);
            for mult in [0.9, 1.1] {
                let f1 = objective(tau * mult, r2, beta, c);
                ensure(f0 <= f1 + 1e-9 * (1.0 + f1.abs()), "not a local min")?;
            }
            // x = c + τ = β/τ + R²/τ² > 0
            let x = c + tau;
            ensure(x > 0.0, format!("x = {x} must be positive"))?;
            let identity = beta / tau + r2 / (tau * tau);
            ensure(
                (x - identity).abs() < 1e-5 * (1.0 + identity),
                format!("x {x} != β/τ + R²/τ² {identity}"),
            )?;
            Ok(())
        });
    }

    #[test]
    fn degenerate_no_barrier_no_r2() {
        let tau = solve(0.0, 0.0, -3.0, TauOptions::default());
        assert!((tau - 3.0).abs() < 1e-9);
        let tau2 = solve(0.0, 0.0, 5.0, TauOptions::default());
        assert!(tau2 > 0.0 && tau2 < 1e-200);
    }

    #[test]
    fn huge_r2_and_negative_c() {
        let tau = solve(1e8, 1e-6, -1e4, TauOptions::default());
        assert!(tau.is_finite() && tau > 0.0);
        assert!(g(tau, 1e8, 1e-6, -1e4).abs() < 1e-2);
    }
}
