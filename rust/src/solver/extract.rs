//! Recover the sparse principal component from the SDP solution.
//!
//! Problem (1)'s solution `Z*` is (near) rank-one when the relaxation is
//! tight; the sparse PC is its leading eigenvector. Small numerical dust
//! below `tol` is truncated to give the crisp support reported in the
//! paper's tables.

use crate::data::SymMat;
use crate::linalg::power::power_iteration;
use crate::linalg::vec::{normalize, norm2};
use crate::util::rng::Rng;

/// A sparse principal component.
#[derive(Clone, Debug)]
pub struct SparsePc {
    /// Unit-norm loading vector (zeros off support).
    pub vector: Vec<f64>,
    /// Indices of the nonzero loadings, sorted by decreasing |loading|.
    pub support: Vec<usize>,
    /// Leading eigenvalue of `Z*` (rank-one-ness diagnostic: ≈ 1 when tight).
    pub z_eigenvalue: f64,
}

impl SparsePc {
    /// Cardinality of the component.
    pub fn cardinality(&self) -> usize {
        self.support.len()
    }

    /// Explained variance `xᵀΣx` of this component on a covariance.
    pub fn explained_variance(&self, sigma: &SymMat) -> f64 {
        sigma.quad_form(&self.vector)
    }

    /// Re-express the PC in a larger index space through `map`
    /// (`map[reduced] = target index`, e.g. the
    /// [`kept`](crate::elim::SafeElimination::kept) survivor map of a
    /// safe elimination): loadings are
    /// scattered into a length-`n_target` vector and the support is
    /// remapped in place, preserving its decreasing-|loading| order. This
    /// is how λ-search probes lift masked solves back to the caller's
    /// coordinates and how the model artifact carries PCs in
    /// original-vocabulary indices.
    pub fn mapped(&self, map: &[usize], n_target: usize) -> SparsePc {
        assert_eq!(self.vector.len(), map.len(), "map must cover the reduced space");
        let mut vector = vec![0.0; n_target];
        for (r, &target) in map.iter().enumerate() {
            assert!(target < n_target, "map entry {target} out of range {n_target}");
            vector[target] = self.vector[r];
        }
        SparsePc {
            vector,
            support: self.support.iter().map(|&r| map[r]).collect(),
            z_eigenvalue: self.z_eigenvalue,
        }
    }

    /// The `(index, loading)` pairs of the support, in decreasing
    /// |loading| order (the model artifact's PC payload).
    pub fn loadings(&self) -> Vec<(usize, f64)> {
        self.support.iter().map(|&i| (i, self.vector[i])).collect()
    }
}

/// Extract the leading sparse PC from `Z*` (or any PSD matrix).
///
/// `tol` is the relative magnitude below which loadings are truncated to
/// zero (relative to the largest |loading|).
pub fn leading_sparse_pc(z: &SymMat, tol: f64) -> SparsePc {
    // Deterministic seed: extraction must be reproducible.
    let mut rng = Rng::seed_from(0xD59Cu64 ^ z.n() as u64);
    let res = power_iteration(z, 10_000, 1e-12, &mut rng);
    let mut v = res.vector;
    // Truncate dust, renormalize.
    let maxabs = v.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    if maxabs > 0.0 {
        for x in v.iter_mut() {
            if x.abs() < tol * maxabs {
                *x = 0.0;
            }
        }
    }
    if norm2(&v) > 0.0 {
        normalize(&mut v);
    }
    // Canonical sign: largest-|loading| entry positive.
    let mut support: Vec<usize> = (0..v.len()).filter(|&i| v[i] != 0.0).collect();
    support.sort_by(|&a, &b| v[b].abs().partial_cmp(&v[a].abs()).unwrap());
    if let Some(&lead) = support.first() {
        if v[lead] < 0.0 {
            for x in v.iter_mut() {
                *x = -*x;
            }
        }
    }
    SparsePc { vector: v, support, z_eigenvalue: res.value }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn rank_one_recovery() {
        // Z = vvᵀ with sparse v → exact recovery.
        let v = {
            let mut v = vec![0.0; 6];
            v[1] = 0.8;
            v[4] = -0.6;
            v
        };
        let z = SymMat::from_fn(6, |i, j| v[i] * v[j]);
        let pc = leading_sparse_pc(&z, 1e-6);
        assert_eq!(pc.support.len(), 2);
        assert_eq!(pc.support[0], 1);
        assert_eq!(pc.support[1], 4);
        close(pc.z_eigenvalue, 1.0, 1e-8).unwrap();
        // canonical sign: leading loading positive
        assert!(pc.vector[1] > 0.0);
        close(pc.vector[1], 0.8, 1e-8).unwrap();
        close(pc.vector[4], -0.6, 1e-8).unwrap();
    }

    #[test]
    fn prop_unit_norm_and_sorted_support() {
        property("extracted PC: unit norm, support sorted by |loading|", 15, |rng| {
            let n = rng.range(2, 12);
            let z = SymMat::random_psd(n, n + 2, 1e-6, rng);
            let pc = leading_sparse_pc(&z, 1e-4);
            close(crate::linalg::vec::norm2(&pc.vector), 1.0, 1e-9)?;
            for w in pc.support.windows(2) {
                ensure(
                    pc.vector[w[0]].abs() >= pc.vector[w[1]].abs() - 1e-15,
                    "support not sorted",
                )?;
            }
            ensure(
                pc.explained_variance(&z) >= -1e-12,
                "explained variance must be ≥ 0 on PSD",
            )?;
            Ok(())
        });
    }

    #[test]
    fn mapped_scatters_and_remaps() {
        let pc = SparsePc {
            vector: vec![0.8, 0.0, -0.6],
            support: vec![0, 2],
            z_eigenvalue: 1.0,
        };
        let lifted = pc.mapped(&[5, 9, 11], 20);
        assert_eq!(lifted.vector.len(), 20);
        assert_eq!(lifted.vector[5], 0.8);
        assert_eq!(lifted.vector[11], -0.6);
        assert_eq!(lifted.support, vec![5, 11]);
        assert_eq!(lifted.cardinality(), pc.cardinality());
        assert_eq!(lifted.loadings(), vec![(5, 0.8), (11, -0.6)]);
    }

    #[test]
    fn truncation_respects_tol() {
        // leading eigenvector has a tiny component that must be zeroed
        let mut v = vec![0.70710678, 0.70710678, 1e-8];
        normalize(&mut v);
        let z = SymMat::from_fn(3, |i, j| v[i] * v[j]);
        let pc = leading_sparse_pc(&z, 1e-4);
        assert_eq!(pc.cardinality(), 2);
        assert_eq!(pc.vector[2], 0.0);
    }
}
