//! λ-search for a target cardinality.
//!
//! §4 of the paper: "we run our algorithm with a coarse range of λ to
//! search for a solution with the given cardinality [5]... we might end up
//! accepting a solution with cardinality close, but not necessarily equal
//! to, 5". Cardinality is monotone non-increasing in λ (larger penalty →
//! sparser), so a bracketing bisection over λ converges quickly; we accept
//! within ±`slack` of the target and keep the best-seen solution
//! otherwise.

use crate::covop::{CovOp, MaskedCov};
use crate::solver::bca::{self, BcaOptions, BcaSolution};
use crate::solver::extract::{leading_sparse_pc, SparsePc};

/// Options for the cardinality-targeted λ search.
#[derive(Clone, Copy, Debug)]
pub struct LambdaSearchOptions {
    /// Desired PC cardinality (paper: 5).
    pub target_card: usize,
    /// Accept |card − target| ≤ slack.
    pub slack: usize,
    /// Maximum solver evaluations.
    pub max_evals: usize,
    /// Loading truncation tolerance for cardinality measurement.
    pub extract_tol: f64,
    /// Inner-solver options shared by every probe.
    pub bca: BcaOptions,
    /// Independent λ probes per bracketing round. 1 = classic bisection
    /// (the midpoint); `p` > 1 splits the bracket into `p + 1` equal parts
    /// and evaluates all `p` interior probes, shrinking the bracket by a
    /// factor `p + 1` per round. The probe *schedule* depends only on this
    /// value — never on `threads` — so results are reproducible across
    /// machines and thread counts.
    pub probes_per_round: usize,
    /// Worker threads evaluating one round's probes (0 = auto, 1 = serial).
    pub threads: usize,
    /// Per-λ nested elimination (Thm 2.1): each probe solves on the
    /// survivor subset for *its own* λ through a zero-copy [`MaskedCov`]
    /// view, so high-λ probes run on much smaller subproblems. Disabling
    /// it (the benchmark's "no masks" arm) solves every probe on the full
    /// operator — same optimum, strictly more work.
    pub per_lambda_elim: bool,
}

impl Default for LambdaSearchOptions {
    fn default() -> Self {
        LambdaSearchOptions {
            target_card: 5,
            slack: 2,
            max_evals: 12,
            extract_tol: 1e-3,
            bca: BcaOptions::default(),
            probes_per_round: 1,
            threads: 1,
            per_lambda_elim: true,
        }
    }
}

/// One evaluation in the search trace.
#[derive(Clone, Debug)]
pub struct LambdaEval {
    /// Probe λ.
    pub lambda: f64,
    /// Cardinality of the extracted PC at this λ.
    pub cardinality: usize,
    /// Problem-(1) objective at this λ.
    pub phi: f64,
}

/// Search result: chosen λ, its solution, PC, and the full trace.
#[derive(Clone, Debug)]
pub struct LambdaSearchResult {
    /// Accepted λ.
    pub lambda: f64,
    /// Solver output at the accepted λ.
    pub solution: BcaSolution,
    /// Extracted sparse PC at the accepted λ.
    pub pc: SparsePc,
    /// Every evaluation, in search order.
    pub trace: Vec<LambdaEval>,
    /// Whether the accepted cardinality is within the slack.
    pub hit_target: bool,
}

/// One solver evaluation at a fixed λ, exactly as a [`search`] probe
/// performs it: per-λ safe elimination (when `opts.per_lambda_elim`),
/// BCA on the survivor view, PC extraction, and lifting back to the
/// caller's coordinates. [`crate::session::Session::fit`] uses this for
/// fixed-λ grid points so a grid solve is bitwise-identical to the same
/// λ landing as a search probe.
pub fn evaluate<C: CovOp + ?Sized>(
    sigma: &C,
    lambda: f64,
    opts: &LambdaSearchOptions,
) -> (BcaSolution, SparsePc) {
    // Safe elimination *at this probe λ* (Thm 2.1): features with
    // Σ_ii ≤ λ cannot enter the optimum, so each search evaluation solves
    // only the surviving principal submatrix — a large speedup when the
    // search probes big λ values, and exactly the paper's usage pattern
    // ("applying this safe feature elimination test with a large λ ...
    // leads to huge computational savings"). The submatrix is never
    // materialized: the solve runs on a [`MaskedCov`] view of the shared
    // operator, which for a dense base reads the identical f64 entries
    // the submatrix would hold. The solution is lifted back to the
    // caller's coordinates; φ is unchanged (the test is safe).
    let n = sigma.n();
    if !opts.per_lambda_elim {
        let sol = bca::solve(sigma, lambda, &opts.bca);
        let pc = leading_sparse_pc(&sol.z, opts.extract_tol);
        return (sol, pc);
    }
    let diags: Vec<f64> = (0..n).map(|i| sigma.diag(i)).collect();
    let elim = crate::elim::SafeElimination::apply(&diags, lambda, None);
    if elim.reduced() == n || elim.reduced() == 0 {
        let sol = bca::solve(sigma, lambda, &opts.bca);
        let pc = leading_sparse_pc(&sol.z, opts.extract_tol);
        return (sol, pc);
    }
    let sub = MaskedCov::new(sigma, elim.kept.clone());
    let sol = bca::solve(&sub, lambda, &opts.bca);
    // lift vector + support back to the full coordinate space
    let pc = leading_sparse_pc(&sol.z, opts.extract_tol).mapped(&elim.kept, n);
    (sol, pc)
}

/// Run the search on a (reduced) covariance matrix.
///
/// The bracket starts at `[0, max_diag)` — at λ ≥ max Σ_ii every feature is
/// eliminated, so cardinality is 0 there; at λ = 0 the solution is dense.
///
/// Bracketing over λ: an exact hit stops the search; a within-slack
/// solution is accepted (paper §4: "close, but not necessarily equal")
/// only after a few refining evaluations have tried for the exact target —
/// the best-seen solution is kept either way. With
/// `probes_per_round == 1` this is classic midpoint bisection; with more
/// probes the round's evaluations are *independent* and run on
/// `opts.threads` workers (the probe schedule never depends on the thread
/// count, so the result is identical for any `threads` — see the
/// `perf_equivalence` tests).
pub fn search<C: CovOp + ?Sized>(sigma: &C, opts: &LambdaSearchOptions) -> LambdaSearchResult {
    search_observed(sigma, opts, &mut |_| {})
}

/// [`search`] with a per-evaluation callback: `on_eval` fires for every
/// probe as it is folded (deterministic ascending-λ order within a
/// round), carrying the probe's λ, cardinality and φ — the λ-grid
/// progress feed for [`crate::session::Progress`] observers. The
/// callback cannot change the search: results are identical to
/// [`search`] for any callback.
pub fn search_observed<C: CovOp + ?Sized>(
    sigma: &C,
    opts: &LambdaSearchOptions,
    on_eval: &mut dyn FnMut(&LambdaEval),
) -> LambdaSearchResult {
    let n = sigma.n();
    assert!(n > 0);
    let probes = opts.probes_per_round.max(1);
    let max_diag = (0..n).map(|i| sigma.diag(i)).fold(0.0f64, f64::max);
    let mut lo = 0.0f64; // card(lo) ≥ target side
    let mut hi = max_diag * 0.999; // card(hi) ≤ target side (sparser)
    let mut trace = Vec::new();
    let mut best: Option<(f64, BcaSolution, SparsePc)> = None;
    // score: distance to target, tie-broken toward higher φ
    let mut best_key = (usize::MAX, f64::NEG_INFINITY);
    let mut evals = 0usize;
    while evals < opts.max_evals {
        // This round's probe grid: `count` equally spaced interior points
        // of the bracket (the midpoint when count == 1).
        let count = probes.min(opts.max_evals - evals);
        let step = (hi - lo) / (count + 1) as f64;
        let lambdas: Vec<f64> = (1..=count).map(|k| lo + step * k as f64).collect();
        let results = crate::util::parallel::par_map_indexed(
            opts.threads,
            lambdas.len(),
            |k| evaluate(sigma, lambdas[k], opts),
        );
        // Fold in ascending-λ order — deterministic regardless of which
        // worker evaluated which probe. An exact hit stops immediately; a
        // within-slack evaluation is accepted only once half the budget
        // has tried for the exact target (identical to the classic
        // bisection's rule at `probes_per_round == 1`).
        let mut stop = false;
        for (k, (sol, pc)) in results.into_iter().enumerate() {
            let lambda = lambdas[k];
            evals += 1;
            let card = pc.cardinality();
            trace.push(LambdaEval { lambda, cardinality: card, phi: sol.phi });
            on_eval(trace.last().expect("just pushed"));
            let dist = card.abs_diff(opts.target_card);
            let key = (dist, sol.phi);
            if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 > best_key.1) {
                best_key = key;
                best = Some((lambda, sol, pc));
            }
            if dist == 0 || (dist <= opts.slack && evals >= opts.max_evals / 2) {
                stop = true;
                break;
            }
            // Cardinality is monotone non-increasing in λ: probes that are
            // too dense raise the lower edge, too-sparse ones lower the
            // upper edge. Measured cardinality comes from an approximate
            // solve, though, so a probe contradicting the current bracket
            // (which would invert it) is ignored rather than applied — the
            // bracket stays valid and refinement continues. At one probe
            // per round the midpoint is always strictly interior, so this
            // never fires and classic bisection is preserved exactly.
            if card > opts.target_card {
                if lambda < hi {
                    lo = lo.max(lambda);
                }
            } else if lambda > lo {
                hi = hi.min(lambda);
            }
        }
        if stop || hi - lo < 1e-12 * (1.0 + max_diag) {
            break; // accepted, or bracket collapsed
        }
    }
    let (lambda, solution, pc) = best.expect("at least one evaluation");
    let hit_target = pc.cardinality().abs_diff(opts.target_card) <= opts.slack;
    LambdaSearchResult { lambda, solution, pc, trace, hit_target }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::spiked_covariance_with_u;
    use crate::util::check::ensure;
    use crate::util::rng::Rng;

    #[test]
    fn finds_target_cardinality_on_spiked() {
        let mut rng = Rng::seed_from(141);
        let (sigma, u) = spiked_covariance_with_u(30, 90, 5, 5.0, &mut rng);
        let opts = LambdaSearchOptions { target_card: 5, slack: 1, ..Default::default() };
        let res = search(&sigma, &opts);
        assert!(res.hit_target, "trace: {:?}", res.trace);
        let card = res.pc.cardinality();
        assert!((4..=6).contains(&card), "card={card}");
        // support recovers most of the spike
        let planted = crate::linalg::vec::support(&u, 1e-9);
        let hits = res.pc.support.iter().filter(|i| planted.contains(i)).count();
        assert!(hits >= 3, "hits={hits} support={:?} planted={planted:?}", res.pc.support);
    }

    #[test]
    fn trace_cardinalities_follow_bracketing() {
        let mut rng = Rng::seed_from(142);
        let (sigma, _) = spiked_covariance_with_u(20, 60, 4, 3.0, &mut rng);
        let opts = LambdaSearchOptions { target_card: 4, slack: 0, max_evals: 10, ..Default::default() };
        let res = search(&sigma, &opts);
        ensure(!res.trace.is_empty(), "must evaluate at least once").unwrap();
        // chosen λ yields the reported cardinality
        assert_eq!(
            res.pc.cardinality(),
            res.trace
                .iter()
                .find(|e| e.lambda == res.lambda)
                .map(|e| e.cardinality)
                .unwrap()
        );
    }

    #[test]
    fn target_one_gives_singleton() {
        let mut rng = Rng::seed_from(143);
        let (sigma, _) = spiked_covariance_with_u(15, 45, 3, 4.0, &mut rng);
        let opts = LambdaSearchOptions { target_card: 1, slack: 0, max_evals: 16, ..Default::default() };
        let res = search(&sigma, &opts);
        assert!(res.pc.cardinality() <= 2, "card={}", res.pc.cardinality());
    }
}
