//! Deflation: remove an extracted component before computing the next one
//! (the paper extracts "the top 5 sparse principal components" — its tables
//! are produced by repeated solve-then-deflate).

use crate::data::SymMat;
use crate::linalg::vec::dot;

/// Projection deflation: `Σ ← (I − vvᵀ) Σ (I − vvᵀ)` for a unit vector v.
/// Keeps PSD-ness and removes all variance along `v` (robust to `v` not
/// being an exact eigenvector — the right choice for sparse PCs).
pub fn projection(sigma: &mut SymMat, v: &[f64]) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    // w = Σ v, α = vᵀΣv
    let mut w = vec![0.0; n];
    sigma.matvec(v, &mut w);
    let alpha = dot(v, &w);
    // Σ' = Σ − v wᵀ − w vᵀ + α v vᵀ
    let buf = sigma.as_mut_slice();
    for i in 0..n {
        for j in 0..n {
            buf[i * n + j] += -v[i] * w[j] - w[i] * v[j] + alpha * v[i] * v[j];
        }
    }
}

/// Hotelling deflation: `Σ ← Σ − θ v vᵀ` with `θ = vᵀΣv` (exact for true
/// eigenvectors; can lose PSD-ness for approximate ones).
pub fn hotelling(sigma: &mut SymMat, v: &[f64], theta: f64) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    let buf = sigma.as_mut_slice();
    for i in 0..n {
        for j in 0..n {
            buf[i * n + j] -= theta * v[i] * v[j];
        }
    }
}

/// Scheme selector used by the pipeline config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Projection,
    Hotelling,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "projection" => Some(Scheme::Projection),
            "hotelling" => Some(Scheme::Hotelling),
            _ => None,
        }
    }

    /// Apply the scheme for a unit direction `v` on `sigma`.
    pub fn apply(self, sigma: &mut SymMat, v: &[f64]) {
        match self {
            Scheme::Projection => projection(sigma, v),
            Scheme::Hotelling => {
                let mut w = vec![0.0; sigma.n()];
                sigma.matvec(v, &mut w);
                let theta = dot(v, &w);
                hotelling(sigma, v, theta);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::is_psd;
    use crate::linalg::vec::normalize;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn prop_projection_annihilates_direction() {
        property("projection deflation: vᵀΣ'v = 0, Σ'v = 0, PSD kept", 15, |rng| {
            let n = rng.range(2, 10);
            let mut sigma = SymMat::random_psd(n, n + 5, 0.1, rng);
            let mut v = rng.gauss_vec(n);
            normalize(&mut v);
            projection(&mut sigma, &v);
            close(sigma.quad_form(&v), 0.0, 1e-8)?;
            let mut w = vec![0.0; n];
            sigma.matvec(&v, &mut w);
            for &x in &w {
                close(x, 0.0, 1e-8)?;
            }
            ensure(is_psd(&sigma, 1e-8), "projection must keep PSD")?;
            ensure(sigma.asymmetry() < 1e-9, "symmetric")?;
            Ok(())
        });
    }

    #[test]
    fn hotelling_exact_for_eigenvector() {
        let mut rng = crate::util::rng::Rng::seed_from(121);
        let sigma0 = SymMat::random_psd(6, 18, 0.1, &mut rng);
        let eig = crate::linalg::eig::JacobiEig::new(&sigma0);
        let mut sigma = sigma0.clone();
        hotelling(&mut sigma, eig.vector(0), eig.values[0]);
        // new top eigenvalue = old second eigenvalue
        let e2 = crate::linalg::eig::JacobiEig::new(&sigma);
        assert!((e2.lambda_max() - eig.values[1]).abs() < 1e-7);
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("projection"), Some(Scheme::Projection));
        assert_eq!(Scheme::parse("hotelling"), Some(Scheme::Hotelling));
        assert_eq!(Scheme::parse("x"), None);
    }
}
