//! Deflation: remove an extracted component before computing the next one
//! (the paper extracts "the top 5 sparse principal components" — its tables
//! are produced by repeated solve-then-deflate).

use crate::data::SymMat;
use crate::linalg::vec::dot;

/// Projection deflation: `Σ ← (I − vvᵀ) Σ (I − vvᵀ)` for a unit vector v.
/// Keeps PSD-ness and removes all variance along `v` (robust to `v` not
/// being an exact eigenvector — the right choice for sparse PCs).
pub fn projection(sigma: &mut SymMat, v: &[f64]) {
    projection_par(sigma, v, 1);
}

/// [`projection`] with the rank-2 update applied over row blocks on
/// `threads` workers. Rows are independent given `w` and `α`, so the
/// result is identical for any thread count.
pub fn projection_par(sigma: &mut SymMat, v: &[f64], threads: usize) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    if n == 0 {
        return;
    }
    // w = Σ v, α = vᵀΣv
    let mut w = vec![0.0; n];
    sigma.matvec(v, &mut w);
    let alpha = dot(v, &w);
    // Σ' = Σ − v wᵀ − w vᵀ + α v vᵀ, row blocks in parallel
    let rows_per_chunk = 64usize;
    let buf = sigma.as_mut_slice();
    crate::util::parallel::par_chunks_mut(threads, buf, rows_per_chunk * n, |off, chunk| {
        let row0 = off / n;
        for (r, row) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let vi = v[i];
            let wi = w[i];
            for j in 0..n {
                row[j] += -vi * w[j] - wi * v[j] + alpha * vi * v[j];
            }
        }
    });
}

/// Hotelling deflation: `Σ ← Σ − θ v vᵀ` with `θ = vᵀΣv` (exact for true
/// eigenvectors; can lose PSD-ness for approximate ones).
pub fn hotelling(sigma: &mut SymMat, v: &[f64], theta: f64) {
    hotelling_par(sigma, v, theta, 1);
}

/// [`hotelling`] with the rank-1 update applied over row blocks on
/// `threads` workers (identical output for any thread count).
pub fn hotelling_par(sigma: &mut SymMat, v: &[f64], theta: f64, threads: usize) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    if n == 0 {
        return;
    }
    let rows_per_chunk = 64usize;
    let buf = sigma.as_mut_slice();
    crate::util::parallel::par_chunks_mut(threads, buf, rows_per_chunk * n, |off, chunk| {
        let row0 = off / n;
        for (r, row) in chunk.chunks_mut(n).enumerate() {
            let tv = theta * v[row0 + r];
            for j in 0..n {
                row[j] -= tv * v[j];
            }
        }
    });
}

/// Scheme selector used by the pipeline config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Projection,
    Hotelling,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "projection" => Some(Scheme::Projection),
            "hotelling" => Some(Scheme::Hotelling),
            _ => None,
        }
    }

    /// Apply the scheme for a unit direction `v` on `sigma`.
    pub fn apply(self, sigma: &mut SymMat, v: &[f64]) {
        self.apply_par(sigma, v, 1);
    }

    /// [`apply`](Scheme::apply) with the update spread over `threads`
    /// workers (same result for any thread count).
    pub fn apply_par(self, sigma: &mut SymMat, v: &[f64], threads: usize) {
        match self {
            Scheme::Projection => projection_par(sigma, v, threads),
            Scheme::Hotelling => {
                let mut w = vec![0.0; sigma.n()];
                sigma.matvec(v, &mut w);
                let theta = dot(v, &w);
                hotelling_par(sigma, v, theta, threads);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::is_psd;
    use crate::linalg::vec::normalize;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn prop_projection_annihilates_direction() {
        property("projection deflation: vᵀΣ'v = 0, Σ'v = 0, PSD kept", 15, |rng| {
            let n = rng.range(2, 10);
            let mut sigma = SymMat::random_psd(n, n + 5, 0.1, rng);
            let mut v = rng.gauss_vec(n);
            normalize(&mut v);
            projection(&mut sigma, &v);
            close(sigma.quad_form(&v), 0.0, 1e-8)?;
            let mut w = vec![0.0; n];
            sigma.matvec(&v, &mut w);
            for &x in &w {
                close(x, 0.0, 1e-8)?;
            }
            ensure(is_psd(&sigma, 1e-8), "projection must keep PSD")?;
            ensure(sigma.asymmetry() < 1e-9, "symmetric")?;
            Ok(())
        });
    }

    #[test]
    fn hotelling_exact_for_eigenvector() {
        let mut rng = crate::util::rng::Rng::seed_from(121);
        let sigma0 = SymMat::random_psd(6, 18, 0.1, &mut rng);
        let eig = crate::linalg::eig::JacobiEig::new(&sigma0);
        let mut sigma = sigma0.clone();
        hotelling(&mut sigma, eig.vector(0), eig.values[0]);
        // new top eigenvalue = old second eigenvalue
        let e2 = crate::linalg::eig::JacobiEig::new(&sigma);
        assert!((e2.lambda_max() - eig.values[1]).abs() < 1e-7);
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("projection"), Some(Scheme::Projection));
        assert_eq!(Scheme::parse("hotelling"), Some(Scheme::Hotelling));
        assert_eq!(Scheme::parse("x"), None);
    }
}
