//! Deflation: remove an extracted component before computing the next one
//! (the paper extracts "the top 5 sparse principal components" — its tables
//! are produced by repeated solve-then-deflate).
//!
//! Two forms live here:
//!
//! - the classic destructive dense updates ([`projection`], [`hotelling`])
//!   that edit a [`SymMat`] in place — kept for dense-only callers and as
//!   the reference the operator form is tested against;
//! - [`DeflatedCov`], a *composable rank-K correction* over any
//!   [`CovOp`]: each extracted component appends one or two symmetric
//!   rank-one terms, so K components cost O(K·n̂) memory on top of the
//!   base operator and the base (which may be an implicit Gram operator)
//!   is never modified.

use crate::covop::CovOp;
use crate::data::SymMat;
use crate::linalg::vec::dot;

/// Projection deflation: `Σ ← (I − vvᵀ) Σ (I − vvᵀ)` for a unit vector v.
/// Keeps PSD-ness and removes all variance along `v` (robust to `v` not
/// being an exact eigenvector — the right choice for sparse PCs).
pub fn projection(sigma: &mut SymMat, v: &[f64]) {
    projection_par(sigma, v, 1);
}

/// [`projection`] with the rank-2 update applied over row blocks on
/// `threads` workers. Rows are independent given `w` and `α`, so the
/// result is identical for any thread count.
pub fn projection_par(sigma: &mut SymMat, v: &[f64], threads: usize) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    if n == 0 {
        return;
    }
    // w = Σ v, α = vᵀΣv
    let mut w = vec![0.0; n];
    sigma.matvec(v, &mut w);
    let alpha = dot(v, &w);
    // Σ' = Σ − v wᵀ − w vᵀ + α v vᵀ, row blocks in parallel
    let rows_per_chunk = 64usize;
    let buf = sigma.as_mut_slice();
    crate::util::parallel::par_chunks_mut(threads, buf, rows_per_chunk * n, |off, chunk| {
        let row0 = off / n;
        for (r, row) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let vi = v[i];
            let wi = w[i];
            for j in 0..n {
                row[j] += -vi * w[j] - wi * v[j] + alpha * vi * v[j];
            }
        }
    });
}

/// Hotelling deflation: `Σ ← Σ − θ v vᵀ` with `θ = vᵀΣv` (exact for true
/// eigenvectors; can lose PSD-ness for approximate ones).
pub fn hotelling(sigma: &mut SymMat, v: &[f64], theta: f64) {
    hotelling_par(sigma, v, theta, 1);
}

/// [`hotelling`] with the rank-1 update applied over row blocks on
/// `threads` workers (identical output for any thread count).
pub fn hotelling_par(sigma: &mut SymMat, v: &[f64], theta: f64, threads: usize) {
    let n = sigma.n();
    assert_eq!(v.len(), n);
    if n == 0 {
        return;
    }
    let rows_per_chunk = 64usize;
    let buf = sigma.as_mut_slice();
    crate::util::parallel::par_chunks_mut(threads, buf, rows_per_chunk * n, |off, chunk| {
        let row0 = off / n;
        for (r, row) in chunk.chunks_mut(n).enumerate() {
            let tv = theta * v[row0 + r];
            for j in 0..n {
                row[j] -= tv * v[j];
            }
        }
    });
}

/// Scheme selector used by the pipeline config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Orthogonal projection deflation (removes the component subspace).
    Projection,
    /// Hotelling's deflation (subtracts the explained rank-one term).
    Hotelling,
}

impl Scheme {
    /// Parse the config string (`"projection"` | `"hotelling"`).
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "projection" => Some(Scheme::Projection),
            "hotelling" => Some(Scheme::Hotelling),
            _ => None,
        }
    }

    /// Apply the scheme for a unit direction `v` on `sigma`.
    pub fn apply(self, sigma: &mut SymMat, v: &[f64]) {
        self.apply_par(sigma, v, 1);
    }

    /// [`apply`](Scheme::apply) with the update spread over `threads`
    /// workers (same result for any thread count).
    pub fn apply_par(self, sigma: &mut SymMat, v: &[f64], threads: usize) {
        match self {
            Scheme::Projection => projection_par(sigma, v, threads),
            Scheme::Hotelling => {
                let mut w = vec![0.0; sigma.n()];
                sigma.matvec(v, &mut w);
                let theta = dot(v, &w);
                hotelling_par(sigma, v, theta, threads);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Operator-form deflation
// ---------------------------------------------------------------------------

/// A base covariance operator plus a symmetric low-rank correction:
///
/// ```text
/// Σ' = Σ_base + Σ_t (x_t y_tᵀ + y_t x_tᵀ)
/// ```
///
/// [`DeflatedCov::push`] appends the correction for one extracted unit
/// direction `v` under a [`Scheme`]:
///
/// - **Projection** `(I − vvᵀ)Σ(I − vvᵀ) = Σ − vwᵀ − wvᵀ + αvvᵀ` with
///   `w = Σv`, `α = vᵀΣv` → terms `(−v, w)` and `(αv/2, v)`;
/// - **Hotelling** `Σ − θvvᵀ` with `θ = vᵀΣv` → term `(−θv/2, v)`.
///
/// `w` and `α` are measured against the *current* deflated operator, so
/// pushing components one after another reproduces the sequential
/// destructive updates (up to FP summation order — pinned to ~1e-10 by
/// the deflate tests). The base is only read, never written: dense and
/// implicit-Gram backends share this path, and K components cost
/// O(K·n̂) extra memory.
pub struct DeflatedCov<'a, C: CovOp + ?Sized> {
    base: &'a C,
    terms: Vec<(Vec<f64>, Vec<f64>)>,
}

impl<'a, C: CovOp + ?Sized> DeflatedCov<'a, C> {
    /// Start with no correction (behaves exactly like `base`).
    pub fn new(base: &'a C) -> DeflatedCov<'a, C> {
        DeflatedCov { base, terms: Vec::new() }
    }

    /// Number of rank-one correction terms accumulated so far.
    pub fn rank(&self) -> usize {
        self.terms.len()
    }

    /// Deflate one extracted unit direction `v` under `scheme`.
    pub fn push(&mut self, scheme: Scheme, v: &[f64]) {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut w = vec![0.0; n];
        self.matvec(v, &mut w);
        let alpha = dot(v, &w);
        match scheme {
            Scheme::Projection => {
                let neg_v: Vec<f64> = v.iter().map(|&x| -x).collect();
                self.terms.push((neg_v, w));
                let half_av: Vec<f64> = v.iter().map(|&x| 0.5 * alpha * x).collect();
                self.terms.push((half_av, v.to_vec()));
            }
            Scheme::Hotelling => {
                let ht: Vec<f64> = v.iter().map(|&x| -0.5 * alpha * x).collect();
                self.terms.push((ht, v.to_vec()));
            }
        }
    }
}

impl<C: CovOp + ?Sized> CovOp for DeflatedCov<'_, C> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn diag(&self, j: usize) -> f64 {
        let mut d = self.base.diag(j);
        for (x, y) in &self.terms {
            d += 2.0 * x[j] * y[j];
        }
        d
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        self.base.row_into(j, out);
        for (x, y) in &self.terms {
            crate::linalg::vec::axpy(x[j], y, out);
            crate::linalg::vec::axpy(y[j], x, out);
        }
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        self.base.row_gather(j, idx, out);
        for (x, y) in &self.terms {
            let (xj, yj) = (x[j], y[j]);
            for (o, &i) in out.iter_mut().zip(idx) {
                *o += xj * y[i] + yj * x[i];
            }
        }
    }

    fn matvec(&self, v: &[f64], out: &mut [f64]) {
        self.base.matvec(v, out);
        for (x, y) in &self.terms {
            let yv = dot(y, v);
            let xv = dot(x, v);
            crate::linalg::vec::axpy(yv, x, out);
            crate::linalg::vec::axpy(xv, y, out);
        }
    }

    fn quad_form(&self, v: &[f64]) -> f64 {
        let mut q = self.base.quad_form(v);
        for (x, y) in &self.terms {
            q += 2.0 * dot(x, v) * dot(y, v);
        }
        q
    }

    fn frob_with(&self, m: &SymMat) -> f64 {
        // ⟨xyᵀ + yxᵀ, M⟩ = 2 xᵀMy for symmetric M.
        let mut acc = self.base.frob_with(m);
        let n = self.n();
        let mut my = vec![0.0; n];
        for (x, y) in &self.terms {
            SymMat::matvec(m, y, &mut my);
            acc += 2.0 * dot(x, &my);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covop::CovOp;
    use crate::linalg::chol::is_psd;
    use crate::linalg::vec::normalize;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn prop_projection_annihilates_direction() {
        property("projection deflation: vᵀΣ'v = 0, Σ'v = 0, PSD kept", 15, |rng| {
            let n = rng.range(2, 10);
            let mut sigma = SymMat::random_psd(n, n + 5, 0.1, rng);
            let mut v = rng.gauss_vec(n);
            normalize(&mut v);
            projection(&mut sigma, &v);
            close(sigma.quad_form(&v), 0.0, 1e-8)?;
            let mut w = vec![0.0; n];
            sigma.matvec(&v, &mut w);
            for &x in &w {
                close(x, 0.0, 1e-8)?;
            }
            ensure(is_psd(&sigma, 1e-8), "projection must keep PSD")?;
            ensure(sigma.asymmetry() < 1e-9, "symmetric")?;
            Ok(())
        });
    }

    #[test]
    fn hotelling_exact_for_eigenvector() {
        let mut rng = crate::util::rng::Rng::seed_from(121);
        let sigma0 = SymMat::random_psd(6, 18, 0.1, &mut rng);
        let eig = crate::linalg::eig::JacobiEig::new(&sigma0);
        let mut sigma = sigma0.clone();
        hotelling(&mut sigma, eig.vector(0), eig.values[0]);
        // new top eigenvalue = old second eigenvalue
        let e2 = crate::linalg::eig::JacobiEig::new(&sigma);
        assert!((e2.lambda_max() - eig.values[1]).abs() < 1e-7);
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("projection"), Some(Scheme::Projection));
        assert_eq!(Scheme::parse("hotelling"), Some(Scheme::Hotelling));
        assert_eq!(Scheme::parse("x"), None);
    }

    #[test]
    fn prop_deflated_cov_matches_destructive_updates() {
        property("DeflatedCov == sequential destructive deflation", 10, |rng| {
            let n = rng.range(3, 12);
            let base = SymMat::random_psd(n, n + 5, 0.1, rng);
            for scheme in [Scheme::Projection, Scheme::Hotelling] {
                // three sequential components
                let vs: Vec<Vec<f64>> = (0..3)
                    .map(|_| {
                        let mut v = rng.gauss_vec(n);
                        normalize(&mut v);
                        v
                    })
                    .collect();
                let mut dense = base.clone();
                let mut op = DeflatedCov::new(&base);
                for v in &vs {
                    scheme.apply(&mut dense, v);
                    op.push(scheme, v);
                }
                let mut row = vec![0.0; n];
                for j in 0..n {
                    close(op.diag(j), dense.get(j, j), 1e-9)?;
                    op.row_into(j, &mut row);
                    for k in 0..n {
                        close(row[k], dense.get(j, k), 1e-9)?;
                    }
                }
                let x = rng.gauss_vec(n);
                let (mut ya, mut yb) = (vec![0.0; n], vec![0.0; n]);
                CovOp::matvec(&op, &x, &mut ya);
                SymMat::matvec(&dense, &x, &mut yb);
                for k in 0..n {
                    close(ya[k], yb[k], 1e-8)?;
                }
                close(CovOp::quad_form(&op, &x), dense.quad_form(&x), 1e-8)?;
                let m = SymMat::random_psd(n, n + 2, 0.0, rng);
                close(op.frob_with(&m), dense.frob_dot(&m), 1e-7)?;
                ensure(op.rank() == if scheme == Scheme::Projection { 6 } else { 3 }, "rank")?;
            }
            Ok(())
        });
    }

    #[test]
    fn deflated_cov_projection_annihilates_direction() {
        let mut rng = crate::util::rng::Rng::seed_from(122);
        let base = SymMat::random_psd(8, 20, 0.1, &mut rng);
        let mut v = rng.gauss_vec(8);
        normalize(&mut v);
        let mut op = DeflatedCov::new(&base);
        op.push(Scheme::Projection, &v);
        assert!(CovOp::quad_form(&op, &v).abs() < 1e-8);
        let mut w = vec![0.0; 8];
        CovOp::matvec(&op, &v, &mut w);
        for x in &w {
            assert!(x.abs() < 1e-8, "Σ'v must vanish, got {x}");
        }
    }
}
