//! SPCA baseline (Zou, Hastie & Tibshirani [8]): sparse PCA as an
//! alternating elastic-net regression.
//!
//! For a single component on a covariance Σ (self-contained variant using
//! Σ's Cholesky-like square root as the data proxy):
//!
//! ```text
//! repeat:  β ← argmin_β ‖X α − X β‖² + λ₁‖β‖₁ + λ₂‖β‖²   (elastic net)
//!          α ← Σ β / ‖Σ β‖                                  (SVD step, rank 1)
//! ```
//!
//! Non-convex; converges to a local optimum. Included because the DSPCA
//! papers ([1,2,11], and this paper's intro) report that SPCA-style local
//! methods underperform the SDP relaxation — the ablation bench quantifies
//! that here.

use crate::data::SymMat;
use crate::linalg::eig::JacobiEig;
use crate::linalg::elastic_net::{self, EnetOptions};
use crate::linalg::vec::normalize;
use crate::solver::extract::SparsePc;

/// Options for the alternating SPCA solve.
#[derive(Clone, Copy, Debug)]
pub struct SpcaOptions {
    /// Maximum outer alternations.
    pub max_alternations: usize,
    /// Stop when the loading change falls below this.
    pub tol: f64,
    /// Elastic-net ridge term λ₂ (Zou's default regime: small positive).
    pub lambda2: f64,
    /// Inner elastic-net solver options.
    pub enet: EnetOptions,
}

impl Default for SpcaOptions {
    fn default() -> Self {
        SpcaOptions {
            max_alternations: 100,
            tol: 1e-8,
            lambda2: 1e-3,
            enet: EnetOptions::default(),
        }
    }
}

/// Factor Σ = RᵀR via its eigendecomposition (R = diag(√w) Vᵀ, rows of R
/// are features' "data" directions). Column-major m×p layout for the
/// elastic-net solver, with m = p = n.
fn sigma_root_colmajor(sigma: &SymMat) -> (Vec<f64>, usize) {
    let n = sigma.n();
    let eig = JacobiEig::new(sigma);
    // R[k, j] = sqrt(w_k) * V[k, j]; column j of R is feature j's vector.
    let mut r = vec![0.0f64; n * n];
    for k in 0..n {
        let s = eig.values[k].max(0.0).sqrt();
        for j in 0..n {
            r[j * n + k] = s * eig.vectors[k * n + j];
        }
    }
    (r, n)
}

/// One sparse component via alternating elastic net.
pub fn solve(sigma: &SymMat, lambda1: f64, opts: &SpcaOptions) -> SparsePc {
    let n = sigma.n();
    let (x, m) = sigma_root_colmajor(sigma); // m = n rows
    // α starts at the dense leading eigenvector.
    let mut alpha = crate::solver::pca::leading_pc(sigma, 10_000, 1e-12).vector;
    let mut beta = vec![0.0f64; n];
    for _ in 0..opts.max_alternations {
        // y = X α  (length m)
        let mut y = vec![0.0; m];
        for j in 0..n {
            let xj = &x[j * m..(j + 1) * m];
            for (yi, &xv) in y.iter_mut().zip(xj) {
                *yi += alpha[j] * xv;
            }
        }
        let new_beta = elastic_net::solve(&x, m, n, &y, lambda1, opts.lambda2, opts.enet);
        // α ← Σ β / ‖Σ β‖
        let mut sb = vec![0.0; n];
        sigma.matvec(&new_beta, &mut sb);
        if normalize(&mut sb) <= 1e-300 {
            beta = new_beta;
            break; // λ₁ killed the component
        }
        let delta = crate::linalg::vec::max_abs_diff(&new_beta, &beta);
        beta = new_beta;
        alpha = sb;
        if delta < opts.tol {
            break;
        }
    }
    let mut v = beta;
    if normalize(&mut v) <= 1e-300 {
        // empty component: return the zero PC
        return SparsePc { vector: vec![0.0; n], support: Vec::new(), z_eigenvalue: f64::NAN };
    }
    let mut support: Vec<usize> = (0..n).filter(|&i| v[i] != 0.0).collect();
    support.sort_by(|&a, &b| v[b].abs().partial_cmp(&v[a].abs()).unwrap());
    if let Some(&lead) = support.first() {
        if v[lead] < 0.0 {
            for xv in v.iter_mut() {
                *xv = -*xv;
            }
        }
    }
    SparsePc { vector: v, support, z_eigenvalue: f64::NAN }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::spiked_covariance_with_u;
    use crate::util::check::close;
    use crate::util::rng::Rng;

    #[test]
    fn sigma_root_reconstructs() {
        let mut rng = Rng::seed_from(221);
        let sigma = SymMat::random_psd(7, 20, 0.1, &mut rng);
        let (r, m) = sigma_root_colmajor(&sigma);
        // Σ_ij = column_i · column_j
        for i in 0..7 {
            for j in 0..7 {
                let d = crate::linalg::vec::dot(&r[i * m..(i + 1) * m], &r[j * m..(j + 1) * m]);
                close(d, sigma.get(i, j), 1e-8).unwrap();
            }
        }
    }

    #[test]
    fn lambda_zero_gives_dense_leading_direction() {
        let mut rng = Rng::seed_from(222);
        let sigma = SymMat::random_psd(8, 24, 0.1, &mut rng);
        let pc = solve(&sigma, 0.0, &SpcaOptions::default());
        let eig = crate::linalg::eig::JacobiEig::new(&sigma);
        let align: f64 = pc.vector.iter().zip(eig.vector(0)).map(|(a, b)| a * b).sum::<f64>().abs();
        assert!(align > 0.999, "alignment {align}");
    }

    #[test]
    fn recovers_spike_and_sparsifies() {
        let mut rng = Rng::seed_from(223);
        let (sigma, u) = spiked_covariance_with_u(20, 80, 4, 6.0, &mut rng);
        let pc = solve(&sigma, 0.8, &SpcaOptions::default());
        assert!(pc.cardinality() <= 10, "card {}", pc.cardinality());
        let planted = crate::linalg::vec::support(&u, 1e-9);
        let hits = pc.support.iter().filter(|i| planted.contains(i)).count();
        assert!(hits >= 3, "support {:?} planted {planted:?}", pc.support);
    }

    #[test]
    fn huge_lambda_empty_component() {
        let mut rng = Rng::seed_from(224);
        let sigma = SymMat::random_psd(6, 12, 0.1, &mut rng);
        let pc = solve(&sigma, 1e9, &SpcaOptions::default());
        assert_eq!(pc.cardinality(), 0);
    }
}
