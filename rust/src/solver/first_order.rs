//! The first-order DSPCA baseline of d'Aspremont, El Ghaoui, Jordan &
//! Lanckriet [1] — the method the paper's Fig 1 compares against.
//!
//! Problem (1) dualizes to
//!
//! ```text
//! φ = min_U  λ_max(Σ + U)   s.t.  ‖U‖∞ ≤ λ
//! ```
//!
//! (penalizing `‖Z‖₁` ⇔ a box-dual variable `U`). Following [1], the
//! non-smooth `λ_max` is smoothed with the softmax (matrix log-sum-exp)
//!
//! ```text
//! f_μ(U) = μ · log Tr exp((Σ + U)/μ) − μ log n,     μ = ε / (2 log n)
//! ```
//!
//! whose gradient is the Gibbs density matrix
//! `Z(U) = exp((Σ+U)/μ) / Tr exp((Σ+U)/μ)` — a feasible primal point, so
//! every iteration yields a primal objective value for the Fig 1 curve.
//! We run accelerated projected gradient (FISTA) on `f_μ` over the box;
//! each iteration needs a full eigendecomposition: O(n³) per step with a
//! O(1/ε) ÷ acceleration iteration count — the unfavorable scaling
//! (paper: O(n⁴√log n) total) that motivates Algorithm 1.

use crate::data::SymMat;
use crate::linalg::eig::JacobiEig;
use crate::util::timer::Timer;

/// Options for the first-order method.
#[derive(Clone, Copy, Debug)]
pub struct FirstOrderOptions {
    /// Maximum gradient iterations.
    pub max_iters: usize,
    /// Target accuracy ε (sets the smoothing μ = ε / (2 log n)).
    pub epsilon: f64,
    /// Stop when the duality-ish gap `f_μ(U) − primal(Z)` is below this.
    pub gap_tol: f64,
    /// Record history (objective vs time) every iteration.
    pub track_history: bool,
}

impl Default for FirstOrderOptions {
    fn default() -> Self {
        FirstOrderOptions { max_iters: 2000, epsilon: 1e-2, gap_tol: 1e-4, track_history: true }
    }
}

/// Result of the first-order solve.
#[derive(Clone, Debug)]
pub struct FirstOrderSolution {
    /// Best primal iterate `Z` (PSD, trace 1).
    pub z: SymMat,
    /// Its problem-(1) objective.
    pub phi: f64,
    /// Dual upper bound `min_k λ_max(Σ + U_k)`.
    pub dual_bound: f64,
    /// Iterations performed.
    pub iters: usize,
    /// (iteration, primal objective, seconds) samples.
    pub history: Vec<(usize, f64, f64)>,
    /// Total solve seconds.
    pub seconds: f64,
}

/// Smoothed objective and its gradient (the Gibbs density matrix).
fn smoothed_grad(sigma: &SymMat, u: &SymMat, mu: f64) -> (f64, SymMat, f64) {
    let n = sigma.n();
    let m = SymMat::from_fn(n, |i, j| sigma.get(i, j) + u.get(i, j));
    let eig = JacobiEig::new(&m);
    let wmax = eig.lambda_max();
    // softmax weights, stably
    let weights: Vec<f64> = eig.values.iter().map(|&w| ((w - wmax) / mu).exp()).collect();
    let total: f64 = weights.iter().sum();
    let fval = mu * total.ln() + wmax - mu * (n as f64).ln();
    let z = {
        let probs: Vec<f64> = weights.iter().map(|&w| w / total).collect();
        SymMat::from_fn(n, |i, j| {
            let mut s = 0.0;
            for k in 0..n {
                s += probs[k] * eig.vectors[k * n + i] * eig.vectors[k * n + j];
            }
            s
        })
    };
    (fval, z, wmax)
}

/// Project a symmetric matrix onto the box `‖U‖∞ ≤ λ`.
fn project_box(u: &mut SymMat, lambda: f64) {
    for v in u.as_mut_slice() {
        *v = v.clamp(-lambda, lambda);
    }
}

/// Primal problem-(1) objective of a trace-1 PSD `Z`.
fn primal(sigma: &SymMat, z: &SymMat, lambda: f64) -> f64 {
    sigma.frob_dot(z) - lambda * z.l1_norm()
}

/// Solve DSPCA with the smoothed accelerated first-order method.
pub fn solve(sigma: &SymMat, lambda: f64, opts: &FirstOrderOptions) -> FirstOrderSolution {
    let n = sigma.n();
    assert!(n > 0);
    let timer = Timer::start();
    let logn = (n.max(2) as f64).ln();
    let mu = opts.epsilon / (2.0 * logn);
    // Lipschitz constant of ∇f_μ in Frobenius geometry: 1/μ.
    let step = mu;
    let mut u = SymMat::zeros(n);
    let mut y = u.clone();
    let mut t_acc = 1.0f64;
    let mut best_phi = f64::NEG_INFINITY;
    let mut best_z = SymMat::identity(n);
    crate::linalg::vec::scale(1.0 / n as f64, best_z.as_mut_slice());
    let mut dual_bound = f64::INFINITY;
    let mut history = Vec::new();
    let mut iters = 0;
    for k in 0..opts.max_iters {
        let (fval, z, _wmax) = smoothed_grad(sigma, &y, mu);
        dual_bound = dual_bound.min(fval + mu * logn); // unsmoothed bound: λmax ≤ f_μ + μ log n
        let phi = primal(sigma, &z, lambda);
        if phi > best_phi {
            best_phi = phi;
            best_z = z.clone();
        }
        if opts.track_history {
            history.push((k, best_phi, timer.secs()));
        }
        iters = k + 1;
        if dual_bound - best_phi <= opts.gap_tol * (1.0 + best_phi.abs()) {
            break;
        }
        // Gradient step on f(U) = f_μ(Σ+U): ∂f/∂U = Z; we *minimize* over U.
        let mut u_next = y.clone();
        {
            let un = u_next.as_mut_slice();
            let zs = z.as_slice();
            for (a, b) in un.iter_mut().zip(zs) {
                *a -= step * b;
            }
        }
        project_box(&mut u_next, lambda);
        // FISTA momentum, safeguarded: the extrapolated point is clamped
        // back into the box so the gradient is always evaluated at a
        // *feasible* U — which is what makes `f_μ(U) + μ log n` a valid
        // dual upper bound on φ (an unprojected momentum point can leave
        // ‖U‖∞ ≤ λ and break the bound; the primal ≤ dual property test
        // caught exactly that).
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_acc * t_acc).sqrt());
        let gamma = (t_acc - 1.0) / t_next;
        let mut y_next = u_next.clone();
        {
            let yn = y_next.as_mut_slice();
            let uo = u.as_slice();
            let un = u_next.as_slice();
            for i in 0..yn.len() {
                yn[i] = un[i] + gamma * (un[i] - uo[i]);
            }
        }
        project_box(&mut y_next, lambda);
        u = u_next;
        y = y_next;
        t_acc = t_next;
    }
    FirstOrderSolution {
        z: best_z,
        phi: best_phi,
        dual_bound,
        iters,
        history,
        seconds: timer.secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::bca::{self, BcaOptions};
    use crate::util::check::{close, ensure, property};
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_case() {
        let sigma = SymMat::from_fn(4, |i, j| if i == j { [4.0, 1.0, 2.5, 0.9][i] } else { 0.0 });
        let sol = solve(&sigma, 0.5, &FirstOrderOptions { epsilon: 1e-3, max_iters: 3000, ..Default::default() });
        assert!((sol.phi - 3.5).abs() < 5e-2, "phi={}", sol.phi);
    }

    #[test]
    fn prop_primal_below_dual() {
        property("first-order: primal ≤ dual bound", 6, |rng| {
            let n = rng.range(2, 8);
            let sigma = SymMat::random_psd(n, n + 3, 0.1, rng);
            let lambda = 0.3 * sigma.trace() / n as f64;
            let sol = solve(&sigma, lambda, &FirstOrderOptions { max_iters: 200, ..Default::default() });
            ensure(
                sol.phi <= sol.dual_bound + 1e-6 * (1.0 + sol.dual_bound.abs()),
                format!("primal {} > dual {}", sol.phi, sol.dual_bound),
            )?;
            // Z is trace-1 PSD
            close(sol.z.trace(), 1.0, 1e-6)?;
            ensure(crate::linalg::chol::is_psd(&sol.z, 1e-9), "Z PSD")?;
            Ok(())
        });
    }

    #[test]
    fn agrees_with_bca_on_small_problems() {
        // Both solve the same convex problem — objectives must match.
        let mut rng = Rng::seed_from(101);
        for _ in 0..3 {
            let n = 6;
            let sigma = SymMat::random_psd(n, 12, 0.2, &mut rng);
            let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
            let lambda = 0.4 * min_diag;
            let fo = solve(
                &sigma,
                lambda,
                &FirstOrderOptions { epsilon: 1e-3, max_iters: 4000, gap_tol: 1e-5, ..Default::default() },
            );
            let b = bca::solve(&sigma, lambda, &BcaOptions { max_sweeps: 60, epsilon: 1e-5, ..Default::default() });
            close(fo.phi, b.phi, 2e-2).unwrap();
        }
    }

    #[test]
    fn history_is_monotone() {
        let mut rng = Rng::seed_from(102);
        let sigma = SymMat::random_psd(5, 10, 0.1, &mut rng);
        let sol = solve(&sigma, 0.1, &FirstOrderOptions { max_iters: 100, ..Default::default() });
        for w in sol.history.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
    }
}
