//! Simple thresholding baseline (Cadima & Jolliffe [4]): compute the dense
//! leading PC, keep the k largest |loadings|, renormalize. The ad-hoc
//! method the DSPCA literature shows underperforms the SDP relaxation —
//! included for the ablation benches.

use crate::data::SymMat;
use crate::solver::extract::SparsePc;

/// Thresholded leading PC with exactly `k` nonzeros (fewer if the dense PC
/// has fewer nonzeros).
pub fn thresholded_pc(sigma: &SymMat, k: usize) -> SparsePc {
    let dense = crate::solver::pca::leading_pc(sigma, 20_000, 1e-13);
    let mut idx: Vec<usize> = (0..dense.vector.len()).collect();
    idx.sort_by(|&a, &b| dense.vector[b].abs().partial_cmp(&dense.vector[a].abs()).unwrap());
    let mut v = vec![0.0; dense.vector.len()];
    for &i in idx.iter().take(k) {
        v[i] = dense.vector[i];
    }
    crate::linalg::vec::normalize(&mut v);
    let mut support: Vec<usize> = idx
        .into_iter()
        .take(k)
        .filter(|&i| v[i] != 0.0)
        .collect();
    support.sort_by(|&a, &b| v[b].abs().partial_cmp(&v[a].abs()).unwrap());
    if let Some(&lead) = support.first() {
        if v[lead] < 0.0 {
            for x in v.iter_mut() {
                *x = -*x;
            }
        }
    }
    SparsePc { vector: v, support, z_eigenvalue: f64::NAN }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, ensure, property};

    #[test]
    fn prop_cardinality_and_norm() {
        property("thresholding: card ≤ k, unit norm", 15, |rng| {
            let n = rng.range(2, 12);
            let sigma = SymMat::random_psd(n, n + 4, 0.05, rng);
            let k = rng.range(1, n + 1);
            let pc = thresholded_pc(&sigma, k);
            ensure(pc.cardinality() <= k, "cardinality bound")?;
            close(crate::linalg::vec::norm2(&pc.vector), 1.0, 1e-9)?;
            Ok(())
        });
    }

    #[test]
    fn underperforms_or_ties_dspca_on_spiked() {
        // The classic motivating example: thresholding picks coordinates of
        // the dense PC which mixes spike and noise; DSPCA's variance should
        // be at least as good (allowing small numerical slack).
        let mut rng = crate::util::rng::Rng::seed_from(131);
        let (sigma, _) = crate::corpus::models::spiked_covariance_with_u(25, 50, 4, 2.0, &mut rng);
        let thr = thresholded_pc(&sigma, 4);
        let lam = crate::elim::lambda_for_survivors(
            &(0..25).map(|i| sigma.get(i, i)).collect::<Vec<_>>(),
            8,
        );
        let sol = crate::solver::bca::solve(&sigma, lam, &crate::solver::bca::BcaOptions::default());
        let pc = crate::solver::extract::leading_sparse_pc(&sol.z, 1e-4);
        let (v_thr, v_dspca) = (thr.explained_variance(&sigma), pc.explained_variance(&sigma));
        assert!(
            v_dspca >= 0.5 * v_thr,
            "DSPCA {v_dspca} unreasonably below thresholding {v_thr}"
        );
    }
}
