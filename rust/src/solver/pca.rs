//! Plain PCA — the O(n²)-per-iteration comparison point of the paper's
//! "sparse PCA can be easier than PCA" argument, and the dense baseline in
//! the topic-table experiments.

use crate::data::SymMat;
use crate::linalg::power::{power_iteration, PowerResult};
use crate::util::rng::Rng;

/// Leading principal component of a covariance matrix.
#[derive(Clone, Debug)]
pub struct PcaComponent {
    /// Unit-norm loading vector.
    pub vector: Vec<f64>,
    /// Explained variance (the eigenvalue).
    pub variance: f64,
    /// Power iterations performed.
    pub iters: usize,
}

/// Compute the leading PC by power iteration (deterministic seed).
pub fn leading_pc(sigma: &SymMat, max_iters: usize, tol: f64) -> PcaComponent {
    let mut rng = Rng::seed_from(0x9CA ^ sigma.n() as u64);
    let PowerResult { vector, value, iters, .. } = power_iteration(sigma, max_iters, tol, &mut rng);
    PcaComponent { vector, variance: value, iters }
}

/// Top-k PCs via power iteration + Hotelling deflation (reference
/// implementation for tests & the PCA column of the topic benchmarks).
pub fn top_k(sigma: &SymMat, k: usize, max_iters: usize, tol: f64) -> Vec<PcaComponent> {
    let mut work = sigma.clone();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let pc = leading_pc(&work, max_iters, tol);
        crate::solver::deflate::hotelling(&mut work, &pc.vector, pc.variance);
        out.push(pc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::JacobiEig;
    use crate::util::check::{close, property};

    #[test]
    fn prop_topk_matches_jacobi() {
        property("power-iteration top-k ≈ Jacobi eigenvalues", 8, |rng| {
            let n = rng.range(3, 10);
            let sigma = SymMat::random_psd(n, 3 * n, 0.05, rng);
            let eig = JacobiEig::new(&sigma);
            let pcs = top_k(&sigma, 3.min(n), 20_000, 1e-13);
            for (k, pc) in pcs.iter().enumerate() {
                close(pc.variance, eig.values[k], 1e-3)?;
            }
            Ok(())
        });
    }

    #[test]
    fn explained_variance_is_rayleigh() {
        let mut rng = crate::util::rng::Rng::seed_from(111);
        let sigma = SymMat::random_psd(7, 20, 0.1, &mut rng);
        let pc = leading_pc(&sigma, 10_000, 1e-12);
        let quad = sigma.quad_form(&pc.vector);
        assert!((quad - pc.variance).abs() < 1e-8 * (1.0 + quad));
    }
}
